"""Batch/scalar equivalence for the columnar telemetry plane.

The tentpole invariant: replaying a trace as columnar ``EventBatch`` chunks
must yield *identical* findings (same rows, timestamps, loci, severities,
scores — bit-for-bit) as replaying the same trace event-by-event, for every
registered detector and for the whole plane.  Vectorized ``update_batch``
implementations are only allowed to strip interpreter overhead, never to
change the math.

Also covers the EventBatch/EventBatchBuilder container semantics and the
bounded ring-buffer EventStream.
"""

import dataclasses
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # clean checkout: seeded-random fallback
    from proptest_fallback import given, settings, st

from repro.core import TelemetryPlane
from repro.core.detectors import Detector, DetectorConfig
from repro.core.events import (
    BATCH_COLUMNS,
    CollectiveOp,
    Event,
    EventBatch,
    EventBatchBuilder,
    EventKind,
    EventStream,
)
from repro.core.runbooks import ALL_RUNBOOKS

event_strategy = st.builds(
    Event,
    ts=st.floats(min_value=0.0, max_value=10.0, allow_nan=False),
    kind=st.sampled_from(list(EventKind)),
    node=st.integers(-1, 8),
    device=st.integers(-1, 8),
    flow=st.integers(-1, 64),
    size=st.integers(0, 1 << 30),
    depth=st.integers(0, 1 << 16),
    op=st.sampled_from([-1] + [int(o) for o in CollectiveOp]),
    group=st.integers(-1, 8),
    meta=st.integers(0, 1 << 10),
    replica=st.integers(-1, 4),
)


def _random_trace(rng: random.Random, n: int) -> list[Event]:
    kinds = list(EventKind)
    evs, t = [], 0.0
    for _ in range(n):
        t += rng.expovariate(4000.0)
        evs.append(Event(
            ts=t, kind=rng.choice(kinds), node=rng.randrange(4),
            device=rng.randrange(4), flow=rng.randrange(48),
            size=rng.randrange(1 << 20), depth=rng.randrange(128),
            op=rng.choice([-1] + [int(o) for o in CollectiveOp]),
            group=rng.randrange(4), meta=rng.randrange(600),
            replica=rng.randrange(4)))
    return evs


def _finding_key(findings):
    # evidence is excluded from Finding equality; everything else must match
    return [(f.name, f.table, f.ts, f.severity, f.node, f.device, f.stage,
             f.root_cause, f.directive, f.score) for f in findings]


class TestDetectorEquivalence:
    """Every registered detector: batched replay == event-by-event replay."""

    @pytest.mark.parametrize("entry", ALL_RUNBOOKS,
                             ids=lambda e: e.row_id)
    def test_batch_equals_scalar(self, entry):
        rng = random.Random(sum(map(ord, entry.row_id)))
        for trial in range(3):
            events = [e for e in _random_trace(rng, 700)
                      if e.kind in entry.detector_cls.interested]
            if not events:
                continue
            cfg = DetectorConfig()
            d_scalar = entry.detector_cls(cfg)
            d_one = entry.detector_cls(cfg)      # one big batch
            d_chunked = entry.detector_cls(cfg)  # random chunk sizes
            end = events[-1].ts
            # poll at interior points too: peak latches / interval counters
            # must agree mid-stream, not only at the end
            cuts = [end * 0.4, end * 0.8, end + 0.5]
            lo = 0
            prev_cut = 0.0
            for cut in cuts:
                seg = [e for e in events if prev_cut < e.ts <= cut] \
                    if prev_cut else [e for e in events if e.ts <= cut]
                prev_cut = cut
                for ev in seg:
                    d_scalar.update(ev)
                if seg:
                    d_one.update_batch(EventBatch.from_events(seg))
                    i = 0
                    while i < len(seg):
                        k = rng.randrange(1, 64)
                        d_chunked.update_batch(
                            EventBatch.from_events(seg[i:i + k]))
                        i += k
                f1 = _finding_key(d_scalar.poll(cut))
                f2 = _finding_key(d_one.poll(cut))
                f3 = _finding_key(d_chunked.poll(cut))
                assert f1 == f2 == f3, (
                    f"{entry.row_id} trial {trial} poll@{cut}: "
                    f"scalar={f1} one={f2} chunked={f3}")
            assert d_scalar.events_seen == d_one.events_seen \
                == d_chunked.events_seen


class TestPlaneEquivalence:
    @given(st.lists(event_strategy, min_size=1, max_size=300),
           st.integers(1, 97))
    @settings(max_examples=15, deadline=None)
    def test_random_stream(self, events, chunk):
        stream = sorted(events, key=lambda e: e.ts)

        p_scalar = TelemetryPlane(n_nodes=4, mitigate=False)
        for ev in stream:
            p_scalar.observe(ev)
        p_scalar.tick(11.0)

        p_batched = TelemetryPlane(n_nodes=4, mitigate=False)
        for i in range(0, len(stream), chunk):
            p_batched.observe_batch(
                EventBatch.from_events(stream[i:i + chunk]))
        p_batched.tick(11.0)

        assert _finding_key(p_scalar.findings) \
            == _finding_key(p_batched.findings)
        assert p_scalar.stats.events == p_batched.stats.events == len(stream)

    @pytest.mark.slow
    @pytest.mark.parametrize("scenario", ["egress_jitter", "nic_saturation",
                                          "ingress_retransmit",
                                          "hot_replica"])
    def test_sim_trace(self, scenario):
        """End-to-end: a real fault trace through the full detector set."""
        from repro.core.events import EventTraceRecorder
        from repro.sim import SCENARIOS
        from repro.sim.cluster import ClusterSim

        sc = SCENARIOS[scenario]
        rec = EventTraceRecorder()
        wl = dataclasses.replace(sc.workload,
                                 duration=sc.params.duration * 0.98)
        ClusterSim(dataclasses.replace(sc.params), wl,
                   dataclasses.replace(sc.fault), plane=rec).run()

        p_batched = TelemetryPlane(n_nodes=sc.params.n_nodes, mitigate=False)
        for b in rec.batches:
            p_batched.observe_batch(b)

        p_scalar = TelemetryPlane(n_nodes=sc.params.n_nodes, mitigate=False)
        for b in rec.batches:
            for ev in b.iter_events():
                p_scalar.observe(ev)

        assert p_batched.findings, f"{scenario}: trace produced no findings"
        assert p_batched.findings == p_scalar.findings
        assert _finding_key(p_batched.findings) \
            == _finding_key(p_scalar.findings)
        assert p_batched.stats.events == p_scalar.stats.events


class TestEventBatch:
    def test_roundtrip(self):
        rng = random.Random(0)
        evs = sorted(_random_trace(rng, 50), key=lambda e: e.ts)
        batch = EventBatch.from_events(evs)
        assert len(batch) == 50
        assert batch.to_events() == evs

    def test_builder_sorts_stably(self):
        b = EventBatchBuilder()
        b.add(ts=2.0, kind=EventKind.INGRESS_PKT, node=0, flow=1)
        b.add(ts=1.0, kind=EventKind.EGRESS_PKT, node=1, flow=2)
        b.add(ts=1.0, kind=EventKind.EGRESS_PKT, node=2, flow=3)
        batch = b.build(sort=True)
        out = batch.to_events()
        assert [e.ts for e in out] == [1.0, 1.0, 2.0]
        # equal timestamps keep emission order (stable sort)
        assert [e.node for e in out] == [1, 2, 0]

    def test_add_many_broadcast(self):
        b = EventBatchBuilder()
        b.add_many([0.1, 0.2, 0.3], kind=EventKind.EGRESS_PKT, node=7,
                   flow=[10, 11, 12], size=512)
        batch = b.build()
        evs = batch.to_events()
        assert [e.flow for e in evs] == [10, 11, 12]
        assert all(e.node == 7 and e.size == 512
                   and e.kind == EventKind.EGRESS_PKT for e in evs)

    def test_slice_and_compress(self):
        rng = random.Random(1)
        evs = sorted(_random_trace(rng, 40), key=lambda e: e.ts)
        batch = EventBatch.from_events(evs)
        assert batch.slice(5, 9).to_events() == evs[5:9]
        mask = batch.kind == EventKind.INGRESS_PKT
        assert batch.compress(mask).to_events() == [
            e for e in evs if e.kind == EventKind.INGRESS_PKT]

    def test_add_many_array_columns_and_length_validation(self):
        b = EventBatchBuilder()
        b.add_many(np.asarray([0.1, 0.2, 0.3]), kind=EventKind.EGRESS_PKT,
                   node=np.asarray([1, 2, 3]), flow=[7, 8, 9], size=64)
        evs = b.build().to_events()
        assert [e.node for e in evs] == [1, 2, 3]
        assert [e.flow for e in evs] == [7, 8, 9]
        assert all(e.size == 64 for e in evs)
        with pytest.raises(ValueError):
            b.add_many([0.1, 0.2], kind=EventKind.EGRESS_PKT,
                       flow=[1, 2, 3])
        with pytest.raises(ValueError):
            b.add_many([0.1, 0.2], kind=EventKind.EGRESS_PKT,
                       flow=np.asarray([1]))

    def test_add_columns_mixed_scalar_and_array(self):
        b = EventBatchBuilder()
        b.add_columns(np.asarray([0.3, 0.1, 0.2]),
                      EventKind.INGRESS_PKT,
                      node=np.asarray([3, 1, 2]),
                      flow=5, size=np.asarray([30, 10, 20]), meta=9)
        evs = b.build(sort=True).to_events()
        assert [e.ts for e in evs] == [0.1, 0.2, 0.3]
        assert [e.node for e in evs] == [1, 2, 3]       # sorted with ts
        assert [e.size for e in evs] == [10, 20, 30]
        assert all(e.flow == 5 and e.meta == 9
                   and e.kind == EventKind.INGRESS_PKT for e in evs)

    def test_add_columns_interleaves_with_row_adds(self):
        # insertion order across granularities is preserved for stable
        # tie-breaking
        b = EventBatchBuilder()
        b.add(ts=1.0, kind=EventKind.EGRESS_PKT, node=0)
        b.add_columns(np.asarray([1.0, 1.0]), EventKind.EGRESS_PKT,
                      node=np.asarray([1, 2]))
        b.add(ts=1.0, kind=EventKind.EGRESS_PKT, node=3)
        assert len(b) == 4
        assert [e.node for e in b.build().to_events()] == [0, 1, 2, 3]
        b.clear()
        assert len(b) == 0
        assert b.build().to_events() == []

    def test_add_columns_validation(self):
        b = EventBatchBuilder()
        with pytest.raises(ValueError):       # length mismatch
            b.add_columns(np.asarray([0.1, 0.2]), EventKind.EGRESS_PKT,
                          node=np.asarray([1, 2, 3]))
        with pytest.raises(TypeError):        # float array in int column
            b.add_columns(np.asarray([0.1, 0.2]), EventKind.EGRESS_PKT,
                          size=np.asarray([1.5, 2.5]))
        with pytest.raises(ValueError):       # ts must be 1-D
            b.add_columns(np.zeros((2, 2)), EventKind.EGRESS_PKT)
        with pytest.raises(ValueError):
            b.add_many([0.1, 0.2], kind=EventKind.EGRESS_PKT, node=[1])
        # failed appends must leave NO state behind: a later valid append
        # and build must reflect only the valid rows (no orphan fragments)
        b.add_columns(np.asarray([0.25]), EventKind.INGRESS_PKT, node=4)
        evs = b.build().to_events()
        assert len(evs) == 1
        assert (evs[0].ts, evs[0].node, evs[0].kind) == (
            0.25, 4, EventKind.INGRESS_PKT)
        b.clear()
        b.add_columns(np.empty(0), EventKind.EGRESS_PKT)   # empty is a no-op
        assert len(b) == 0
        # smaller int dtypes are widened, not rejected
        b.add_columns(np.asarray([0.5]), EventKind.EGRESS_PKT,
                      size=np.asarray([7], np.int32))
        assert b.build().to_events()[0].size == 7

    def test_add_columns_equivalent_to_row_adds(self):
        rng = random.Random(7)
        evs = _random_trace(rng, 60)
        rows = EventBatchBuilder()
        cols = EventBatchBuilder()
        for ev in evs:
            rows.add_event(ev)
        cols.add_columns(
            np.asarray([e.ts for e in evs]),
            np.asarray([int(e.kind) for e in evs]),
            node=np.asarray([e.node for e in evs]),
            device=np.asarray([e.device for e in evs]),
            flow=np.asarray([e.flow for e in evs]),
            size=np.asarray([e.size for e in evs]),
            depth=np.asarray([e.depth for e in evs]),
            op=np.asarray([e.op for e in evs]),
            group=np.asarray([e.group for e in evs]),
            meta=np.asarray([e.meta for e in evs]),
            replica=np.asarray([e.replica for e in evs]))
        a, b = rows.build(sort=True), cols.build(sort=True)
        for col in BATCH_COLUMNS:
            assert np.array_equal(getattr(a, col), getattr(b, col)), col


class TestEventStreamRing:
    def test_bounded_retention(self):
        stream = EventStream(capacity=100)
        b = EventBatchBuilder()
        b.add_many([i * 0.001 for i in range(50)],
                   kind=EventKind.EGRESS_PKT, node=0)
        for _ in range(10):
            stream.emit_batch(b.build())
        assert stream.total_events == 500
        assert len(stream) <= 150    # capacity + one chunk of slack
        # retained events are the most recent ones
        assert min(e.ts for e in stream) >= 0.0

    def test_full_trace_mode(self):
        stream = EventStream(capacity=100, full_trace=True)
        for i in range(500):
            stream.emit(Event(ts=i * 1e-3, kind=EventKind.EGRESS_PKT,
                              node=0))
        assert len(stream) == 500
        assert stream.total_events == 500

    def test_subscriber_batch_fanout(self):
        stream = EventStream()
        seen = []
        stream.subscribe(lambda b: seen.append(len(b)))
        b = EventBatchBuilder()
        b.add_many([0.1, 0.2], kind=EventKind.EGRESS_PKT, node=0)
        stream.emit_batch(b.build())
        stream.emit(Event(ts=0.3, kind=EventKind.EGRESS_PKT, node=0))
        assert seen == [2, 1]


class TestSampledTiming:
    def test_ns_per_event_from_sampled_windows(self):
        plane = TelemetryPlane(n_nodes=1, mitigate=False)
        rng = random.Random(3)
        for ev in sorted(_random_trace(rng, 400), key=lambda e: e.ts):
            plane.observe(ev)
        stats = plane.stats
        assert stats.events == 400
        assert 0 < stats.timed_events < stats.events
        assert plane.report()["ns_per_event"] >= 0.0

    def test_batch_path_counts_all_events(self):
        plane = TelemetryPlane(n_nodes=1, mitigate=False)
        rng = random.Random(4)
        evs = sorted(_random_trace(rng, 300), key=lambda e: e.ts)
        for i in range(0, 300, 30):
            plane.observe_batch(EventBatch.from_events(evs[i:i + 30]))
        assert plane.stats.events == 300
        assert plane.stats.timed_events > 0
