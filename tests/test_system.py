"""End-to-end behaviour tests for the paper's system: telemetry plane on
the LIVE serving engine, detection latency, overhead accounting, and the
full observe -> detect -> attribute -> mitigate loop."""

import random

import jax
import pytest

from repro.configs import ARCHS
from repro.core import DetectorConfig, TelemetryPlane
from repro.core.events import Event, EventKind
from repro.models import build_model
from repro.serving import EngineConfig, InferenceEngine, ServeRequest
from repro.sim import SCENARIOS, run_scenario


@pytest.fixture(scope="module")
def engine_parts():
    cfg = ARCHS["qwen3-0.6b"].reduced()
    m = build_model(cfg)
    return cfg, m, m.init(jax.random.key(0))


class TestLiveEngineTelemetry:
    """The real JAX engine emits the same schema the detectors consume."""

    def test_event_stream_covers_three_vantages(self, engine_parts):
        cfg, m, params = engine_parts
        eng = InferenceEngine(m, params, EngineConfig(
            max_slots=4, max_seq=128, n_pages=128, page_size=16))
        rng = random.Random(0)
        reqs = [ServeRequest(req_id=i, arrival=i * 0.002,
                             prompt=[1] * rng.randrange(8, 30),
                             max_new_tokens=6) for i in range(8)]
        eng.run(reqs, max_steps=200)
        kinds = {e.kind for e in eng.plane.agent.stream}
        assert EventKind.INGRESS_PKT in kinds
        assert EventKind.EGRESS_PKT in kinds
        assert EventKind.H2D_XFER in kinds
        assert EventKind.D2H_XFER in kinds
        assert EventKind.DISPATCH in kinds
        assert EventKind.QUEUE_SAMPLE in kinds

    def test_healthy_engine_run_is_clean(self, engine_parts):
        cfg, m, params = engine_parts
        eng = InferenceEngine(m, params, EngineConfig(
            max_slots=4, max_seq=128, n_pages=128, page_size=16))
        reqs = [ServeRequest(req_id=i, arrival=i * 0.004, prompt=[1] * 16,
                             max_new_tokens=8) for i in range(10)]
        rep = eng.run(reqs, max_steps=300)
        assert rep["completed"] == 10
        assert rep["telemetry"]["findings"] == 0

    def test_overhead_under_budget(self, engine_parts):
        """Paper's premise: observability must be (nearly) free for the
        host — our full 28-detector plane costs microseconds per event."""
        cfg, m, params = engine_parts
        eng = InferenceEngine(m, params, EngineConfig(
            max_slots=4, max_seq=128, n_pages=128, page_size=16))
        reqs = [ServeRequest(req_id=i, arrival=0.0, prompt=[1] * 16,
                             max_new_tokens=8) for i in range(8)]
        rep = eng.run(reqs, max_steps=200)
        assert rep["telemetry"]["ns_per_event"] < 200_000   # < 0.2 ms


class TestDetectionLatency:
    def test_straggler_detected_within_two_seconds(self):
        sc = SCENARIOS["tp_straggler"]
        metrics, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        assert metrics.first_finding_ts > 0
        latency = metrics.first_finding_ts - sc.fault.start
        assert latency < 2.0

    def test_detection_is_deterministic(self):
        sc = SCENARIOS["kv_bottleneck"]
        runs = []
        for _ in range(2):
            _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
            runs.append(sorted({f.name for f in plane.findings}))
        assert runs[0] == runs[1]


class TestPlaneDedup:
    def test_steady_condition_not_respammed(self):
        plane = TelemetryPlane(n_nodes=1, mitigate=False)
        t = 0.0
        # sustained retransmit storm: one finding per dedup window, not
        # one per poll
        for i in range(4000):
            t += 0.001
            plane.observe(Event(ts=t, kind=EventKind.COLLECTIVE_BURST,
                                node=0, size=1 << 20, group=0, meta=i))
            if i % 3 == 0:
                plane.observe(Event(ts=t, kind=EventKind.RETRANSMIT,
                                    node=0, size=1500, meta=2))
        n = sum(1 for f in plane.findings
                if f.name == "retransmissions_packet_loss")
        assert 1 <= n <= int(t / plane.dedup_window) + 1
