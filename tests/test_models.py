"""Per-architecture smoke + consistency tests (reduced configs, CPU).

For each of the 10 assigned archs: one forward/train step with shape and
finiteness assertions, plus the decode-consistency invariant
(prefill + step-by-step decode == full forward) that validates every cache
type (ring KV, SWA ring, MoE routing, Mamba2 state, xLSTM state, cross-
attention).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def make_batch(cfg, key, B=2, S=24):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    kw = {}
    if cfg.family == "encdec":
        fe = jax.random.normal(key, (B, 16, cfg.d_model))
        batch["frontend"] = fe
        kw["frontend"] = fe
    if cfg.family == "vlm":
        fe = jax.random.normal(key, (B, cfg.frontend_tokens, cfg.d_model))
        batch["frontend"] = fe
        kw["frontend"] = fe
    return batch, kw


@pytest.mark.parametrize("arch", ASSIGNED)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = ARCHS[arch].reduced()
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        batch, _ = make_batch(cfg, jax.random.key(1))
        logits, aux = m.forward(params, batch)
        S = batch["tokens"].shape[1]
        assert logits.shape == (2, S, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        assert np.isfinite(float(aux))

    def test_train_step_reduces_loss_and_is_finite(self, arch):
        cfg = ARCHS[arch].reduced()
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        batch, _ = make_batch(cfg, jax.random.key(1))
        opt = adamw_init(params)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=1)

        @jax.jit
        def step(p, o, b):
            loss, g = jax.value_and_grad(m.loss)(p, b)
            p, o, _ = adamw_update(ocfg, g, o, p)
            return p, o, loss

        losses = []
        for _ in range(3):
            params, opt, loss = step(params, opt, batch)
            losses.append(float(loss))
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0]        # same batch: must memorize

    def test_decode_step_shapes(self, arch):
        cfg = ARCHS[arch].reduced()
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        cache = m.init_cache(2, 64, src_len=16)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, new_cache = m.decode_step(params, tok, cache)
        assert logits.shape == (2, 1, cfg.vocab)
        assert int(new_cache["pos"]) == 1
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_matches_full_forward(arch):
    cfg = ARCHS[arch].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(1))
    B, S = 2, 24
    batch, kw = make_batch(cfg, jax.random.key(2), B, S)
    toks = batch["tokens"]
    full_logits, _ = m.forward(params, batch)
    cache = m.init_cache(B, 64, src_len=16)
    pre, cache = m.prefill(params, toks[:, :S - 4], cache, **kw)
    errs = [float(np.max(np.abs(np.asarray(
        pre[:, 0] - full_logits[:, S - 5], np.float32))))]
    for i in range(S - 4, S):
        lg, cache = m.decode_step(params, toks[:, i:i + 1], cache)
        errs.append(float(np.max(np.abs(np.asarray(
            lg[:, 0] - full_logits[:, i], np.float32)))))
    assert max(errs) < 1e-3, f"{arch}: {max(errs)}"


def test_swa_ring_buffer_wraps_correctly():
    """Prefill beyond the window + decode through several ring wraps."""
    cfg = ARCHS["h2o-danube-3-4b"].reduced(swa_window=16, n_layers=2)
    m = build_model(cfg)
    params = m.init(jax.random.key(4))
    B, S = 2, 48
    toks = jax.random.randint(jax.random.key(5), (B, S), 0, cfg.vocab)
    full_logits, _ = m.forward(params, {"tokens": toks})
    cache = m.init_cache(B, 16)
    pre, cache = m.prefill(params, toks[:, :32], cache)
    errs = [float(np.max(np.abs(np.asarray(
        pre[:, 0] - full_logits[:, 31], np.float32))))]
    for i in range(32, S):
        lg, cache = m.decode_step(params, toks[:, i:i + 1], cache)
        errs.append(float(np.max(np.abs(np.asarray(
            lg[:, 0] - full_logits[:, i], np.float32)))))
    assert max(errs) < 1e-3


def test_scan_and_unrolled_layers_agree():
    for arch in ("llama3.2-3b", "zamba2-7b", "xlstm-125m"):
        cfg = ARCHS[arch].reduced()
        m1 = build_model(cfg)
        m2 = build_model(dataclasses.replace(cfg, unroll_layers=True))
        p = m1.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
        l1, _ = m1.forward(p, {"tokens": toks})
        l2, _ = m2.forward(p, {"tokens": toks})
        assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-4


def test_moe_aux_loss_nonzero_and_balanced_router_low():
    cfg = ARCHS["qwen2-moe-a2.7b"].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
    _, aux = m.forward(params, {"tokens": toks})
    assert float(aux) > 0.0
    # perfectly balanced router would give aux ~= coef (E * 1/E * 1/E * E)
    assert float(aux) < 1.0


def test_param_count_formulas():
    # llama2-7b ~ 6.7e9; qwen2-moe total ~14e9 vs active ~2.7e9
    c = ARCHS["paper-llama2-7b"]
    assert 6.0e9 < c.param_count() < 7.5e9
    moe = ARCHS["qwen2-moe-a2.7b"]
    assert moe.param_count() > 3 * moe.active_param_count()
    dense = ARCHS["llama3.2-3b"]
    assert dense.param_count() == dense.active_param_count()


def test_kernel_dispatch_path_matches_jnp():
    """cfg.use_kernels routes attention through kernels/ops.py; on CPU the
    dispatcher selects the oracle, on TPU the Pallas kernel (validated
    separately in test_kernels.py) — numerics must agree either way."""
    for arch in ("llama3.2-3b", "h2o-danube-3-4b"):
        cfg = ARCHS[arch].reduced()
        m0 = build_model(cfg)
        m1 = build_model(dataclasses.replace(cfg, use_kernels=True))
        p = m0.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
        l0, _ = m0.forward(p, {"tokens": toks})
        l1, _ = m1.forward(p, {"tokens": toks})
        assert float(jnp.max(jnp.abs(l0 - l1))) < 1e-4
