"""Observability layer: causal tracing, flight recorder, TTM decomposition,
metrics exposition — and the golden-parity guard.

The load-bearing properties:

* Tracing is observe-only: every scenario's findings are bit-identical
  with tracing enabled or disabled (the committed golden fixture pins the
  disabled path, so a traced run must reproduce it exactly).
* One trace context per fault episode: the first finding opens the
  incident, everything downstream (attribution, policy, bus, transitions,
  apply) attaches to it, and the mitigating apply closes it — including
  across a mid-incident DPU crash, standby promotion, and failback.
* TTM telescopes: the decomposed phases always sum to the scalar
  ``t_recover`` the rest of the repo reports.
"""

import dataclasses
import json
import os
from types import SimpleNamespace

import pytest

from repro.dpu import DPUParams, WatchdogParams
from repro.obs import (
    FlightRecorder,
    Incident,
    MetricsRegistry,
    Tracer,
    collect_metrics,
    validate_report,
)
from repro.obs.trace import MAX_EVENTS_PER_INCIDENT
from repro.sim import SCENARIOS, SweepConfig, run_scenario, run_sweep

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "scenario_findings.json")
with open(GOLDEN_PATH) as fh:
    GOLDEN = json.load(fh)["scenarios"]


def _finding(name="tp_straggler", node=2, ts=1.5, severity="warn",
             score=5.0):
    return SimpleNamespace(name=name, node=node, ts=ts, severity=severity,
                           score=score)


def _attribution(ts=1.5, locus="device_scheduling", node=2,
                 confidence=0.5, primary=None):
    return SimpleNamespace(ts=ts, locus=locus, node=node,
                           confidence=confidence,
                           primary=primary or _finding())


def _cmd(cmd_id=1, ts=2.0, action="rebalance_tp", node=2,
         row_id="tp_straggler", term=0):
    return SimpleNamespace(cmd_id=cmd_id, ts=ts, action=action, node=node,
                           row_id=row_id, term=term)


class TestTracerUnit:
    def test_incident_lifecycle_and_ttm(self):
        tr = Tracer(fault_start=1.0, fault_row="tp_straggler")
        tr.on_finding(_finding(ts=1.5), "primary")
        assert len(tr.incidents) == 1 and tr.current is tr.incidents[0]
        tr.on_attribution(_attribution(ts=1.5), "primary")
        cmd = _cmd(ts=2.0)
        tr.on_command(cmd, "primary")
        tr.on_bus("send", cmd, 2.0, "primary")
        tr.on_bus("deliver", cmd, 2.002, "primary")
        tr.on_apply("rebalance_tp", 2, 2.002, True, True)
        inc = tr.incidents[0]
        assert inc.closed and tr.current is None
        assert inc.recover_cmd_id == 1
        ttm = inc.ttm()
        assert ttm["t_detect"] == pytest.approx(0.5)
        assert ttm["t_attribute"] == pytest.approx(0.0)
        assert ttm["t_decide"] == pytest.approx(0.5)
        assert ttm["t_bus_rtt"] == pytest.approx(0.002)
        assert ttm["t_apply"] == pytest.approx(0.0)
        total = sum(v for k, v in ttm.items() if k != "t_recover")
        assert total == pytest.approx(ttm["t_recover"])
        assert validate_report(inc.to_report()) == []

    def test_busless_path_reports_zero_bus_rtt(self):
        # instant / degraded-fallback paths never touch the bus: decided
        # telescopes to applied and t_bus_rtt must be exactly 0 — this is
        # the hot-vs-degraded attribution signal
        tr = Tracer(fault_start=1.0, fault_row="x")
        tr.on_finding(_finding(ts=1.4), "plane")
        tr.on_apply("rebalance_tp", 2, 1.6, True, True)
        ttm = tr.incidents[0].ttm()
        assert ttm["t_bus_rtt"] == 0.0
        assert ttm["t_decide"] == pytest.approx(0.2)
        total = sum(v for k, v in ttm.items() if k != "t_recover")
        assert total == pytest.approx(ttm["t_recover"])

    def test_liveness_pings_are_not_causal_traffic(self):
        tr = Tracer(fault_start=1.0, fault_row="x")
        tr.on_finding(_finding(ts=1.4), "primary")
        tr.on_bus("deliver", _cmd(cmd_id=-3), 1.5, "primary")
        assert tr.counters["bus_deliver"] == 0
        assert all(e.phase != "bus" for e in tr.incidents[0].events)

    def test_event_cap_counts_overflow(self):
        tr = Tracer(fault_start=0.0, fault_row="x")
        for i in range(MAX_EVENTS_PER_INCIDENT + 10):
            tr.on_finding(_finding(ts=float(i)), "plane")
        inc = tr.incidents[0]
        assert len(inc.events) == MAX_EVENTS_PER_INCIDENT
        assert inc.dropped_events == 10
        assert validate_report(inc.to_report()) == []

    def test_transitions_without_incident_land_in_orphans(self):
        tr = Tracer()
        tr.on_transition("dpu_crash", 1.0, "primary", lost_rows=4)
        assert not tr.incidents
        assert tr.orphan_events[0].name == "dpu_crash"
        assert tr.counters["crashes"] == 1

    def test_validate_report_rejects_malformed(self):
        assert validate_report([]) == ["report is not a dict"]
        assert any("missing key" in e for e in validate_report({}))
        tr = Tracer(fault_start=1.0, fault_row="x")
        tr.on_finding(_finding(ts=1.4), "plane")
        tr.on_apply("rebalance_tp", 2, 1.6, True, True)
        rep = tr.incidents[0].to_report()
        rep["ttm"]["t_recover"] = 99.0  # phases no longer sum
        assert any("sum" in e for e in validate_report(rep))
        open_rep = Incident("inc-000", "x", 1.0, 0.0, "x").to_report()
        open_rep["ttm"]["t_recover"] = 1.0  # recover set, phases missing
        assert any("missing" in e for e in validate_report(open_rep))


class TestFlightRecorder:
    def _batch(self, ts0, n=4):
        import numpy as np

        from repro.core.events import BATCH_COLUMNS, EventBatch
        cols = {c: np.zeros(n, dtype=np.int64) for c in BATCH_COLUMNS}
        cols["ts"] = ts0 + np.arange(n) * 0.001
        return EventBatch(*(cols[c] for c in BATCH_COLUMNS))

    def test_ring_is_bounded_and_snapshot_is_plain_data(self):
        rec = FlightRecorder(max_frames=4)
        for i in range(10):
            rec.on_batch(float(i), self._batch(float(i)))
        assert rec.occupancy() == 4
        assert rec.frames_seen == 10
        snap = rec.snapshot(10.0)
        assert snap["freeze_ts"] == 10.0
        assert len(snap["frames"]) == 4
        # snapshot must be json-serializable (ships inside the report)
        json.dumps(snap)

    def test_window_span_tracks_payload_time(self):
        rec = FlightRecorder(max_frames=8)
        rec.on_batch(1.0, self._batch(1.0))
        rec.on_batch(2.0, self._batch(2.0))
        assert rec.window_span() == pytest.approx(1.003)


class TestMetrics:
    def test_prometheus_text_format(self):
        reg = MetricsRegistry()
        reg.counter("repro_x_total", "help text").inc(3, row="a")
        reg.gauge("repro_g").set(1.5)
        reg.histogram("repro_h", buckets=(1.0, 2.0)).observe(1.5)
        text = reg.render()
        assert "# TYPE repro_x_total counter" in text
        assert 'repro_x_total{row="a"} 3' in text
        assert "repro_g 1.5" in text
        assert 'repro_h_bucket{le="+Inf"} 1' in text
        assert "repro_h_count 1" in text

    def test_collect_metrics_from_tracer(self):
        tr = Tracer(fault_start=1.0, fault_row="x")
        tr.on_finding(_finding(ts=1.5), "primary")
        tr.on_apply("rebalance_tp", 2, 2.0, True, True)
        text = collect_metrics(tracer=tr).render()
        assert 'repro_findings_total{row="tp_straggler"} 1' in text
        assert "repro_incidents_total 1" in text
        assert 'repro_ttm_seconds_count{phase="t_recover"} 1' in text


class TestTraceE2E:
    def test_incident_closes_and_phases_sum_to_t_recover(self):
        sc = SCENARIOS["tp_straggler"].variant(seed=0)
        params = dataclasses.replace(
            sc.params, duration=sc.params.duration + 1.0, control="dpu",
            trace=True)
        m, plane, sim = run_scenario(dataclasses.replace(sc.fault), params,
                                     sc.workload, mitigate=True)
        assert sim.fault.mitigated
        inc = sim.tracer.incidents[0]
        assert inc.closed
        rep = inc.to_report()
        assert validate_report(rep) == []
        ttm = rep["ttm"]
        total = sum(ttm[k] for k in ("t_detect", "t_attribute", "t_decide",
                                     "t_bus_rtt", "t_apply"))
        t_recover = m.mitigated_ts - sc.fault.start
        # the phases telescope: sum is exact up to export rounding, and
        # in any case within one detector poll of the scalar metric
        assert abs(total - t_recover) < 0.25
        assert ttm["t_bus_rtt"] > 0.0  # dpu path pays the modeled bus
        from repro.core.export import render_incident
        md = render_incident(rep)
        assert "TTM decomposition" in md and inc.incident_id in md

    def test_trace_context_survives_failover_and_promotion(self):
        # chaos Part-B hot shape: fault + mid-incident primary crash with
        # a hot standby under the watchdog.  The incident opened by the
        # primary's first finding must stay THE incident across the
        # promotion — same trace context, recovery attached to it.
        # (tp_straggler detects at fault.start+0.7 and dwells ~1s before
        # deciding, so a crash at +0.9 lands inside the open incident.)
        sc = SCENARIOS["tp_straggler"].variant(seed=0)
        fault = dataclasses.replace(sc.fault,
                                    dpu_crash_at=sc.fault.start + 0.9,
                                    dpu_restart_after=0.4)
        params = dataclasses.replace(
            sc.params, duration=sc.params.duration + 2.0, control="dpu",
            standby=DPUParams(), watchdog=WatchdogParams(), trace=True)
        m, plane, sim = run_scenario(fault, params, sc.workload,
                                     mitigate=True)
        assert sim.fault.mitigated
        tr = sim.tracer
        inc = tr.incidents[0]
        assert inc.incident_id == "inc-000" and inc.closed
        assert tr.counters["promotions"] >= 1
        names = {e.name for e in inc.events}
        assert "promote_standby" in names  # transition attached in-span
        sources = {e.source for e in inc.events}
        assert "standby" in sources        # post-promotion causal events
        assert validate_report(inc.to_report()) == []

    def test_healthy_traced_run_opens_no_incident(self):
        sc = SCENARIOS["healthy"].variant(seed=0)
        params = dataclasses.replace(sc.params, control="dpu", trace=True)
        m, plane, sim = run_scenario(dataclasses.replace(sc.fault), params,
                                     sc.workload, mitigate=True)
        assert sim.tracer.incidents == []
        assert sim.tracer.counters["findings"] == 0

    def test_watchdog_surfaces_retained_tap_window(self):
        # satellite: remirror decisions are observable — the retained-tap
        # ring's occupancy/age ride the watchdog report and the
        # META_MON_RETAIN self-telemetry row
        from repro.core.detectors import META_MON_RETAIN
        assert META_MON_RETAIN == 12
        sc = SCENARIOS["tp_straggler"].variant(seed=0)
        params = dataclasses.replace(
            sc.params, control="dpu", watchdog=WatchdogParams(), trace=True)
        m, plane, sim = run_scenario(dataclasses.replace(sc.fault), params,
                                     sc.workload, mitigate=True)
        wd = plane.report()["watchdog"]
        for key in ("retained_batches", "retained_span_s",
                    "retain_evictions"):
            assert key in wd
        text = collect_metrics(tracer=sim.tracer, watchdog=plane).render()
        assert 'repro_watchdog{field="retained_batches"}' in text


class TestTracedSweep:
    def test_traced_cells_carry_exactly_one_incident_per_fault(self):
        report = run_sweep(SweepConfig(
            scenarios=("healthy", "tp_straggler"), seeds=(0,), workers=1,
            trace=True))
        assert report.incident_problems() == []
        by_name = {r.scenario: r for r in report.results}
        assert len(by_name["tp_straggler"].incidents) == 1
        assert by_name["healthy"].incidents == []
        # reports are plain data all the way down (cross-process safe)
        json.dumps(by_name["tp_straggler"].incidents)


@pytest.mark.slow
class TestGoldenParityGuard:
    """Tracing is observe-only: a traced run reproduces the committed
    (untraced) golden findings bit-for-bit, for every registry scenario."""

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_traced_findings_match_golden(self, name):
        sc = SCENARIOS[name].variant(scalar_synth=True)
        params = dataclasses.replace(sc.params, trace=True)
        m, plane, sim = run_scenario(sc.fault, params, sc.workload)
        got = [[f.name, f.node, f.ts, f.severity, f.score]
               for f in plane.findings]
        assert got == GOLDEN[name]["findings"], (
            f"{name}: tracing perturbed findings — the observe-only "
            "contract is broken")
