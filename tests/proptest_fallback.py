"""Seeded-random fallback for ``hypothesis`` so tier-1 runs from a clean
checkout.

The property-based suites (`test_sketches`, `test_plane_fuzz`, parts of
`test_serving_training`) use a small, fixed subset of the hypothesis API:
``given``, ``settings``, and the strategies ``floats / integers / booleans /
lists / tuples / sampled_from / builds``.  When hypothesis is installed
(see requirements-dev.txt) the real library runs with full shrinking and
example databases; when it is not, this module provides drop-in stand-ins
that draw a fixed number of seeded pseudo-random examples per test — far
weaker than hypothesis, but the properties still execute and regressions in
the happy path still fail loudly.

Usage (in test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                      # pragma: no cover
        from proptest_fallback import given, settings, st
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

FALLBACK_EXAMPLES = 25      # examples per @given test when hypothesis absent


class Strategy:
    """Minimal strategy: something that can draw one example from an RNG."""

    def example(self, rng: random.Random):  # pragma: no cover - interface
        raise NotImplementedError


class _Floats(Strategy):
    def __init__(self, lo: float, hi: float) -> None:
        self.lo, self.hi = lo, hi

    def example(self, rng):
        # mix uniform draws with boundary values — property bugs live at
        # the edges, and plain uniform sampling would never visit them
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        if r < 0.20:
            return rng.uniform(-1.0, 1.0) if self.lo < 0 <= self.hi \
                else self.lo + (self.hi - self.lo) * 1e-6
        return rng.uniform(self.lo, self.hi)


class _Integers(Strategy):
    def __init__(self, lo: int, hi: int) -> None:
        self.lo, self.hi = lo, hi

    def example(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Booleans(Strategy):
    def example(self, rng):
        return rng.random() < 0.5


class _Lists(Strategy):
    def __init__(self, elem: Strategy, min_size: int, max_size: int) -> None:
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng) for _ in range(n)]


class _Tuples(Strategy):
    def __init__(self, *elems: Strategy) -> None:
        self.elems = elems

    def example(self, rng):
        return tuple(e.example(rng) for e in self.elems)


class _SampledFrom(Strategy):
    def __init__(self, options) -> None:
        self.options = list(options)

    def example(self, rng):
        return rng.choice(self.options)


class _Builds(Strategy):
    def __init__(self, target, **kwargs: Strategy) -> None:
        self.target, self.kwargs = target, kwargs

    def example(self, rng):
        return self.target(
            **{k: v.example(rng) for k, v in self.kwargs.items()})


class _StrategiesNamespace:
    """Mirrors the ``hypothesis.strategies`` names the tests use."""

    @staticmethod
    def floats(min_value=-1e9, max_value=1e9, allow_nan=False,
               allow_infinity=False):
        return _Floats(float(min_value), float(max_value))

    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return _Integers(int(min_value), int(max_value))

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def lists(elem, min_size=0, max_size=16):
        return _Lists(elem, min_size, max_size)

    @staticmethod
    def tuples(*elems):
        return _Tuples(*elems)

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)

    @staticmethod
    def builds(target, **kwargs):
        return _Builds(target, **kwargs)


st = _StrategiesNamespace()


def given(*strategies: Strategy):
    """Run the wrapped test FALLBACK_EXAMPLES times with seeded draws.

    The seed derives from the test's qualified name, so failures reproduce
    deterministically run-to-run and test-to-test independence holds.
    """

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(FALLBACK_EXAMPLES):
                vals = [s.example(rng) for s in strategies]
                try:
                    fn(*args, *vals, **kwargs)
                except AssertionError as e:  # noqa: PERF203
                    raise AssertionError(
                        f"falsified on example {i} (seed={seed}): "
                        f"{vals!r}") from e

        # hide the strategy-bound trailing parameters from pytest, which
        # would otherwise look for fixtures named after them
        sig = inspect.signature(fn)
        kept = list(sig.parameters.values())
        kept = kept[:len(kept) - len(strategies)]
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper
    return deco


def settings(**_kwargs):
    """No-op stand-in for hypothesis.settings decorators."""

    def deco(fn):
        return fn
    return deco
