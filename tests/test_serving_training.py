"""Integration tests: serving engine end-to-end, paged pool invariants
(property-based), trainer fault tolerance, data pipeline, collectives."""

import dataclasses
import random
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # clean checkout: seeded-random fallback
    from proptest_fallback import given, settings, st

from repro.configs import ARCHS
from repro.data import (DataConfig, Prefetcher, SyntheticCorpus,
                        length_buckets, pack_documents, padding_waste)
from repro.models import build_model
from repro.parallel.collectives import accumulate_grads, init_error_buf
from repro.serving import (EngineConfig, InferenceEngine, PagedKVPool,
                           ServeRequest)
from repro.training import TrainConfig, Trainer


# ----------------------------------------------------------------------
# paged KV pool — property-based invariants
# ----------------------------------------------------------------------

class TestPagedPool:
    @given(st.lists(st.tuples(st.integers(1, 200), st.booleans()),
                    min_size=1, max_size=60))
    @settings(max_examples=50, deadline=None)
    def test_no_page_leak_or_double_alloc(self, ops_):
        pool = PagedKVPool(n_pages=64, page_size=16)
        live = {}
        for i, (tokens, do_free) in enumerate(ops_):
            pages = pool.allocate(i, tokens)
            if pages is not None:
                assert len(set(pages)) == len(pages)
                for p in pages:
                    for other in live.values():
                        assert p not in other, "double allocation"
                live[i] = list(pages)
            if do_free and live:
                victim = next(iter(live))
                pool.free(victim)
                del live[victim]
        used = sum(len(v) for v in live.values())
        assert pool.stats.free_pages == 64 - used
        for sid in list(live):
            pool.free(sid)
        assert pool.stats.free_pages == 64

    def test_extend_allocates_on_boundary(self):
        pool = PagedKVPool(8, page_size=4)
        pool.allocate(0, 4)
        assert len(pool.table(0)) == 1
        assert pool.extend(0, 1)
        assert len(pool.table(0)) == 2

    def test_eviction_relieves_pressure(self):
        pool = PagedKVPool(4, page_size=4)
        pool.allocate(0, 8)
        pool.allocate(1, 8)
        assert not pool.can_admit(4)
        assert pool.evict_lru() in (0, 1)
        assert pool.can_admit(4)


# ----------------------------------------------------------------------
# serving engine end-to-end
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_engine_parts():
    cfg = ARCHS["llama3.2-3b"].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    return cfg, m, params


class TestInferenceEngine:
    def test_completes_all_requests(self, small_engine_parts):
        cfg, m, params = small_engine_parts
        eng = InferenceEngine(m, params, EngineConfig(
            max_slots=4, max_seq=128, n_pages=64, page_size=16))
        rng = random.Random(0)
        reqs = [ServeRequest(req_id=i, arrival=i * 0.004,
                             prompt=[rng.randrange(cfg.vocab)
                                     for _ in range(rng.randrange(8, 40))],
                             max_new_tokens=rng.randrange(4, 16))
                for i in range(10)]
        rep = eng.run(reqs, max_steps=400)
        assert rep["completed"] == 10
        assert rep["tokens"] == sum(r.max_new_tokens for r in reqs)
        assert rep["p50_latency"] < 1.0
        assert rep["telemetry"]["events"] > 100

    def test_continuous_beats_static_batching(self, small_engine_parts):
        """The paper's early-completion pathology, live on the real engine."""
        cfg, m, params = small_engine_parts
        rng = random.Random(1)

        def mk():
            return [ServeRequest(
                req_id=i, arrival=0.0,
                prompt=[rng.randrange(cfg.vocab) for _ in range(8)],
                max_new_tokens=(40 if i % 4 == 0 else 4))
                for i in range(12)]

        res = {}
        for mode in (True, False):
            eng = InferenceEngine(m, params, EngineConfig(
                max_slots=4, max_seq=128, n_pages=256, page_size=16,
                telemetry=False))
            eng.sched.set_continuous(mode)
            res[mode] = eng.run(mk(), max_steps=600)
        assert res[True]["steps"] < res[False]["steps"]
        assert res[True]["tokens_per_step"] > res[False]["tokens_per_step"]

    def test_mitigation_surface(self, small_engine_parts):
        cfg, m, params = small_engine_parts
        eng = InferenceEngine(m, params, EngineConfig(
            max_slots=2, max_seq=64, telemetry=False))
        assert eng.apply_action("inflight_remap", 0, {})
        assert eng.sched.cfg.continuous
        assert eng.apply_action("compress_kv", 0, {})
        assert eng.kv_compress
        assert eng.apply_action("admission_control", 0, {})
        assert eng.apply_action("throttle_telemetry", 0, {})
        assert eng.telemetry_stride == 2

    def test_dpu_control_mode_serves_through_sidecar(self,
                                                     small_engine_parts):
        """control="dpu": engine telemetry crosses the modeled transport,
        detection runs on the sidecar's inner plane, and the loop's
        actuator is the engine itself."""
        cfg, m, params = small_engine_parts
        eng = InferenceEngine(m, params, EngineConfig(
            max_slots=4, max_seq=128, n_pages=64, page_size=16,
            control="dpu"))
        assert eng.dpu is not None
        assert eng.plane.controller is None     # policy engine owns the loop
        assert eng.dpu.bus.engine is eng
        rng = random.Random(2)
        reqs = [ServeRequest(req_id=i, arrival=i * 0.004,
                             prompt=[rng.randrange(cfg.vocab)
                                     for _ in range(12)],
                             max_new_tokens=6) for i in range(8)]
        rep = eng.run(reqs, max_steps=400)
        assert rep["completed"] == 8
        # the delayed tap still delivered the whole trace to the detectors
        assert eng.dpu.uplink.dropped == 0
        assert rep["telemetry"]["events"] > 0
        assert eng.dpu.budget.events_shed == 0


# ----------------------------------------------------------------------
# trainer: fault tolerance + compression
# ----------------------------------------------------------------------

class TestTrainer:
    def test_crash_restart_resumes_and_trains(self):
        cfg = ARCHS["qwen3-0.6b"].reduced()
        m = build_model(cfg)
        dc = DataConfig(vocab=cfg.vocab, seq_len=32, batch=4, seed=1)
        with tempfile.TemporaryDirectory() as d:
            tcfg = TrainConfig(steps=6, n_micro=2, ckpt_dir=d, ckpt_every=2)
            tr = Trainer(m, m.init(jax.random.key(0)), tcfg)
            with pytest.raises(RuntimeError):
                tr.run(pack_documents(SyntheticCorpus(dc), 20), crash_at=3)
            tr2 = Trainer(m, m.init(jax.random.key(9)),
                          TrainConfig(steps=6, n_micro=2, ckpt_dir=d,
                                      ckpt_every=2))
            assert tr2.maybe_restore()
            assert tr2.step >= 2
            hist = tr2.run(pack_documents(SyntheticCorpus(dc), 20))
            assert tr2.step == 6
            assert all(np.isfinite(h["loss"]) for h in hist)

    def test_compressed_grads_close_to_exact(self):
        cfg = ARCHS["xlstm-125m"].reduced()
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        mb = jax.tree.map(lambda x: x.reshape(2, 2, *x.shape[1:]), batch)

        def loss(p, b):
            return m.loss(p, b)

        _, g_exact, _ = accumulate_grads(loss, params, mb, compress=False)
        _, g_comp, ebuf = accumulate_grads(loss, params, mb, compress=True,
                                           error_buf=init_error_buf(params))
        rel = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))
                               / (jnp.max(jnp.abs(a)) + 1e-9)),
            g_exact, g_comp)
        assert max(jax.tree.leaves(rel)) < 0.05
        # error feedback buffer holds the rounding residual
        assert any(float(jnp.max(jnp.abs(e))) > 0
                   for e in jax.tree.leaves(ebuf))


# ----------------------------------------------------------------------
# checkpoint atomicity
# ----------------------------------------------------------------------

class TestCheckpoint:
    def test_roundtrip_and_gc(self):
        from repro.training import checkpoint as ckpt
        tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
                "b": {"c": np.ones((4,), np.int32)}}
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 3, 4, 5):
                ckpt.save(d, s, tree, keep=2)
            assert ckpt.latest_step(d) == 5
            back = ckpt.restore(d, 5, tree)
            np.testing.assert_array_equal(back["a"], tree["a"])
            np.testing.assert_array_equal(back["b"]["c"], tree["b"]["c"])
            import os
            kept = [x for x in os.listdir(d) if x.startswith("step_")]
            assert len(kept) == 2   # GC keeps newest K


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------

class TestData:
    def test_packing_shapes_and_determinism(self):
        dc = DataConfig(vocab=1000, seq_len=64, batch=4, seed=7)
        b1 = list(pack_documents(SyntheticCorpus(dc), 3))
        b2 = list(pack_documents(SyntheticCorpus(dc), 3))
        for x, y in zip(b1, b2):
            assert x["tokens"].shape == (4, 64)
            np.testing.assert_array_equal(x["tokens"], y["tokens"])
            # labels are next-token shifted
        dc2 = dataclasses.replace(dc, seed=8)
        b3 = next(iter(pack_documents(SyntheticCorpus(dc2), 1)))
        assert not np.array_equal(b1[0]["tokens"], b3["tokens"])

    @given(st.lists(st.integers(1, 2048), min_size=1, max_size=100))
    @settings(max_examples=30, deadline=None)
    def test_bucketing_reduces_padding_waste(self, lengths):
        w_b = padding_waste(lengths, bucketed=True)
        w_n = padding_waste(lengths, bucketed=False)
        assert 0.0 <= w_b <= 1.0
        assert w_b <= w_n + 1e-9

    def test_prefetcher_preserves_order(self):
        items = list(range(20))
        assert list(Prefetcher(iter(items), depth=3)) == items
