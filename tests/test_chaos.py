"""Monitoring-plane chaos tests (repro.dpu robustness layer).

Covers the chaos-capable transport (partition windows, corruption,
duplication — and the zero-RNG contract that keeps every pre-existing
golden bit-identical), the wire framing (batch_seq / content checksums),
the ingest guard (gaps, replays, corruption, the latched dirty flag), the
command bus's exponential backoff and retry exhaustion, the policy engine's
actuation quarantine, DPU crash/restart semantics (ring loss, detector
reset, post-restart quarantine), post-blackout backlog floods against the
ingest budget, and the host-side watchdog's failover/failback state
machine with its degraded-mode controller.
"""

import numpy as np
import pytest

from repro.core.attribution import Attribution
from repro.core.detectors import META_TAP_DEBUG, Finding
from repro.core.events import EventBatchBuilder, EventKind
from repro.core.telemetry import TelemetryPlane
from repro.dpu import (
    PING_ACTION,
    CommandBus,
    DPUBudget,
    DPUParams,
    DPUSidecar,
    IngestGuard,
    LinkParams,
    ModeledLink,
    PolicyEngine,
    Watchdog,
    WatchdogParams,
)
from repro.dpu.policy import Command


def _finding(name="tp_straggler", ts=1.0, node=1, severity="warn",
             score=5.0):
    return Finding(name=name, table="3c", ts=ts, severity=severity,
                   node=node, device=-1, stage="s", root_cause="r",
                   directive="d", score=score)


def _att(name="tp_straggler", ts=1.0, node=1, severity="warn",
         confidence=0.9, score=5.0, locus="device_scheduling"):
    return Attribution(ts=ts, locus=locus, node=node, confidence=confidence,
                       primary=_finding(name, ts, node, severity, score),
                       supporting=(), narrative="n")


def _batch(n, ts0=0.0, kind=EventKind.QUEUE_SAMPLE, meta=META_TAP_DEBUG):
    b = EventBatchBuilder()
    for i in range(n):
        b.add(ts0 + i * 1e-5, int(kind), i % 4, meta=meta)
    return b.build(sort=True)


def _cmd(cmd_id=1, ts=0.0, action="tune_transport", node=1):
    return Command(cmd_id=cmd_id, ts=ts, action=action, node=node,
                   row_id="r", locus="l")


class TestPartitionWindow:
    def test_drops_exactly_inside_window(self):
        link = ModeledLink(LinkParams(delay=1e-3, partition_start=1.0,
                                      partition_duration=0.5),
                           np.random.default_rng(0))
        assert link.send(0.5, "before")
        assert not link.send(1.0, "at-start")       # window is closed-open
        assert not link.send(1.499, "inside")
        assert link.send(1.5, "at-end")
        assert link.partition_dropped == 2
        assert link.dropped == 2
        got = link.deliver(2.0)
        assert got == ["before", "at-end"]

    def test_inactive_window_consumes_no_randomness(self):
        # satellite 2 regression: a configured-but-inactive partition window
        # (and the corrupt/duplicate knobs at zero) must draw nothing from
        # the generator — the golden contract for every pre-existing
        # scenario is "zero knobs => zero draws", and the partition window
        # is pure clock comparison even when it fires
        rng = np.random.default_rng(7)
        before = rng.bit_generator.state
        link = ModeledLink(LinkParams(delay=1e-3, partition_start=5.0,
                                      partition_duration=1.0), rng)
        for i in range(50):
            link.send(i * 1e-3, i)                  # all before the window
        for i in range(10):
            link.send(5.1 + i * 1e-3, i)            # all inside: dropped
        link.deliver(10.0)
        assert rng.bit_generator.state == before
        assert link.partition_dropped == 10


class TestCorruptionAndDuplication:
    def test_corruptor_applied_per_coin(self):
        link = ModeledLink(LinkParams(delay=1e-3, corrupt_p=1.0),
                           np.random.default_rng(0),
                           corruptor=lambda p: ("rot", p))
        link.send(0.0, "x")
        assert link.deliver(1.0) == [("rot", "x")]
        assert link.corrupted == 1

    def test_duplicate_delivers_replay_later(self):
        link = ModeledLink(LinkParams(delay=1e-3, duplicate_p=1.0),
                           np.random.default_rng(0))
        link.send(0.0, "x")
        assert link.deliver(1e-3) == ["x"]
        assert link.deliver(1.0) == ["x"]           # replay: one delay later
        assert link.duplicated == 1


class TestWireFraming:
    def test_content_checksum_is_stable_and_sensitive(self):
        b = _batch(20)
        assert b.content_checksum() == _batch(20).content_checksum()
        rotted = _batch(20)
        rotted.size[3] ^= 0x5A5A
        assert rotted.content_checksum() != b.content_checksum()

    def test_guard_detects_gap_replay_and_corruption(self):
        g = IngestGuard()
        b1, b2, b4 = _batch(5), _batch(5), _batch(5)
        b1.batch_seq, b2.batch_seq, b4.batch_seq = 1, 2, 4
        assert g.admit(b1) and g.admit(b2)
        assert not g.dirty
        assert g.admit(b4)                          # gap: admitted, latched
        assert g.dirty and g.fresh_gap
        assert g.gaps == 1 and g.missing == 1
        assert not g.admit(b2)                      # replay: dropped
        assert g.replays == 1
        bad = _batch(5)
        bad.batch_seq = 5
        bad.checksum = bad.content_checksum()
        bad.size[0] ^= 1
        assert not g.admit(bad)                     # corrupt: dropped
        assert g.corrupt == 1
        g.resync()
        assert not g.dirty and not g.fresh_gap
        assert g.gaps == 1                          # history survives resync

    def test_unstamped_batches_pass(self):
        g = IngestGuard()
        assert g.admit(_batch(5))                   # batch_seq == -1
        assert g.last_seq == -1 and not g.dirty


class TestCommandBusBackoff:
    def test_backoff_schedule_doubles_and_caps(self):
        bus = CommandBus(None, np.random.default_rng(0),
                         ack_timeout=10e-3, ack_backoff=2.0,
                         ack_timeout_cap=0.25)
        assert bus.backoff_delay(1) == pytest.approx(10e-3)
        assert bus.backoff_delay(2) == pytest.approx(20e-3)
        assert bus.backoff_delay(3) == pytest.approx(40e-3)
        assert bus.backoff_delay(10) == 0.25        # capped

    def test_exhaustion_counts_and_fires_callback(self):
        # a fully dark downlink: every attempt is dropped, retries back off
        # 10 -> 20 ms, then the third attempt exhausts the budget
        expired = []
        bus = CommandBus(None, np.random.default_rng(0),
                         down=LinkParams(delay=1e-3, drop_p=1.0),
                         ack_timeout=10e-3, max_retries=3, stale_after=5.0,
                         on_expired=lambda c, ex: expired.append((c, ex)))
        bus.send(_cmd(ts=0.0), 0.0)
        t, resend_times = 0.0, []
        while t < 0.2:
            before = bus.stats.retries
            bus.advance(t)
            if bus.stats.retries > before:
                resend_times.append(round(t, 3))
            t += 1e-3
        assert resend_times == [0.01, 0.03]         # 10 ms then +20 ms
        assert bus.stats.exhausted == 1
        assert bus.stats.expired == 1
        assert len(expired) == 1 and expired[0][1] is True
        assert not bus._outstanding

    def test_stale_expiry_is_not_exhaustion(self):
        bus = CommandBus(None, np.random.default_rng(0),
                         down=LinkParams(delay=1e-3, drop_p=1.0),
                         ack_timeout=10e-3, max_retries=10, stale_after=0.02)
        bus.send(_cmd(ts=0.0), 0.0)
        for t in (0.01, 0.03, 0.05):
            bus.advance(t)
        assert bus.stats.expired == 1
        assert bus.stats.exhausted == 0             # staleness, not retries

    def test_ping_acks_without_actuating(self):
        bus = CommandBus(None, np.random.default_rng(0),
                         down=LinkParams(delay=1e-3))
        bus.send(_cmd(cmd_id=-1, action=PING_ACTION, node=-1), 0.0)
        for t in (1e-3, 2e-3, 3e-3):
            bus.advance(t)
        assert bus.stats.acked == 1
        assert bus.stats.applied == 0
        assert bus.log == []

    def test_drop_outstanding_forgets_without_accounting(self):
        bus = CommandBus(None, np.random.default_rng(0),
                         down=LinkParams(delay=1e-3, drop_p=1.0))
        bus.send(_cmd(cmd_id=1), 0.0)
        bus.send(_cmd(cmd_id=2, node=2), 0.0)
        assert bus.drop_outstanding() == 2
        bus.advance(1.0)
        assert bus.stats.expired == 0 and bus.stats.exhausted == 0


class TestPolicyQuarantine:
    def _engine(self):
        return PolicyEngine(min_confidence=0.5, confirmations=1,
                            cooldown=0.1)

    def test_quarantine_suppresses_and_expires(self):
        pe = self._engine()
        pe.quarantine(2.0)
        pe.observe(_att(ts=1.0))
        assert pe.decide(1.0) == []
        assert pe.quarantined == 1
        assert any(s[0] == "quarantine" for s in pe.suppressed)
        # staged state was cleared: the pre-quarantine sighting is gone and
        # a fresh post-quarantine attribution re-confirms from zero
        pe.observe(_att(ts=2.5))
        cmds = pe.decide(2.5)
        assert len(cmds) == 1

    def test_quarantine_only_extends(self):
        pe = self._engine()
        pe.quarantine(3.0)
        pe.quarantine(2.0)                          # earlier: ignored
        assert pe.quarantine_until == 3.0

    def test_no_double_trigger_during_quarantine(self):
        # satellite 3: a dpu_saturation attribution arriving while the
        # post-blackout quarantine holds must not actuate — and must not
        # leave half-confirmed state that actuates the instant the window
        # closes without fresh evidence
        pe = self._engine()
        pe.quarantine(2.0)
        pe.observe(_att(name="dpu_saturation", ts=1.5, node=-1,
                        locus="telemetry_plane"))
        assert pe.decide(1.5) == []
        assert pe.decide(2.1) == []                 # no stale carryover
        assert pe.quarantined == 1

    def test_expired_callback_clears_cooldown(self):
        pe = PolicyEngine(min_confidence=0.5, confirmations=1, cooldown=10.0)
        pe.observe(_att(ts=1.0))
        cmds = pe.decide(1.0)
        assert len(cmds) == 1
        # without the callback, the cooldown blocks re-issue for 10 s
        pe.observe(_att(ts=1.2))
        assert pe.decide(1.2) == []
        pe.on_expired(cmds[0], True)                # bus gave up on it
        pe.observe(_att(ts=1.4))
        assert len(pe.decide(1.4)) == 1


class TestBudgetCrashAndFlood:
    def test_crash_loses_ring_and_resets_drain_clock(self):
        budget = DPUBudget(events_per_s=1000.0, ring_events=1000)
        budget.offer(_batch(100))
        budget.drain(0.0)
        lost = budget.crash()
        assert lost == 100
        assert budget.backlog == 0
        assert budget.events_shed == 100            # lost rows are shed rows
        # the drain clock reset: no phantom credit accrues across dead time
        budget.offer(_batch(100, ts0=1.0))
        assert budget.drain(5.0) == []              # anchor, not 5 s credit
        out = budget.drain(5.010)
        # ~10 ms of credit at 1000 rows/s (float credit may floor to 9)
        assert sum(len(b) for b in out) in (9, 10)

    def test_post_blackout_flood_sheds_fifo(self):
        # satellite 3: when a blackout lifts, the uplink delivers the
        # backlog in one burst; the ring must absorb up to capacity and
        # shed the overflow tail with exact accounting
        budget = DPUBudget(events_per_s=1e5, ring_events=200)
        shed = budget.offer(_batch(500, ts0=1.0))
        assert shed == 300
        assert budget.backlog == 200
        assert budget.events_offered == 500
        assert budget.events_accepted == 200
        assert budget.events_shed == 300
        # FIFO: what survived is the oldest prefix of the flood
        rows = [t for b in [*budget.drain(2.0), *budget.drain(3.0)]
                for t in b.ts.tolist()]
        assert rows == sorted(rows)
        assert len(rows) == 200
        assert rows[0] == pytest.approx(1.0)


def _drive(side, until, dt=2e-3, rate_per_step=4, start=0.0):
    """Feed a steady healthy tap and pump the sidecar/watchdog loop."""
    t = start
    while t < until:
        b = EventBatchBuilder()
        for i in range(rate_per_step):
            b.add(t + i * 1e-5, int(EventKind.QUEUE_SAMPLE), i % 4,
                  meta=META_TAP_DEBUG)
        side.observe_batch(b.build(sort=True))
        side.advance(t)
        t += dt
    return t


class TestSidecarCrashRestart:
    def _mk(self, **dpu_kw):
        plane = TelemetryPlane(n_nodes=4, mitigate=False)
        side = DPUSidecar(plane, DPUParams(**dpu_kw), mitigate=False)
        return plane, side

    def test_crash_freezes_heartbeat_and_drops_frames(self):
        _, side = self._mk(crash_at=0.5)            # no restart: stays down
        _drive(side, 1.0)
        assert side.crashed
        assert side.heartbeat_ts < 0.5
        assert side.crash_dropped > 0
        assert side.budget.backlog == 0             # ring died with it

    def test_restart_rejoins_with_sequence_gap(self):
        _, side = self._mk(crash_at=0.5, restart_after=0.2)
        _drive(side, 1.2)
        assert not side.crashed
        assert side.restarts == 1
        assert side.guard.gaps >= 1                 # rejoined mid-stream
        assert side.guard.dirty                     # latched until resync
        assert side.heartbeat_ts >= 1.19            # alive again
        side.resync(1.2)
        assert not side.guard.dirty

    def test_crash_resets_detector_state_not_logs(self):
        plane, side = self._mk(crash_at=0.5, restart_after=0.2)
        plane.findings.append("sentinel")           # the experiment record
        _drive(side, 0.6)
        assert plane.findings[0] == "sentinel"


class TestWatchdogStateMachine:
    def _mk(self, wd_kw=None, **dpu_kw):
        plane = TelemetryPlane(n_nodes=4, mitigate=False)
        side = DPUSidecar(plane, DPUParams(**dpu_kw), mitigate=False)
        wd = Watchdog(side, WatchdogParams(**(wd_kw or {})), mitigate=False)
        return plane, side, wd

    def test_failover_on_silence_then_hysteretic_failback(self):
        _, side, wd = self._mk(crash_at=0.5, restart_after=0.3)
        _drive(wd, 0.5)
        assert wd.state == Watchdog.NORMAL and wd.failovers == 0
        _drive(wd, 0.7, start=0.5)
        assert wd.state == Watchdog.FALLBACK        # silence > 80 ms
        assert wd.failovers == 1
        # DPU back at 0.8; failback only after 200 ms of continuous health
        _drive(wd, 0.95, start=0.7)
        assert wd.state == Watchdog.FALLBACK
        _drive(wd, 1.2, start=0.95)
        assert wd.state == Watchdog.NORMAL
        assert wd.failbacks == 1

    def test_standby_detects_outage_while_dpu_dark(self):
        _, side, wd = self._mk(crash_at=0.5)        # never restarts
        _drive(wd, 1.5)
        assert wd.state == Watchdog.FALLBACK
        names = {f.name for f in wd.standby.findings}
        assert "dpu_outage" in names
        # the merged view surfaces it to whoever holds the plane handle
        assert "dpu_outage" in {f.name for f in wd.findings}

    def test_force_failover_is_idempotent(self):
        _, side, wd = self._mk()
        assert wd.force_failover(0.1)
        assert wd.state == Watchdog.FALLBACK and wd.failovers == 1
        assert wd.force_failover(0.2)
        assert wd.failovers == 1                    # already failed over

    def test_no_failover_on_healthy_loop(self):
        _, side, wd = self._mk()
        _drive(wd, 1.0)
        assert wd.state == Watchdog.NORMAL
        assert wd.failovers == 0
        assert {f.name for f in wd.standby.findings} == set()


# ---------------------------------------------------------------------------
# hot-standby pair: tap fan-out, leader leases, fencing, promotion
# ---------------------------------------------------------------------------

from repro.dpu import (       # noqa: E402  (grouped with the suite they test)
    ElectionArbiter,
    FencingRegistry,
    LeaseParams,
    TapFanout,
)


class TestTapFanout:
    def test_fanout_delivers_to_all_consumers(self):
        p1 = TelemetryPlane(n_nodes=4, mitigate=False)
        p2 = TelemetryPlane(n_nodes=4, mitigate=False)
        a = DPUSidecar(p1, DPUParams(), mitigate=False)
        b = DPUSidecar(p2, DPUParams(), mitigate=False, seed=1)
        fan = TapFanout(a, b)
        fan.observe_batch(_batch(8, ts0=0.0))
        a.advance(0.01)
        b.advance(0.01)
        assert fan.forked == 1
        # each consumer's guard saw the same (independently stamped) frame
        assert a.guard.last_seq == b.guard.last_seq > -1
        assert a.guard.gaps == 0 and b.guard.gaps == 0

    def test_forks_are_independent_frames(self):
        # the per-link sequence stamp is written into the frame in place:
        # without a fork the second consumer would see the first link's
        # batch_seq and its ingest guard would desynchronize immediately
        p1 = TelemetryPlane(n_nodes=4, mitigate=False)
        p2 = TelemetryPlane(n_nodes=4, mitigate=False)
        a = DPUSidecar(p1, DPUParams(), mitigate=False)
        # standby uplink partitioned mid-stream: its sequence stream must
        # gap independently of the primary's
        b = DPUSidecar(p2, DPUParams(
            uplink=LinkParams(delay=1e-3, partition_start=0.04,
                              partition_duration=0.04)),
            mitigate=False, seed=1)
        fan = TapFanout(a, b)
        t = 0.0
        for i in range(60):
            fan.observe_batch(_batch(2, ts0=t))
            a.advance(t)
            b.advance(t)
            t += 2e-3
        assert a.guard.gaps == 0                    # primary stream whole
        assert b.guard.gaps >= 1                    # standby gapped alone

    def test_fork_copies_payload_not_reference(self):
        batch = _batch(4)
        fork = TapFanout.fork(batch)
        assert fork.batch_seq == -1                 # unstamped copy
        assert np.array_equal(fork.ts, batch.ts)
        assert fork is not batch

    def test_empty_fanout_rejected(self):
        with pytest.raises(ValueError):
            TapFanout()


class TestElectionArbiter:
    def _arb(self, lease_s=0.1):
        arb = ElectionArbiter(LeaseParams(lease_s=lease_s))
        arb.register("primary")
        arb.register("standby")
        return arb

    def test_grant_renew_and_expiry(self):
        arb = self._arb()
        assert arb.grant("primary", 0.0) == 1
        assert arb.holder_valid("primary", 0.05)
        arb.renew(0.05)
        assert arb.holder_valid("primary", 0.14)    # renewed past t=0.1
        assert not arb.holder_valid("primary", 0.30)

    def test_no_promotion_before_horizon_expires(self):
        arb = self._arb()
        arb.grant("primary", 0.0)
        arb.renew(0.08)                             # horizon now 0.18
        assert not arb.can_promote("standby", 0.10)
        assert arb.grant("standby", 0.10) == 0      # refused, term unchanged
        assert arb.registry.term == 1
        assert arb.can_promote("standby", 0.18)
        assert arb.grant("standby", 0.18) == 2
        assert arb.registry.holder == "standby"

    def test_undelivered_renewal_does_not_extend(self):
        arb = self._arb()
        arb.grant("primary", 0.0)
        arb.renew(0.08, delivered=False)            # OOB partition: lost
        assert arb.lost_renewals == 1
        assert not arb.holder_valid("primary", 0.11)
        assert arb.can_promote("standby", 0.10)     # horizon stayed at 0.1

    def test_revoke_clamps_lease_and_horizon(self):
        arb = self._arb()
        arb.grant("primary", 0.0)
        arb.revoke("primary", 0.02)
        assert not arb.holder_valid("primary", 0.03)
        assert arb.can_promote("standby", 0.02)

    def test_terms_strictly_monotonic(self):
        arb = self._arb(lease_s=0.01)
        terms = []
        t = 0.0
        for holder in ("primary", "standby", "primary", "standby"):
            t += 0.05                               # past every horizon
            terms.append(arb.grant(holder, t))
        assert terms == [1, 2, 3, 4]

    def test_valid_leases_never_overlap(self):
        arb = self._arb()
        arb.grant("primary", 0.0)
        arb.grant("standby", 0.2)                   # after horizon expiry
        for t in (0.0, 0.05, 0.1, 0.15, 0.2, 0.25, 0.35):
            assert len(arb.valid_holders(t)) <= 1


class TestFencing:
    def test_stale_term_command_is_fenced_and_recorded(self):
        reg = FencingRegistry()
        reg.term, reg.holder = 3, "standby"
        from dataclasses import replace
        stale = replace(_cmd(cmd_id=7), term=2)
        fresh = replace(_cmd(cmd_id=8), term=3)
        legacy = _cmd(cmd_id=9)                     # term 0: unleased bus
        assert not reg.admit(stale, 1.0)
        assert reg.admit(fresh, 1.0)
        assert reg.admit(legacy, 1.0)
        assert len(reg.fenced) == 1
        assert reg.fenced[0].term == 2 and reg.fenced[0].granted_term == 3
        assert reg.stale_applied == 0

    def test_bus_fences_stale_sender_end_to_end(self):
        from repro.core.mitigation import NullEngine
        from repro.dpu.election import LeaderLease
        eng = NullEngine()
        reg = FencingRegistry()
        reg.term = 5
        bus = CommandBus(eng, np.random.default_rng(0),
                         down=LinkParams(delay=1e-3),
                         ack=LinkParams(delay=1e-3))
        lease = LeaderLease("deposed")
        lease.term = 4                              # believes an old term
        bus.lease = lease
        bus.fencing = reg
        bus.send(_cmd(cmd_id=1, ts=0.0), 0.0)
        for t in (1e-3, 2e-3, 3e-3):
            bus.advance(t)
        assert bus.stats.fenced == 1
        assert bus.stats.applied == 0
        assert eng.calls == []                      # zero double-actuation
        assert reg.stale_applied == 0
        assert bus.stats.acked == 1                 # nack closed retry state
        assert bus.stats.live_acked == 0            # ...but is not liveness

    def test_superseded_late_ack_is_not_liveness(self):
        # satellite regression: a late straggler superseded by a newer
        # applied command gets a nack that closes its retry state — it
        # must NOT count as channel liveness (live_acked) and must NOT
        # clear the sidecar's exhaustion latch
        from repro.core.mitigation import NullEngine
        eng = NullEngine()
        bus = CommandBus(eng, np.random.default_rng(0),
                         down=LinkParams(delay=1e-3),
                         ack=LinkParams(delay=1e-3))
        bus.send(_cmd(cmd_id=5, ts=0.0), 0.0)       # newest applies first
        for t in (1e-3, 2e-3, 3e-3):
            bus.advance(t)
        assert bus.stats.applied == 1
        live_before = bus.stats.live_acked
        bus.send(_cmd(cmd_id=3, ts=0.05), 0.05)     # older id: superseded
        for t in (0.051, 0.052, 0.053):
            bus.advance(t)
        assert bus.stats.superseded == 1
        assert bus.stats.acked == 2
        assert bus.stats.live_acked == live_before  # nack isn't liveness
        # and the sidecar latch keyed on live acks stays latched
        plane = TelemetryPlane(n_nodes=4, mitigate=False)
        side = DPUSidecar(plane, DPUParams(ping_every=0.0), mitigate=False)
        side.bus = bus
        side._bus_dirty = True
        side._acked_seen = bus.stats.live_acked
        side._exhausted_seen = bus.stats.exhausted
        side._self_telemetry()
        assert side._bus_dirty                      # stale nack didn't clear


def _mk_pair(wd_kw=None, primary_kw=None, standby_kw=None, mitigate=False):
    plane = TelemetryPlane(n_nodes=4, mitigate=False)
    side = DPUSidecar(plane, DPUParams(**(primary_kw or {})),
                      mitigate=mitigate)
    sb_plane = TelemetryPlane(n_nodes=4, mitigate=False)
    standby = DPUSidecar(sb_plane, DPUParams(**(standby_kw or {})),
                         mitigate=mitigate, seed=1)
    wd = Watchdog(side, WatchdogParams(**(wd_kw or {})), mitigate=mitigate,
                  standby=standby)
    return side, standby, wd


class TestHotStandbyPromotion:
    def test_standby_shadows_without_leading(self):
        side, standby, wd = _mk_pair()
        _drive(wd, 1.0)
        assert wd.state == Watchdog.NORMAL
        assert wd.promotions == 0
        assert standby.guard.last_seq > 0           # warm the whole time
        assert wd.arbiter.registry.holder == "primary"
        assert wd.arbiter.registry.term == 1

    def test_primary_crash_promotes_warm_standby(self):
        side, standby, wd = _mk_pair(primary_kw=dict(crash_at=0.5))
        _drive(wd, 1.0)
        assert wd.state == Watchdog.STANDBY
        assert wd.promotions == 1
        assert wd.failovers == 0                    # hot path, not degraded
        assert wd.arbiter.registry.holder == "standby"
        assert wd.arbiter.registry.term == 2
        # promotion waited for the delivered lease horizon to expire
        assert wd.arbiter.registry.stale_applied == 0

    def test_primary_return_demotes_hysteretically(self):
        side, standby, wd = _mk_pair(
            primary_kw=dict(crash_at=0.5, restart_after=0.2))
        _drive(wd, 1.5)
        assert wd.state == Watchdog.NORMAL
        assert wd.promotions == 1
        assert wd.failbacks == 1
        assert wd.arbiter.registry.holder == "primary"
        assert wd.arbiter.registry.term == 3        # crash, promote, regrant

    def test_both_dark_degrades_to_host_mode(self):
        side, standby, wd = _mk_pair(
            primary_kw=dict(crash_at=0.5),
            standby_kw=dict(crash_at=0.5))
        _drive(wd, 1.2)
        assert wd.state == Watchdog.FALLBACK
        assert wd.failovers == 1
        assert wd.arbiter.registry.holder == "host"

    def test_retention_stays_bounded(self):
        # satellite regression: many tiny flushes per simulated second must
        # not grow the retained window past the explicit cap
        side, standby, wd = _mk_pair(wd_kw=dict(retain_max=64))
        t = 0.0
        for _ in range(500):
            wd.observe_batch(_batch(1, ts0=t))
            t += 1e-5                               # payload clock crawls
        assert len(wd._retained) <= 64

    def test_force_failover_does_not_restamp_ts(self):
        # satellite regression: a redundant force landing mid-incident must
        # not reset failover_ts — the dark-window evidence handover keys
        # off the original failover instant
        side, standby, wd = _mk_pair(primary_kw=dict(crash_at=0.3))
        _drive(wd, 0.8)
        assert wd.state != Watchdog.NORMAL
        ts0 = wd.failover_ts
        wd.force_failover(0.9)
        assert wd.failover_ts == ts0
