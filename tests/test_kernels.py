"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in kernels/ref.py, executed with interpret=True on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.paged_attention import paged_attention_kernel
from repro.kernels.ssd_scan import ssd_scan_kernel

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestFlashAttention:
    @pytest.mark.parametrize("s", [128, 192, 256])
    @pytest.mark.parametrize("d", [64, 120, 128])
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
    def test_shapes_causal(self, s, d, hq, hkv):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (2, s, hq, d), jnp.float32)
        k = jax.random.normal(ks[1], (2, s, hkv, d), jnp.float32)
        v = jax.random.normal(ks[2], (2, s, hkv, d), jnp.float32)
        out = flash_attention_kernel(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("window", [32, 100, 200])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 256, 4, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 256, 4, 64), jnp.float32)
        out = flash_attention_kernel(q, k, v, causal=True, window=window,
                                     interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_bf16(self):
        ks = jax.random.split(jax.random.key(2), 3)
        q = jax.random.normal(ks[0], (2, 128, 4, 64), jnp.bfloat16)
        k = jax.random.normal(ks[1], (2, 128, 2, 64), jnp.bfloat16)
        v = jax.random.normal(ks[2], (2, 128, 2, 64), jnp.bfloat16)
        out = flash_attention_kernel(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            atol=3e-2, rtol=3e-2)

    def test_ragged_seq_padding(self):
        ks = jax.random.split(jax.random.key(3), 3)
        q = jax.random.normal(ks[0], (1, 200, 4, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 200, 4, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 200, 4, 64), jnp.float32)
        out = flash_attention_kernel(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


class TestPagedAttention:
    @pytest.mark.parametrize("page,per_seq", [(16, 8), (32, 4)])
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
    def test_vs_ref(self, page, per_seq, hq, hkv):
        B, D, P = 3, 64, 64
        ks = jax.random.split(jax.random.key(4), 4)
        q = jax.random.normal(ks[0], (B, hq, D), jnp.float32)
        kp = jax.random.normal(ks[1], (P, page, hkv, D), jnp.float32)
        vp = jax.random.normal(ks[2], (P, page, hkv, D), jnp.float32)
        table = jax.random.permutation(
            ks[3], P)[:B * per_seq].reshape(B, per_seq).astype(jnp.int32)
        lengths = jnp.array([page * per_seq, 3, page + 1][:B], jnp.int32)
        out = paged_attention_kernel(q, kp, vp, table, lengths,
                                     interpret=True)
        want = ref.paged_attention_ref(q, kp, vp, table, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_short_sequences_skip_pages(self):
        B, page, per_seq, hq, hkv, D = 2, 16, 8, 4, 2, 64
        ks = jax.random.split(jax.random.key(5), 4)
        q = jax.random.normal(ks[0], (B, hq, D), jnp.float32)
        kp = jax.random.normal(ks[1], (32, page, hkv, D), jnp.float32)
        vp = jax.random.normal(ks[2], (32, page, hkv, D), jnp.float32)
        table = jnp.arange(B * per_seq, dtype=jnp.int32).reshape(B, per_seq)
        lengths = jnp.array([1, 2], jnp.int32)
        out = paged_attention_kernel(q, kp, vp, table, lengths,
                                     interpret=True)
        want = ref.paged_attention_ref(q, kp, vp, table, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("l", [128, 256, 384])
    @pytest.mark.parametrize("p,n", [(32, 16), (64, 64)])
    def test_vs_sequential_ref(self, l, p, n):
        b, h = 2, 3
        ks = jax.random.split(jax.random.key(6), 4)
        x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
        a = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.1
        B = jax.random.normal(ks[2], (b, l, n), jnp.float32)
        C = jax.random.normal(ks[3], (b, l, n), jnp.float32)
        y, _ = ssd_scan_kernel(x, a, B, C, interpret=True)
        want, _ = ref.ssd_scan_ref(x, a, B, C)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   atol=2e-4, rtol=2e-4)

    def test_matches_model_ssd(self):
        """Kernel semantics == the model's chunked jnp implementation."""
        from repro.models.ssm import ssd_chunked
        b, l, h, p, n = 1, 256, 2, 32, 16
        ks = jax.random.split(jax.random.key(7), 4)
        x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
        a = -jnp.abs(jax.random.normal(ks[1], (b, l, h))) * 0.1
        B = jax.random.normal(ks[2], (b, l, n), jnp.float32)
        C = jax.random.normal(ks[3], (b, l, n), jnp.float32)
        y_model, _ = ssd_chunked(x, a, B, C, chunk=128)
        y_kernel, _ = ssd_scan_kernel(x, a, B, C, interpret=True)
        np.testing.assert_allclose(np.asarray(y_kernel),
                                   np.asarray(y_model),
                                   atol=2e-4, rtol=2e-4)


def test_ops_dispatch_cpu_uses_ref():
    """On CPU (non-interpret) the wrappers fall through to the oracle."""
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 4, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 4, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-6)
