"""DPU control-plane unit + integration tests (repro.dpu).

Covers the modeled transport (delay/jitter/loss determinism), the on-DPU
ingest budget (ceiling pacing, bounded ring, shed accounting), the policy
engine (confirmations, cooldown re-arm, flap damping, conflict arbitration,
quorum escalation), the command bus (RTT, acks, retries, stale/duplicate/
superseded handling), the sidecar end-to-end loop (event storm ->
``dpu_saturation`` finding -> throttle command applied on the host), and the
instant-mode MitigationController's hysteresis/cooldown edges the scenarios
never stress directly.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.attribution import Attribution
from repro.core.detectors import META_TAP_DEBUG, Finding
from repro.core.events import EventBatchBuilder, EventKind
from repro.core.mitigation import ACTIONS, MitigationController, NullEngine
from repro.core.telemetry import TelemetryPlane
from repro.dpu import (
    CONFLICT_GROUPS,
    CommandBus,
    DPUBudget,
    DPUParams,
    DPUSidecar,
    LinkParams,
    ModeledLink,
    PolicyEngine,
)
from repro.dpu.policy import Command


def _finding(name="tp_straggler", ts=1.0, node=1, severity="warn",
             score=5.0):
    return Finding(name=name, table="3c", ts=ts, severity=severity,
                   node=node, device=-1, stage="s", root_cause="r",
                   directive="d", score=score)


def _att(name="tp_straggler", ts=1.0, node=1, severity="warn",
         confidence=0.9, score=5.0, locus="device_scheduling"):
    return Attribution(ts=ts, locus=locus, node=node, confidence=confidence,
                       primary=_finding(name, ts, node, severity, score),
                       supporting=(), narrative="n")


def _batch(n, ts0=0.0, kind=EventKind.QUEUE_SAMPLE, meta=META_TAP_DEBUG):
    b = EventBatchBuilder()
    for i in range(n):
        b.add(ts0 + i * 1e-5, int(kind), i % 4, meta=meta)
    return b.build(sort=True)


class TestModeledLink:
    def test_delivers_after_delay_in_order(self):
        link = ModeledLink(LinkParams(delay=0.01), np.random.default_rng(0))
        link.send(0.0, "a")
        link.send(0.002, "b")
        assert link.deliver(0.005) == []
        assert link.deliver(0.010) == ["a"]
        assert link.deliver(0.020) == ["b"]
        assert link.sent == 2 and link.delivered == 2 and link.dropped == 0

    def test_zero_knob_link_consumes_no_randomness(self):
        rng = np.random.default_rng(7)
        before = rng.bit_generator.state
        link = ModeledLink(LinkParams(delay=1e-3), rng)
        for i in range(50):
            link.send(i * 1e-3, i)
        link.deliver(1.0)
        assert rng.bit_generator.state == before

    def test_drop_is_deterministic_per_seed(self):
        def run():
            link = ModeledLink(LinkParams(delay=1e-3, drop_p=0.5),
                               np.random.default_rng(42))
            kept = [i for i in range(100) if link.send(0.0, i)]
            return kept, link.dropped
        a, b = run(), run()
        assert a == b
        assert 0 < a[1] < 100


class TestDPUBudget:
    def test_ring_bound_sheds_overflow_prefix(self):
        budget = DPUBudget(events_per_s=1e9, ring_events=100)
        assert budget.offer(_batch(80)) == 0
        assert budget.offer(_batch(50)) == 30       # 20 fit, 30 shed
        assert budget.backlog == 100
        assert budget.offer(_batch(10)) == 10       # ring full
        assert budget.events_shed == 40
        assert budget.occupancy() == 1.0

    def test_ceiling_paces_drain_and_splits_batches(self):
        budget = DPUBudget(events_per_s=1000.0, ring_events=10_000)
        budget.offer(_batch(100))
        assert budget.drain(0.0) == []              # anchor call
        out = budget.drain(0.010)                   # 10 ms -> 10 rows
        assert sum(len(b) for b in out) == 10
        assert budget.backlog == 90
        out = budget.drain(0.100)                   # 90 ms -> the rest
        assert sum(len(b) for b in out) == 90
        assert budget.backlog == 0
        assert budget.events_processed == 100

    def test_drained_rows_preserve_order(self):
        budget = DPUBudget(events_per_s=1000.0, ring_events=1000)
        budget.offer(_batch(30, ts0=0.0))
        budget.offer(_batch(30, ts0=1.0))
        budget.drain(0.0)
        rows = []
        for t in (0.02, 0.04, 0.2):
            rows.extend(ts for b in budget.drain(t) for ts in b.ts.tolist())
        assert rows == sorted(rows)
        assert len(rows) == 60


class TestPolicyEngine:
    def test_warn_needs_confirmations_critical_does_not(self):
        pol = PolicyEngine(confirmations=2)
        pol.observe(_att(ts=1.0))
        assert pol.decide(1.0) == []
        pol.observe(_att(ts=2.0))
        assert len(pol.decide(2.0)) == 1
        pol2 = PolicyEngine(confirmations=2)
        pol2.observe(_att(ts=1.0, severity="critical"))
        assert len(pol2.decide(1.0)) == 1

    def test_cooldown_suppresses_then_rearms(self):
        pol = PolicyEngine(confirmations=1, cooldown=1.0)
        pol.observe(_att(ts=1.0, severity="critical"))
        assert len(pol.decide(1.0)) == 1
        pol.observe(_att(ts=1.5, severity="critical"))
        assert pol.decide(1.5) == []                # held down
        assert pol.suppressed[-1][0] == "cooldown"
        pol.observe(_att(ts=2.5, severity="critical"))
        assert len(pol.decide(2.5)) == 1            # cooldown expired

    def test_flap_damping_backs_off_cooldown(self):
        pol = PolicyEngine(confirmations=1, cooldown=0.2, flap_window=10.0,
                           flap_limit=2, flap_backoff=2.0)
        key = ("rebalance_shards", 1)
        issued = []
        for k in range(8):
            t = 1.0 + k * 0.5
            pol.observe(_att(ts=t, severity="critical"))
            issued.extend(c.ts for c in pol.decide(t))
        # flapping: the effective cooldown doubles per issue inside the
        # window, so issues must thin out instead of firing every 0.5 s
        assert len(issued) < 8
        assert pol.effective_cooldown(key, issued[-1]) > 0.2

    def test_conflicting_actions_one_winner_per_node(self):
        pol = PolicyEngine(confirmations=1)
        # same node, same conflict group (admission), different rows
        a_warn = _att("burst_admission_backlog", ts=1.0, node=0,
                      severity="warn", locus="ingress_path")
        a_crit = _att("ingress_egress_bandwidth_saturation", ts=1.0, node=0,
                      severity="critical", locus="ingress_path")
        assert CONFLICT_GROUPS["smooth_admission"] \
            == CONFLICT_GROUPS["admission_control"]
        # warn alone would actuate at 1 confirmation too
        pol.observe(a_warn)
        pol.observe(_att("burst_admission_backlog", ts=1.0, node=0,
                         severity="warn", locus="ingress_path"))
        pol.observe(a_crit)
        cmds = pol.decide(1.0)
        assert [c.action for c in cmds] == ["admission_control"]
        assert any(s[0] == "conflict" for s in pol.suppressed)

    def test_quorum_escalation_after_dwell(self):
        pol = PolicyEngine(confirmations=2, quorum=3, quorum_dwell=1.0,
                           cooldown=5.0)

        def quorum_round(ts, nodes):
            for node in nodes:
                pol.observe(_att("d2h_return_bottleneck", ts=ts, node=node,
                                 confidence=0.6, locus="pcie_transfer"))

        # one-shot row: every node reports once, in the same round
        quorum_round(1.0, range(4))
        assert pol.decide(1.0) == []                # per-node never confirms
        assert pol.decide(1.5) == []                # dwell not reached
        cmds = pol.decide(2.1)
        assert len(cmds) == 1
        assert cmds[0].action == "pin_and_coalesce"
        assert cmds[0].node == -1                   # cluster-wide
        assert pol.decide(3.0) == []                # no repeat w/o evidence
        # a RECURRING cluster incident re-arms once the cooldown expires:
        # fresh quorum evidence (here from a disjoint node set, so the
        # per-node path still can't confirm) re-seeds the dwell and
        # re-escalates instead of latching off forever
        quorum_round(8.0, range(10, 14))
        assert pol.decide(8.0) == []                # dwell again
        cmds = pol.decide(9.1)
        assert len(cmds) == 1 and cmds[0].node == -1

    def test_low_confidence_filtered(self):
        pol = PolicyEngine(confirmations=1, min_confidence=0.5)
        pol.observe(_att(ts=1.0, severity="critical", confidence=0.4))
        assert pol.decide(1.0) == []


class _FakeEngine:
    def __init__(self, ok=True):
        self.ok = ok
        self.calls = []

    def apply_action(self, action, node, detail):
        self.calls.append((action, node))
        return self.ok


def _cmd(cmd_id=1, ts=0.0, action="rebalance_shards", node=1):
    return Command(cmd_id=cmd_id, ts=ts, action=action, node=node,
                   row_id="tp_straggler", locus="device_scheduling",
                   detail={})


class TestCommandBus:
    def test_rtt_and_ack(self):
        eng = _FakeEngine()
        bus = CommandBus(eng, np.random.default_rng(0),
                         down=LinkParams(delay=0.01))
        bus.send(_cmd(ts=0.0), 0.0)
        assert bus.advance(0.005) == []             # still on the wire
        recs = bus.advance(0.010)
        assert len(recs) == 1 and recs[0].applied
        assert eng.calls == [("rebalance_shards", 1)]
        assert bus.stats.acked == 0                 # ack still in flight
        bus.advance(0.020)
        assert bus.stats.acked == 1
        assert not bus._outstanding

    def test_lost_command_retried_until_applied(self):
        eng = _FakeEngine()
        # drop_p = 1 would never deliver; use a seeded coin and wide retry
        bus = CommandBus(eng, np.random.default_rng(3),
                         down=LinkParams(delay=1e-3, drop_p=0.7),
                         ack_timeout=5e-3, max_retries=10, stale_after=10.0)
        bus.send(_cmd(ts=0.0), 0.0)
        t = 0.0
        while not eng.calls and t < 0.5:
            t += 1e-3
            bus.advance(t)
        assert eng.calls, "retries never landed the command"
        assert bus.stats.retries > 0

    def test_stale_command_invalidated_not_applied(self):
        eng = _FakeEngine()
        bus = CommandBus(eng, np.random.default_rng(0),
                         down=LinkParams(delay=0.2), stale_after=0.1)
        bus.send(_cmd(ts=0.0), 0.0)
        assert bus.advance(0.2) == []
        assert eng.calls == []
        assert bus.stats.stale_dropped == 1

    def test_duplicate_delivery_applies_once(self):
        eng = _FakeEngine()
        # ack link loses everything: the sender keeps retrying a command
        # the host already applied — apply-at-most-once must hold
        bus = CommandBus(eng, np.random.default_rng(0),
                         down=LinkParams(delay=1e-3),
                         ack=LinkParams(delay=1e-3, drop_p=1.0),
                         ack_timeout=2e-3, max_retries=5, stale_after=10.0)
        bus.send(_cmd(ts=0.0), 0.0)
        for k in range(1, 30):
            bus.advance(k * 1e-3)
        assert len(eng.calls) == 1
        assert bus.stats.duplicates > 0

    def test_superseded_straggler_dropped(self):
        eng = _FakeEngine()
        bus = CommandBus(eng, np.random.default_rng(0),
                         down=LinkParams(delay=0.0))
        # the newer command (id 2) arrives and applies first; the older
        # straggler (id 1) is then discarded
        bus.send(_cmd(cmd_id=2, ts=0.01), 0.01)
        bus.advance(0.02)
        bus.send(_cmd(cmd_id=1, ts=0.015), 0.03)
        bus.advance(0.04)
        assert len(eng.calls) == 1
        assert bus.stats.superseded == 1

    def test_gives_up_after_max_retries(self):
        eng = _FakeEngine()
        bus = CommandBus(eng, np.random.default_rng(0),
                         down=LinkParams(delay=1e-3, drop_p=1.0),
                         ack_timeout=1e-3, max_retries=3, stale_after=10.0)
        bus.send(_cmd(ts=0.0), 0.0)
        for k in range(1, 20):
            bus.advance(k * 1e-3)
        assert eng.calls == []
        assert bus.stats.expired == 1
        assert not bus._outstanding


class TestSidecarEndToEnd:
    def test_event_storm_saturates_and_throttle_lands_on_host(self):
        plane = TelemetryPlane(n_nodes=4, mitigate=False)
        side = DPUSidecar(
            plane,
            DPUParams(events_per_s=5_000, ring_events=512,
                      uplink=LinkParams(delay=1e-3),
                      downlink=LinkParams(delay=1e-3)),
            seed=0, mitigate=True)
        eng = _FakeEngine()
        side.bind(eng)
        # ~50 rows/ms against a 5 rows/ms budget: the ring must fill
        t = 0.0
        for step in range(600):
            t = step * 1e-3
            side.observe_batch(_batch(50, ts0=t))
            side.advance(t)
        assert side.budget.events_shed > 0
        fired = {f.name for f in plane.findings}
        assert "dpu_saturation" in fired
        assert ("throttle_telemetry", -1) in eng.calls
        assert any(r.action == "throttle_telemetry" and r.applied
                   for r in plane.actions)
        rep = side.report()
        assert rep["budget"]["shed"] == side.budget.events_shed
        assert rep["commands"]["applied"] >= 1

    def test_fully_starved_budget_still_self_diagnoses(self):
        """Regression: a budget too small to forward ANYTHING must still
        report its own saturation — self-telemetry rides the arrival (tap)
        clock, not the drained-stream clock."""
        plane = TelemetryPlane(n_nodes=4, mitigate=False)
        side = DPUSidecar(
            plane, DPUParams(events_per_s=10, ring_events=256,
                             uplink=LinkParams(delay=1e-3)),
            seed=0, mitigate=False)
        for step in range(300):
            t = step * 2e-3
            side.observe_batch(_batch(40, ts0=t))
            side.advance(t)
        assert side.budget.events_shed > 0
        assert {f.name for f in plane.findings} == {"dpu_saturation"}

    def test_warmup_sheds_surface_in_first_eligible_poll(self):
        """Regression: sheds seen before MIN_SAMPLES warm-up completes must
        accumulate into the first eligible finding, not vanish."""
        from repro.core.detectors import (DPUSaturation, DetectorConfig,
                                          META_DPU_RING, Event)
        det = DPUSaturation(DetectorConfig())

        def sample(ts, shed, occ):
            det.update(Event(ts=ts, kind=EventKind.QUEUE_SAMPLE, node=-1,
                             size=shed, depth=occ, meta=META_DPU_RING))

        for k in range(3):                      # shed during warm-up...
            sample(0.1 * k, shed=400, occ=100)
            assert det.poll(0.1 * k + 0.05) == []
        sample(0.3, shed=0, occ=10)             # ...burst over by sample 4
        out = det.poll(0.4)
        assert len(out) == 1
        assert out[0].severity == "critical"
        assert out[0].evidence["shed_rows"] == 1200

    def test_healthy_stream_no_shed_no_actions(self):
        plane = TelemetryPlane(n_nodes=4, mitigate=False)
        side = DPUSidecar(plane, DPUParams(events_per_s=1e6,
                                           ring_events=65536),
                          seed=0, mitigate=True)
        eng = _FakeEngine()
        side.bind(eng)
        for step in range(200):
            t = step * 1e-3
            side.observe_batch(_batch(20, ts0=t))
            side.advance(t)
        assert side.budget.events_shed == 0
        assert eng.calls == []
        assert plane.actions == []


class TestMitigationControllerEdges:
    """Satellite coverage: the instant controller's hysteresis/cooldown
    boundaries, which the scenario suite only crosses on the happy path."""

    def test_noisy_findings_do_not_thrash(self):
        eng = NullEngine()
        ctl = MitigationController(eng, confirmations=2, cooldown=5.0)
        # a noisy detector re-reporting every 100 ms must actuate once,
        # then hold through the cooldown no matter how often it fires
        for k in range(40):
            ctl.consider(_att(ts=1.0 + k * 0.1))
        assert len(eng.calls) == 1
        assert len(ctl.log) == 1

    def test_cooldown_expiry_rearms(self):
        eng = NullEngine()
        ctl = MitigationController(eng, confirmations=2, cooldown=1.0)
        assert ctl.consider(_att(ts=1.0)) is None
        assert ctl.consider(_att(ts=1.1)) is not None
        # still inside cooldown: confirmations accumulate but nothing fires
        assert ctl.consider(_att(ts=1.5)) is None
        assert ctl.consider(_att(ts=1.6)) is None
        # past cooldown: the same pathology re-confirms and re-actuates
        assert ctl.consider(_att(ts=2.2)) is not None
        assert len(eng.calls) == 2

    def test_critical_short_circuits_confirmation(self):
        eng = NullEngine()
        ctl = MitigationController(eng, confirmations=2)
        assert ctl.consider(_att(ts=1.0, severity="critical")) is not None

    def test_low_confidence_and_unknown_rows_ignored(self):
        eng = NullEngine()
        ctl = MitigationController(eng, confirmations=1)
        assert ctl.consider(_att(ts=1.0, confidence=0.5)) is None
        assert ctl.consider(_att("not_a_row", ts=1.0)) is None
        assert eng.calls == []

    def test_actions_registry_in_sync_with_runbooks(self):
        from repro.core.runbooks import ALL_RUNBOOKS
        assert {e.action for e in ALL_RUNBOOKS} <= set(ACTIONS)


@pytest.mark.slow
class TestClosedLoopLatencyOrdering:
    """The headline property on a real scenario: the modeled DPU loop
    detects the same fault but mitigates strictly later than the instant
    in-process loop — the feedback path's cost is measured, not assumed."""

    def test_dpu_mitigates_later_than_instant(self):
        from repro.sim import SCENARIOS
        from repro.sim.cluster import run_scenario
        sc = SCENARIOS["early_completion"]
        res = {}
        for mode in ("instant", "dpu"):
            params = dataclasses.replace(sc.params, control=mode)
            m, plane, sim = run_scenario(dataclasses.replace(sc.fault),
                                         params, sc.workload, mitigate=True)
            assert sim.fault.mitigated, mode
            assert plane.actions
            res[mode] = m.mitigated_ts
        assert res["dpu"] > res["instant"]

    def test_dpu_saturation_scenario_self_heals(self):
        from repro.sim import SCENARIOS
        from repro.sim.cluster import run_scenario
        sc = SCENARIOS["dpu_saturation"]
        m, plane, sim = run_scenario(dataclasses.replace(sc.fault),
                                     sc.params, sc.workload, mitigate=True)
        assert sim.fault.mitigated
        assert any(r.action == "throttle_telemetry" for r in plane.actions)
        side = sim.plane
        assert side.budget.events_shed > 0
        # post-mitigation the storm stops and the ring drains back down
        assert side.budget.occupancy() < 0.5