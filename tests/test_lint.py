"""The linter's own coverage: one injected violation per rule class, the
guard shapes the dominator walk must accept, pragma-suppression semantics,
wiring-chain breakage via patched registries, and the acceptance gate —
a whole-tree run with zero unsuppressed findings.
"""

from unittest import mock

import pytest

from repro.lint.cli import run_lint
from repro.lint.findings import RULES
from repro.lint.pragmas import apply_pragmas, collect_pragmas
from repro.lint.purity import lint_source
from repro.lint.wiring import (EXPECTED_TABLE_COUNTS, check_wiring,
                               expected_rows, repo_root)


def rules_of(findings, suppressed=None):
    return [f.rule for f in findings
            if suppressed is None or f.suppressed is suppressed]


def lint_with_pragmas(src, path="src/repro/sim/x.py"):
    """Purity pass + pragma matching on one snippet — the full per-file
    path the CLI runs, minus the wiring half."""
    return apply_pragmas(lint_source(src, path),
                         {path: collect_pragmas(src, path)})


class TestUnseededRNG:
    def test_module_level_draw_flagged(self):
        src = ("import numpy as np\n"
               "def f():\n"
               "    return np.random.rand(3)\n")
        assert rules_of(lint_source(src, "x.py")) == ["unseeded-rng"]

    def test_bare_random_flagged(self):
        src = ("import random\n"
               "def f():\n"
               "    return random.random()\n")
        assert rules_of(lint_source(src, "x.py")) == ["unseeded-rng"]

    def test_unseeded_default_rng_flagged(self):
        src = ("import numpy as np\n"
               "rng = np.random.default_rng()\n")
        assert rules_of(lint_source(src, "x.py")) == ["unseeded-rng"]

    def test_seeded_generator_clean(self):
        src = ("import numpy as np\n"
               "def f(seed):\n"
               "    rng = np.random.default_rng(seed)\n"
               "    return rng.normal(), np.random.SeedSequence(seed)\n")
        assert lint_source(src, "x.py") == []

    def test_import_alias_resolved(self):
        src = ("import numpy.random as nr\n"
               "def f():\n"
               "    return nr.normal()\n")
        assert rules_of(lint_source(src, "x.py")) == ["unseeded-rng"]

    def test_jax_random_exempt(self):
        src = ("import jax\n"
               "def f(key):\n"
               "    return jax.random.normal(key)\n")
        assert lint_source(src, "x.py") == []


class TestWallClock:
    def test_time_time_flagged(self):
        src = ("import time\n"
               "def f():\n"
               "    return time.time()\n")
        assert rules_of(lint_source(src, "x.py")) == ["wall-clock"]

    def test_datetime_now_flagged(self):
        src = ("from datetime import datetime\n"
               "def f():\n"
               "    return datetime.now()\n")
        assert rules_of(lint_source(src, "x.py")) == ["wall-clock"]

    def test_allowlisted_site_suppressed_with_reason(self):
        # the sampled-timing window in core/telemetry.py is the one legal
        # wall-clock home — it surfaces as a *suppressed* finding
        src = ("import time\n"
               "class DPUAgent:\n"
               "    def poll(self):\n"
               "        return time.perf_counter()\n")
        fs = lint_source(src, "src/repro/core/telemetry.py")
        assert [f.rule for f in fs] == ["wall-clock"]
        assert fs[0].suppressed and fs[0].reason

    def test_same_code_elsewhere_not_allowlisted(self):
        src = ("import time\n"
               "class DPUAgent:\n"
               "    def poll(self):\n"
               "        return time.perf_counter()\n")
        fs = lint_source(src, "src/repro/sim/cluster.py")
        assert [f.suppressed for f in fs] == [False]


class TestMutableDefault:
    def test_list_default_flagged(self):
        src = "def f(xs=[]):\n    return xs\n"
        assert rules_of(lint_source(src, "x.py")) == ["mutable-default"]

    def test_dict_call_default_flagged(self):
        src = "def f(m=dict()):\n    return m\n"
        assert rules_of(lint_source(src, "x.py")) == ["mutable-default"]

    def test_none_and_tuple_defaults_clean(self):
        src = "def f(xs=None, t=(), s='a'):\n    return xs, t, s\n"
        assert lint_source(src, "x.py") == []


class TestUnguardedHook:
    def test_bare_call_flagged(self):
        src = ("class C:\n"
               "    def go(self):\n"
               "        self.tracer.on_finding(1)\n")
        assert rules_of(lint_source(src, "x.py")) == ["unguarded-hook"]

    def test_if_guard_clean(self):
        src = ("class C:\n"
               "    def go(self):\n"
               "        if self.tracer is not None:\n"
               "            self.tracer.on_finding(1)\n")
        assert lint_source(src, "x.py") == []

    def test_early_return_guard_clean(self):
        src = ("class C:\n"
               "    def go(self):\n"
               "        if self.tracer is None:\n"
               "            return\n"
               "        self.tracer.on_finding(1)\n")
        assert lint_source(src, "x.py") == []

    def test_alias_guard_clean(self):
        src = ("class C:\n"
               "    def go(self):\n"
               "        t = self.tracer\n"
               "        if t is not None:\n"
               "            t.on_finding(1)\n")
        assert lint_source(src, "x.py") == []

    def test_alias_without_guard_flagged(self):
        src = ("class C:\n"
               "    def go(self):\n"
               "        t = self.tracer\n"
               "        t.on_finding(1)\n")
        assert rules_of(lint_source(src, "x.py")) == ["unguarded-hook"]

    def test_ifexp_guard_clean(self):
        src = ("def f(sim):\n"
               "    return (sim.tracer.reports()\n"
               "            if sim.tracer is not None else [])\n")
        assert lint_source(src, "x.py") == []

    def test_boolop_shortcircuit_clean(self):
        src = ("class C:\n"
               "    def go(self):\n"
               "        self.tracer and self.tracer.on_finding(1)\n")
        assert lint_source(src, "x.py") == []

    def test_getattr_normalized(self):
        src = ("def f(sim):\n"
               "    return (sim.tracer.reports()\n"
               "            if getattr(sim, 'tracer', None) is not None\n"
               "            else [])\n")
        assert lint_source(src, "x.py") == []

    def test_guard_on_holder_covers_deep_access(self):
        # a guard on the hook holder dominates deeper attribute calls
        src = ("def f(tracer):\n"
               "    if tracer is not None:\n"
               "        return tracer.counters.get('x')\n")
        assert lint_source(src, "x.py") == []

    def test_wrong_branch_flagged(self):
        src = ("class C:\n"
               "    def go(self):\n"
               "        if self.tracer is None:\n"
               "            self.tracer.on_finding(1)\n")
        assert rules_of(lint_source(src, "x.py")) == ["unguarded-hook"]

    def test_reassignment_kills_guard(self):
        src = ("class C:\n"
               "    def go(self, mk):\n"
               "        if self.tracer is None:\n"
               "            return\n"
               "        self.tracer = mk()\n"
               "        self.tracer.on_finding(1)\n")
        assert rules_of(lint_source(src, "x.py")) == ["unguarded-hook"]


class TestPragmas:
    def test_trailing_pragma_suppresses(self):
        src = ("import time\n"
               "def f():\n"
               "    return time.time()  "
               "# repro-lint: allow(wall-clock): test reason\n")
        fs = lint_with_pragmas(src)
        assert [(f.rule, f.suppressed) for f in fs] == [("wall-clock", True)]
        assert fs[0].reason == "test reason"

    def test_own_line_pragma_anchors_next_statement(self):
        src = ("import time\n"
               "def f():\n"
               "    # repro-lint: allow(wall-clock): test reason\n"
               "    return time.time()\n")
        fs = lint_with_pragmas(src)
        assert [(f.rule, f.suppressed) for f in fs] == [("wall-clock", True)]

    def test_missing_reason_is_bad_pragma(self):
        src = ("import time\n"
               "def f():\n"
               "    return time.time()  # repro-lint: allow(wall-clock)\n")
        fs = lint_with_pragmas(src)
        assert sorted(rules_of(fs, suppressed=False)) == \
            ["bad-pragma", "wall-clock"]

    def test_unknown_rule_is_bad_pragma(self):
        src = "x = 1  # repro-lint: allow(no-such-rule): why\n"
        fs = lint_with_pragmas(src)
        assert rules_of(fs) == ["bad-pragma"]

    def test_unused_pragma_flagged(self):
        src = "x = 1  # repro-lint: allow(wall-clock): stale\n"
        fs = lint_with_pragmas(src)
        assert rules_of(fs) == ["unused-pragma"]

    def test_pragma_does_not_suppress_other_rule(self):
        src = ("import time\n"
               "def f():\n"
               "    return time.time()  "
               "# repro-lint: allow(unseeded-rng): wrong rule\n")
        fs = lint_with_pragmas(src)
        assert sorted(rules_of(fs, suppressed=False)) == \
            ["unused-pragma", "wall-clock"]

    def test_every_rule_documented(self):
        for rule, desc in RULES.items():
            assert desc and rule == rule.lower()


class TestWiring:
    def test_real_registry_clean_modulo_smoke_pragmas(self):
        hard = [f for f in check_wiring() if f.rule != "smoke-coverage"]
        assert not hard, "\n".join(f.format() for f in hard)

    def test_counts_single_source(self):
        assert expected_rows() == sum(EXPECTED_TABLE_COUNTS.values())

    def test_missing_action_detected(self):
        from repro.core.mitigation import ACTIONS
        broken = dict(ACTIONS)
        victim = next(iter(broken))
        del broken[victim]
        with mock.patch("repro.core.mitigation.ACTIONS", broken):
            rules = rules_of(check_wiring())
        assert "wiring-action" in rules

    def test_orphan_action_detected(self):
        from repro.core.mitigation import ACTIONS
        padded = dict(ACTIONS)
        padded["no_row_emits_this"] = object()
        with mock.patch("repro.core.mitigation.ACTIONS", padded):
            fs = check_wiring()
        assert any(f.rule == "wiring-action"
                   and "no_row_emits_this" in f.message for f in fs)

    def test_missing_scenario_detected(self):
        from repro.sim.faults import SCENARIOS
        broken = dict(SCENARIOS)
        victim = next(n for n, sc in broken.items() if sc.row_id)
        del broken[victim]
        with mock.patch("repro.sim.faults.SCENARIOS", broken):
            fs = check_wiring()
        # forward break (row -> scenario) and the now-stale golden entry
        assert "wiring-scenario" in rules_of(fs)
        assert any(f.rule == "wiring-golden" and victim in f.message
                   for f in fs)

    def test_missing_attribution_detected(self):
        from repro.core.attribution import DIRECT_LOCUS
        broken = dict(DIRECT_LOCUS)
        del broken[next(iter(broken))]
        with mock.patch("repro.core.attribution.DIRECT_LOCUS", broken):
            rules = rules_of(check_wiring())
        assert "wiring-attribution" in rules

    def test_unknown_smoke_name_detected(self):
        with mock.patch("repro.sim.sweep.SMOKE_SCENARIOS",
                        ("healthy", "no_such_scenario")):
            fs = check_wiring()
        assert any(f.rule == "smoke-coverage"
                   and "no_such_scenario" in f.message for f in fs)

    def test_table_count_drift_detected(self):
        with mock.patch("repro.lint.wiring.EXPECTED_TABLE_COUNTS",
                        dict(EXPECTED_TABLE_COUNTS, mon=6)):
            rules = rules_of(check_wiring())
        assert "wiring-counts" in rules


class TestSweepCLI:
    def test_unknown_scenario_exits_2(self, capsys):
        from repro.sim.sweep import main
        rc = main(["--scenarios", "definitely_not_a_scenario",
                   "--workers", "1"])
        assert rc == 2
        assert "unknown scenario" in capsys.readouterr().err

    def test_smoke_grid_names_are_real(self):
        from repro.sim.faults import SCENARIOS
        from repro.sim.sweep import SMOKE_SCENARIOS
        assert set(SMOKE_SCENARIOS) <= set(SCENARIOS)


class TestWholeTree:
    def test_zero_unsuppressed_findings(self):
        # the acceptance gate: the CLI over the real tree must be clean,
        # and every suppression must carry a reason
        report = run_lint(repo_root())
        assert report.files_scanned > 20
        bad = report.unsuppressed
        assert not bad, "\n".join(f.format() for f in bad)
        for f in report.suppressed:
            assert f.reason, f.format()
