"""Robustness property tests: the telemetry plane must never crash, leak
unknown findings, or mis-time on ARBITRARY event streams (a DPU sees
whatever the wire carries — detectors cannot assume well-formed traffic)."""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # clean checkout: seeded-random fallback
    from proptest_fallback import given, settings, st

from repro.core import TelemetryPlane
from repro.core.events import CollectiveOp, Event, EventKind
from repro.core.runbooks import BY_ID

event_strategy = st.builds(
    Event,
    ts=st.floats(min_value=0.0, max_value=10.0, allow_nan=False),
    kind=st.sampled_from(list(EventKind)),
    node=st.integers(-1, 8),
    device=st.integers(-1, 8),
    flow=st.integers(-1, 64),
    size=st.integers(0, 1 << 30),
    depth=st.integers(0, 1 << 16),
    op=st.sampled_from([-1] + [int(o) for o in CollectiveOp]),
    group=st.integers(-1, 8),
    meta=st.integers(0, 1 << 10),
    replica=st.integers(-1, 4),
)


class TestPlaneFuzz:
    @given(st.lists(event_strategy, min_size=1, max_size=400))
    @settings(max_examples=40, deadline=None)
    def test_never_crashes_and_findings_are_known_rows(self, events):
        plane = TelemetryPlane(n_nodes=4, mitigate=True)
        # feed in time order (the wire is ordered); arbitrary content
        for ev in sorted(events, key=lambda e: e.ts):
            plane.observe(ev)
        plane.tick(11.0)
        for f in plane.findings:
            assert f.name in BY_ID               # only registered rows
            assert f.severity in ("warn", "critical")
            assert f.table == BY_ID[f.name].table  # table matches registry
        for a in plane.attributions:
            assert 0.0 <= a.confidence <= 1.0
        rep = plane.report()
        assert rep["events"] == len(events)

    @given(st.lists(event_strategy, min_size=1, max_size=200))
    @settings(max_examples=20, deadline=None)
    def test_deterministic_given_same_stream(self, events):
        stream = sorted(events, key=lambda e: e.ts)

        def run():
            plane = TelemetryPlane(n_nodes=4, mitigate=False)
            for ev in stream:
                plane.observe(ev)
            plane.tick(11.0)
            return sorted((f.name, f.node, round(f.ts, 6))
                          for f in plane.findings)

        assert run() == run()
