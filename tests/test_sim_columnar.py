"""Columnar producer plane: vectorized-vs-scalar synthesis parity, golden
per-scenario regression fixtures, admission-path invariants, and the
parallel sweep runner.

The core property: ``SimParams.scalar_synth=True`` (per-event reference
emission) and the default vectorized path draw from ONE seeded
``np.random.Generator`` stream and stage identical rows in identical
order, so the produced ``EventBatch`` traces are bit-identical — and
therefore so are detector findings and SimMetrics.  The committed golden
fixture (``tests/golden/scenario_findings.json``, generated from the
scalar reference via ``tests/regen_golden.py``) pins that behavior."""

import dataclasses
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # clean checkout: seeded-random fallback
    from proptest_fallback import given, settings, st

from repro.core.events import BATCH_COLUMNS, EventTraceRecorder
from repro.sim import (
    SCENARIOS,
    SimParams,
    SweepConfig,
    WorkloadSpec,
    run_scenario,
    run_sweep,
)
from repro.sim.cluster import ClusterSim

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "scenario_findings.json")
with open(GOLDEN_PATH) as fh:
    GOLDEN = json.load(fh)["scenarios"]


def _run(name: str, scalar: bool, flush: int = 1, scale: int = 1):
    sc = SCENARIOS[name].variant(scalar_synth=scalar, scale=scale)
    sc.params.flush_events = flush
    wl = dataclasses.replace(sc.workload, duration=sc.params.duration * 0.98)
    rec = EventTraceRecorder()
    sim = ClusterSim(sc.params, wl, sc.fault, plane=rec)
    sim.run()
    return rec.batches, sim


def _assert_traces_equal(a, b, ctx=""):
    assert len(a) == len(b), f"{ctx}: batch count {len(a)} != {len(b)}"
    for i, (x, y) in enumerate(zip(a, b)):
        for col in BATCH_COLUMNS:
            assert np.array_equal(getattr(x, col), getattr(y, col)), (
                f"{ctx}: batch {i} column {col} differs")


class TestSynthesisParity:
    """Vectorized and scalar-reference synthesis are bit-identical."""

    @pytest.mark.parametrize("name", ["healthy", "burst_admission",
                                      "egress_jitter", "registration_churn",
                                      "hot_replica"])
    def test_traces_bit_identical(self, name):
        bv, _ = _run(name, scalar=False)
        bs, _ = _run(name, scalar=True)
        _assert_traces_equal(bv, bs, name)

    def test_traces_bit_identical_at_ring_dma_window(self):
        # parity is cadence-independent: same rows, same order, whatever
        # the flush granularity
        bv, _ = _run("nic_saturation", scalar=False, flush=65536)
        bs, _ = _run("nic_saturation", scalar=True, flush=65536)
        _assert_traces_equal(bv, bs, "nic_saturation@65536")

    def test_traces_bit_identical_at_scale(self):
        bv, _ = _run("flow_skew", scalar=False, scale=4)
        bs, _ = _run("flow_skew", scalar=True, scale=4)
        _assert_traces_equal(bv, bs, "flow_skew@x4")

    @pytest.mark.parametrize("name", ["collective_straggler",
                                      "rail_congestion",
                                      "hbm_bandwidth_cliff"])
    def test_traces_bit_identical_for_3e_tiers(self, name):
        # the per-collective, rail-leg, and HBM-gated egress phases all
        # stage through the same deferred-columns path — parity must hold
        # with the new emission tiers switched on
        bv, _ = _run(name, scalar=False)
        bs, _ = _run(name, scalar=True)
        _assert_traces_equal(bv, bs, name)

    @pytest.mark.parametrize("flush", [257, 65536])
    def test_3e_parity_is_cadence_independent(self, flush):
        bv, _ = _run("collective_straggler", scalar=False, flush=flush)
        bs, _ = _run("collective_straggler", scalar=True, flush=flush)
        _assert_traces_equal(bv, bs, f"collective_straggler@{flush}")

    @given(st.integers(0, 10_000), st.integers(2, 4))
    @settings(max_examples=5, deadline=None)
    def test_parity_on_random_small_workloads(self, seed, n_nodes):
        # property form: any (seed, topology) cell keeps the two paths
        # bit-identical — not just the registry's hand-picked scenarios
        params = SimParams(n_nodes=n_nodes, duration=0.3, seed=seed)
        wl = WorkloadSpec(rate=150.0, duration=0.29, seed=seed)
        traces = []
        for scalar in (False, True):
            rec = EventTraceRecorder()
            ClusterSim(dataclasses.replace(params, scalar_synth=scalar),
                       wl, None, plane=rec).run()
            traces.append(rec.batches)
        _assert_traces_equal(*traces, ctx=f"seed={seed},n={n_nodes}")

    @given(st.integers(0, 10_000), st.integers(2, 4),
           st.sampled_from([1, 257, 4096]))
    @settings(max_examples=5, deadline=None)
    def test_parity_with_3e_tiers_on_random_workloads(self, seed, n_nodes,
                                                      flush):
        # property form for the new tiers: arbitrary (seed, topology,
        # flush cadence) with per-collective rounds, rail legs, and the
        # HBM knee all enabled keeps the two paths bit-identical
        params = SimParams(n_nodes=n_nodes, duration=0.3, seed=seed,
                           flush_events=flush, per_collective=True,
                           rail_domain_size=2, hbm_knee=6)
        wl = WorkloadSpec(rate=150.0, duration=0.29, seed=seed)
        traces = []
        for scalar in (False, True):
            rec = EventTraceRecorder()
            ClusterSim(dataclasses.replace(params, scalar_synth=scalar),
                       wl, None, plane=rec).run()
            traces.append(rec.batches)
        _assert_traces_equal(
            *traces, ctx=f"3e:seed={seed},n={n_nodes},flush={flush}")


@pytest.mark.slow
class TestGoldenFixtures:
    """The committed scalar-reference fixture pins findings AND metrics;
    the vectorized path must reproduce it exactly."""

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_vectorized_reproduces_golden(self, name):
        sc = SCENARIOS[name].variant(scalar_synth=False)
        m, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        got = [[f.name, f.node, f.ts, f.severity, f.score]
               for f in plane.findings]
        g = GOLDEN[name]
        assert got == g["findings"], f"{name}: findings diverge from golden"
        gm = g["metrics"]
        assert m.completed == gm["completed"]
        assert m.tokens_out == gm["tokens_out"]
        assert m.first_finding_ts == gm["first_finding_ts"]
        assert m.p(0.5) == gm["p50_latency"]
        assert m.p(0.99) == gm["p99_latency"]
        assert m.p_ttft(0.5) == gm["p50_ttft"]
        assert m.p_ttft(0.99) == gm["p99_ttft"]

    @pytest.mark.parametrize("name", ["healthy", "tp_straggler",
                                      "early_completion"])
    def test_scalar_reference_still_matches_golden(self, name):
        # staleness guard: the fixture IS the scalar path's output
        sc = SCENARIOS[name].variant(scalar_synth=True)
        m, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        got = [[f.name, f.node, f.ts, f.severity, f.score]
               for f in plane.findings]
        assert got == GOLDEN[name]["findings"], (
            f"{name}: scalar reference drifted from committed golden — "
            "if intentional, run tests/regen_golden.py")

    def test_golden_covers_registry(self):
        assert set(GOLDEN) == set(SCENARIOS)


class TestAdmissionPath:
    """The O(n^2) pop(0) admission is gone; semantics are preserved."""

    def test_cursor_admits_every_arrival_exactly_once(self):
        params = SimParams(duration=1.0, seed=5)
        wl = WorkloadSpec(rate=400.0, duration=0.98, burst_factor=16.0,
                          seed=5)
        sim = ClusterSim(params, wl, None, plane=None)
        sim.run()
        # every generated request was either admitted (queued/active/
        # completed) — none lost, none duplicated
        n_active = sum(len(a) for a in sim.active)
        n_queued = sum(len(q) for q in sim.queues)
        assert sim._pend_i == len(sim.pending)
        assert n_active + n_queued + sim.metrics.completed == len(
            sim.requests)
        # the backlog list itself is never mutated by admission
        assert sim.pending == sorted(sim.requests, key=lambda r: r.arrival)

    def test_queued_work_accounting_stays_consistent(self):
        params = SimParams(duration=0.8, seed=9)
        wl = WorkloadSpec(rate=500.0, duration=0.78, seed=9)
        sim = ClusterSim(params, wl, None, plane=None)
        sim.run()
        for node, q in enumerate(sim.queues):
            assert sim._queued_work[node] == sum(
                max(r.decode_len, 1) for r in q)


@pytest.mark.slow
class TestSweepRunner:
    SCENARIO_SUBSET = ("healthy", "tp_straggler", "hot_replica")

    def test_parallel_sweep_detects_and_aggregates(self):
        report = run_sweep(SweepConfig(
            scenarios=self.SCENARIO_SUBSET, seeds=(0,), workers=2))
        assert len(report.results) == 3
        assert report.hit_rate() == 1.0
        assert report.false_positives() == 0
        assert report.events > 0
        summary = report.summary()
        assert summary["cells"] == 3
        assert set(summary["scenarios"]) == set(self.SCENARIO_SUBSET)

    def test_parallel_equals_sequential(self):
        cfg = dict(scenarios=self.SCENARIO_SUBSET, seeds=(0, 1))
        par = run_sweep(SweepConfig(workers=2, **cfg))
        seq = run_sweep(SweepConfig(workers=1, **cfg))
        key = lambda r: (r.scenario, r.seed)
        for a, b in zip(sorted(par.results, key=key),
                        sorted(seq.results, key=key)):
            assert (a.scenario, a.seed, a.hit, a.findings, a.completed,
                    a.tokens_out, a.detect_latency) == \
                   (b.scenario, b.seed, b.hit, b.findings, b.completed,
                    b.tokens_out, b.detect_latency)

    def test_seed_grid_and_unknown_scenario_rejected(self):
        cfg = SweepConfig(scenarios=("healthy",), seeds=(0, 1, 2))
        assert len(cfg.jobs()) == 3
        with pytest.raises(ValueError):
            SweepConfig(scenarios=("nope",)).jobs()
