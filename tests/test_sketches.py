"""Property-based tests for the line-rate streaming sketches."""

import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # clean checkout: seeded-random fallback
    from proptest_fallback import given, settings, st

from repro.core.sketch import (
    EWMA,
    BurstMeter,
    CUSUM,
    GapTracker,
    P2Quantile,
    RateMeter,
    SpreadTracker,
    Welford,
)

finite = st.floats(min_value=-1e6, max_value=1e6,
                   allow_nan=False, allow_infinity=False)
positive = st.floats(min_value=1e-3, max_value=1e6,
                     allow_nan=False, allow_infinity=False)


class TestEWMA:
    @given(st.lists(finite, min_size=1, max_size=200))
    def test_mean_within_range(self, xs):
        ew = EWMA(0.1)
        for x in xs:
            ew.update(x)
        assert min(xs) - 1e-6 <= ew.mean <= max(xs) + 1e-6

    @given(finite)
    def test_constant_stream_zero_variance(self, c):
        ew = EWMA(0.2)
        for _ in range(50):
            ew.update(c)
        assert ew.std <= max(abs(c) * 1e-5, 1e-6)
        assert ew.zscore(c) == pytest.approx(0.0, abs=1e-3)

    def test_converges_to_level_shift(self):
        ew = EWMA(0.1)
        for _ in range(100):
            ew.update(1.0)
        for _ in range(200):
            ew.update(5.0)
        assert abs(ew.mean - 5.0) < 0.1

    def test_invalid_alpha(self):
        with pytest.raises(ValueError):
            EWMA(0.0)


class TestP2Quantile:
    @given(st.lists(st.floats(min_value=0, max_value=1000,
                              allow_nan=False), min_size=20, max_size=500),
           st.sampled_from([0.5, 0.9, 0.99]))
    @settings(max_examples=50, deadline=None)
    def test_within_sample_range(self, xs, q):
        p2 = P2Quantile(q)
        for x in xs:
            p2.update(x)
        assert min(xs) - 1e-9 <= p2.value <= max(xs) + 1e-9

    def test_median_of_uniform(self):
        import random
        rng = random.Random(0)
        p2 = P2Quantile(0.5)
        for _ in range(5000):
            p2.update(rng.random())
        assert abs(p2.value - 0.5) < 0.05

    def test_p99_of_uniform(self):
        import random
        rng = random.Random(1)
        p2 = P2Quantile(0.99)
        for _ in range(5000):
            p2.update(rng.random())
        assert abs(p2.value - 0.99) < 0.05

    def test_small_sample_exact(self):
        p2 = P2Quantile(0.5)
        for x in [3.0, 1.0, 2.0]:
            p2.update(x)
        assert p2.value == 2.0


class TestCUSUM:
    def test_no_fire_on_stationary(self):
        import random
        rng = random.Random(2)
        cs = CUSUM(slack=0.5, threshold=5.0)
        fired = False
        for _ in range(500):
            fired |= cs.update(rng.gauss(10.0, 1.0))
        assert not fired

    def test_fires_on_level_shift(self):
        import random
        rng = random.Random(3)
        cs = CUSUM(slack=0.5, threshold=5.0)
        for _ in range(100):
            cs.update(rng.gauss(10.0, 1.0))
        fired = False
        for _ in range(50):
            fired |= cs.update(rng.gauss(20.0, 1.0))
        assert fired

    def test_constant_stream_stable(self):
        # rel_slack guards the std->0 degeneracy
        cs = CUSUM()
        fired = False
        for i in range(300):
            fired |= cs.update(5.0 + 1e-9 * (i % 2))
        assert not fired


class TestGapTracker:
    @given(st.lists(positive, min_size=2, max_size=100))
    def test_gap_stats_nonnegative(self, gaps):
        gt = GapTracker()
        t = 0.0
        for g in gaps:
            t += g
            gt.update(t)
        assert gt.gaps.mean > 0
        assert gt.max_gap >= gt.gaps.mean - 1e-9
        assert gt.jitter() >= 0

    def test_constant_cadence_low_jitter(self):
        gt = GapTracker()
        for i in range(100):
            gt.update(i * 0.01)
        assert gt.jitter() < 0.05

    def test_open_gap(self):
        gt = GapTracker()
        gt.update(1.0)
        gt.update(2.0)
        assert gt.current_gap(10.0) == pytest.approx(8.0)


class TestSpreadTracker:
    def test_dominant_straggler_identified(self):
        st_ = SpreadTracker(expected=4)
        for r in range(50):
            for node in range(4):
                ts = r * 1.0 + (0.5 if node == 2 else 0.01 * node)
                st_.update(r, node, ts)
        worst, frac = st_.dominant_straggler()
        assert worst == 2
        assert frac > 0.9

    def test_balanced_no_dominant(self):
        import random
        rng = random.Random(4)
        st_ = SpreadTracker(expected=4)
        for r in range(200):
            for node in range(4):
                st_.update(r, node, r * 1.0 + rng.random() * 0.01)
        _, frac = st_.dominant_straggler()
        assert frac < 0.5


class TestRateMeter:
    def test_steady_rate(self):
        rm = RateMeter(halflife=0.5)
        for i in range(1, 2000):
            rm.update(i * 0.001, 100)
        assert rm.rate == pytest.approx(1000.0, rel=0.1)
        assert rm.byte_rate == pytest.approx(100_000.0, rel=0.1)

    def test_rate_at_decays(self):
        rm = RateMeter(halflife=0.1)
        for i in range(1, 100):
            rm.update(i * 0.001, 100)
        assert rm.rate_at(0.099 + 1.0) < 0.01 * rm.rate


class TestBurstMeter:
    def test_burst_detected(self):
        bm = BurstMeter()
        t = 0.0
        for _ in range(200):        # steady background
            t += 0.01
            bm.update(t, 1000)
        for _ in range(50):         # sudden microburst
            t += 1e-5
            bm.update(t, 1000)
        assert bm.byte_burstiness() > 10.0


class TestWelford:
    @given(st.lists(finite, min_size=2, max_size=200))
    def test_matches_numpy(self, xs):
        import numpy as np
        w = Welford()
        for x in xs:
            w.update(x)
        assert w.mean == pytest.approx(float(np.mean(xs)), rel=1e-6,
                                       abs=1e-6)
        assert w.var == pytest.approx(float(np.var(xs, ddof=0)), rel=1e-4,
                                      abs=1e-4)
