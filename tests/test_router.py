"""Cross-replica router: policy invariants, staleness semantics, the live
ReplicaSet plumbing, and the 3d closed loop (hot-replica detection +
rebalance_replicas measurably reducing tail latency)."""

import dataclasses
import random

import pytest

from repro.serving.router import (
    POLICIES,
    ReplicaSet,
    ReplicaSnapshot,
    RequestInfo,
    Router,
    make_policy,
)
from repro.sim import SCENARIOS, SimParams, WorkloadSpec, run_scenario
from repro.sim.cluster import ClusterSim, FaultSpec


def _feed(router: Router, backlogs, ts=0.0, work=None, kv=None):
    for r, b in enumerate(backlogs):
        router.observe(ReplicaSnapshot(
            replica=r, ts=ts, queue_depth=b, active=0, slots=8,
            kv_occupancy=(kv[r] if kv else 0.0),
            expected_work=(work[r] if work else float(b))))


class TestPolicies:
    def test_registry_covers_expected_policies(self):
        assert set(POLICIES) == {"round_robin", "join_shortest_queue",
                                 "least_kv", "prediction_aware"}
        with pytest.raises(ValueError):
            make_policy("no_such_policy")

    @pytest.mark.parametrize("policy", sorted(POLICIES))
    def test_every_request_routed_exactly_once(self, policy):
        """Conservation: N requests -> N decisions, all to valid replicas."""
        router = Router(4, policy=policy, seed=1)
        _feed(router, [3, 1, 4, 2])
        n = 200
        for i in range(n):
            rep = router.route(RequestInfo(flow=i, predicted_decode=8.0),
                               now=0.01 * i)
            assert 0 <= rep < 4
        assert len(router.decisions) == n
        assert sum(router.routed_per_replica) == n
        assert sorted(d.flow for d in router.decisions) == list(range(n))

    def test_round_robin_is_even(self):
        router = Router(4, policy="round_robin")
        _feed(router, [100, 0, 0, 0])   # load-blind: ignores the view
        for i in range(40):
            router.route(RequestInfo(flow=i))
        assert router.routed_per_replica == [10, 10, 10, 10]

    def test_jsq_never_routes_to_strictly_longer_queue(self):
        """The JSQ invariant, under a churning view and optimistic bumps."""
        rng = random.Random(0)
        router = Router(4, policy="join_shortest_queue", seed=2)
        effective = None
        for i in range(300):
            if i % 7 == 0:
                backlogs = [rng.randrange(0, 30) for _ in range(4)]
                _feed(router, backlogs, ts=0.01 * i)
            snaps = [router._effective(r, 0.01 * i) for r in range(4)]
            chosen = router.route(RequestInfo(flow=i), now=0.01 * i)
            chosen_backlog = next(s.backlog for s in snaps
                                  if s.replica == chosen)
            assert chosen_backlog <= min(s.backlog for s in snaps), \
                f"JSQ routed to backlog {chosen_backlog} with shorter " \
                f"queues in view at step {i}"

    def test_least_kv_prefers_low_occupancy(self):
        router = Router(3, policy="least_kv")
        _feed(router, [0, 0, 0], kv=[0.9, 0.2, 0.7])
        assert router.route(RequestInfo(flow=0)) == 1

    def test_prediction_aware_prefers_least_expected_work(self):
        # JSQ would pick replica 0 (fewest requests); the predictor knows
        # replica 0's single request is a monster
        router = Router(2, policy="prediction_aware")
        router.observe(ReplicaSnapshot(replica=0, ts=0.0, queue_depth=1,
                                       active=0, slots=8,
                                       expected_work=400.0))
        router.observe(ReplicaSnapshot(replica=1, ts=0.0, queue_depth=3,
                                       active=0, slots=8,
                                       expected_work=24.0))
        assert router.route(RequestInfo(flow=0, predicted_decode=8.0)) == 1

    def test_optimistic_bumps_spread_a_burst(self):
        """A burst between view refreshes must not dogpile one replica."""
        router = Router(4, policy="join_shortest_queue", seed=3)
        _feed(router, [0, 0, 0, 0])
        for i in range(40):
            router.route(RequestInfo(flow=i), now=0.0)
        assert max(router.routed_per_replica) <= 11

    def test_stale_view_disables_bumps_and_lags(self):
        router = Router(2, policy="join_shortest_queue", staleness=1.0)
        _feed(router, [0, 10], ts=0.0)
        _feed(router, [50, 0], ts=2.0)   # fresh truth: replica 0 is loaded
        # the stale router still sees the t=0 view (<= now - staleness)
        for i in range(20):
            assert router.route(RequestInfo(flow=i), now=2.5) == 0


class TestReplicaSet:
    class _StubSched:
        def __init__(self, slots):
            self.queue = []
            self.running = {}
            self.cfg = dataclasses.make_dataclass(
                "C", ["max_slots"])(max_slots=slots)

    class _StubEngine:
        def __init__(self, slots=8, occ=0.0):
            self.sched = TestReplicaSet._StubSched(slots)
            self._occ = occ
            self.submitted = []

        class _Pool:
            def __init__(self, occ):
                self._occ = occ

            def occupancy(self):
                return self._occ

        @property
        def pool(self):
            return self._Pool(self._occ)

        def submit(self, req):
            self.submitted.append(req)
            self.sched.queue.append(req)

    @dataclasses.dataclass
    class _Req:
        req_id: int
        max_new_tokens: int = 8
        tokens_out: int = 0

        @property
        def prompt_len(self):
            return 16

    def test_no_request_dropped_across_engines(self):
        engines = [self._StubEngine() for _ in range(3)]
        rs = ReplicaSet(engines, policy="join_shortest_queue")
        reqs = [self._Req(req_id=i) for i in range(30)]
        replicas = rs.submit_all(reqs)
        assert len(replicas) == 30
        landed = [len(e.submitted) for e in engines]
        assert sum(landed) == 30           # conservation
        assert max(landed) - min(landed) <= 1   # JSQ keeps it level
        seen = sorted(r.req_id for e in engines for r in e.submitted)
        assert seen == list(range(30))     # each exactly once

    def test_kv_occupancy_reaches_policy(self):
        engines = [self._StubEngine(occ=0.9), self._StubEngine(occ=0.1)]
        rs = ReplicaSet(engines, policy="least_kv")
        rs.submit(self._Req(req_id=0))
        assert engines[1].submitted

    def test_rebalance_action_levels_queued_backlog(self):
        """ReplicaSet is a mitigation actuator: rebalance_replicas drains
        the skewed queues and re-deals them level (the command-bus target
        for the 3d row outside the simulator)."""
        engines = [self._StubEngine() for _ in range(3)]
        for e in engines:
            e.sched.submit = e.sched.queue.append
        rs = ReplicaSet(engines, policy="round_robin")
        # pile the whole backlog on replica 0
        for i in range(12):
            engines[0].sched.queue.append(
                dataclasses.replace(self._Req(req_id=i)))
        assert rs.apply_action("rebalance_replicas", -1, {})
        depths = [len(e.sched.queue) for e in engines]
        assert sum(depths) == 12            # conservation
        assert max(depths) - min(depths) <= 1
        # unknown per-engine knob on a stub engine: politely refused
        assert rs.apply_action("compress_kv", 1, {}) is False


class TestReplicaSim:
    def test_replica_dimension_validates(self):
        with pytest.raises(ValueError):
            ClusterSim(SimParams(n_nodes=4, n_replicas=3), WorkloadSpec())

    def test_replica_tagged_telemetry(self):
        params = SimParams(n_nodes=4, n_replicas=2, duration=0.5)
        _, plane, sim = run_scenario(FaultSpec(start=1e9), params,
                                     WorkloadSpec(rate=100.0))
        replicas = {ev.replica for ev in plane.agent.stream
                    if ev.replica >= 0}
        assert replicas == {0, 1}
        # nodes 0,1 -> replica 0; nodes 2,3 -> replica 1
        for ev in plane.agent.stream:
            if ev.replica >= 0 and ev.node >= 0:
                assert ev.replica == ev.node // 2


@pytest.mark.slow
class TestHotReplicaClosedLoop:
    def test_hot_replica_fires_cross_replica_skew(self):
        sc = SCENARIOS["hot_replica"]
        _, plane, _ = run_scenario(dataclasses.replace(sc.fault),
                                   sc.params, sc.workload)
        fired = {f.name for f in plane.findings}
        assert "cross_replica_skew" in fired
        skew = [f for f in plane.findings if f.name == "cross_replica_skew"]
        # the hot replica must be named as the locus
        assert any(f.node == sc.fault.hot_replica for f in skew)

    def test_rebalance_reduces_p99_latency(self):
        """§5 closed loop on the DP layer: detection -> rebalance_replicas
        -> measurably better tail latency and more completions."""
        sc = SCENARIOS["hot_replica"]
        off, _, _ = run_scenario(dataclasses.replace(sc.fault),
                                 sc.params, sc.workload, mitigate=False)
        on, plane, sim = run_scenario(dataclasses.replace(sc.fault),
                                      sc.params, sc.workload, mitigate=True)
        assert any(a.action == "rebalance_replicas" for a in plane.actions)
        assert sim.fault.mitigated
        assert on.p(0.99) < 0.75 * off.p(0.99)
        assert on.p_ttft(0.99) < 0.75 * off.p_ttft(0.99)
        assert on.completed > off.completed

    def test_jsq_beats_round_robin_p99_ttft_under_bursty_skewed_load(self):
        """The router-table headline: queue-aware routing beats static
        rotation on tail TTFT when flows are skewed and arrivals bursty."""
        # rate 55 / seed 13: the np.random.Generator arrival stream needs
        # a partially-loaded regime for queue-aware routing to matter (at
        # 65/s this realization saturates every replica and JSQ ~= RR);
        # ratio holds at 0.72-0.83 across param seeds 3-11
        wl = WorkloadSpec(rate=55.0, duration=3.9, decode_mean=48,
                          decode_cv=0.6, burst_factor=8.0, flow_skew=1.2,
                          seed=13)
        results = {}
        for policy in ("round_robin", "join_shortest_queue"):
            params = SimParams(n_nodes=4, n_replicas=4,
                               router_policy=policy, duration=4.0, seed=3)
            m, _, _ = run_scenario(FaultSpec(start=1e9), params, wl)
            results[policy] = m
        jsq, rr = results["join_shortest_queue"], results["round_robin"]
        assert jsq.p_ttft(0.99) < 0.9 * rr.p_ttft(0.99)
        assert jsq.completed >= 0.95 * rr.completed
