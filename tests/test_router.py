"""Hierarchical cross-replica router: policy invariants (replica and node
tier), telemetry-borne view semantics (modeled-link lag, out-of-order
snapshots), the live ReplicaSet plumbing, and the 3d closed loop
(hot-replica detection + rebalance_replicas measurably reducing tail
latency)."""

import dataclasses
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # clean checkout: seeded-random fallback
    from proptest_fallback import given, settings, st

from repro.dpu.transport import LinkParams
from repro.serving.router import (
    POLICIES,
    NodeSnapshot,
    ReplicaSet,
    ReplicaSnapshot,
    RequestInfo,
    Router,
    RouterView,
    make_policy,
)
from repro.sim import Request, SCENARIOS, SimParams, WorkloadSpec, run_scenario
from repro.sim.cluster import ClusterSim, FaultSpec


def _feed(router: Router, backlogs, ts=0.0, work=None, kv=None, nodes=None):
    for r, b in enumerate(backlogs):
        router.observe(ReplicaSnapshot(
            replica=r, ts=ts, queue_depth=b, active=0, slots=8,
            kv_occupancy=(kv[r] if kv else 0.0),
            expected_work=(work[r] if work else float(b)),
            nodes=(nodes[r] if nodes else ())))


class TestPolicies:
    def test_registry_covers_expected_policies(self):
        assert set(POLICIES) == {"round_robin", "join_shortest_queue",
                                 "least_kv", "prediction_aware",
                                 "prefix_affinity", "hierarchical_jsq"}
        with pytest.raises(ValueError):
            make_policy("no_such_policy")

    @pytest.mark.parametrize("policy", sorted(POLICIES))
    def test_every_request_routed_exactly_once(self, policy):
        """Conservation: N requests -> N decisions, all to valid replicas."""
        router = Router(4, policy=policy, seed=1)
        _feed(router, [3, 1, 4, 2])
        n = 200
        for i in range(n):
            rep = router.route(RequestInfo(flow=i, predicted_decode=8.0),
                               now=0.01 * i)
            assert 0 <= rep < 4
        assert len(router.decisions) == n
        assert sum(router.routed_per_replica) == n
        assert sorted(d.flow for d in router.decisions) == list(range(n))

    def test_round_robin_is_even(self):
        router = Router(4, policy="round_robin")
        _feed(router, [100, 0, 0, 0])   # load-blind: ignores the view
        for i in range(40):
            router.route(RequestInfo(flow=i))
        assert router.routed_per_replica == [10, 10, 10, 10]

    def test_jsq_never_routes_to_strictly_longer_queue(self):
        """The JSQ invariant, under a churning view and optimistic bumps."""
        rng = random.Random(0)
        router = Router(4, policy="join_shortest_queue", seed=2)
        effective = None
        for i in range(300):
            if i % 7 == 0:
                backlogs = [rng.randrange(0, 30) for _ in range(4)]
                _feed(router, backlogs, ts=0.01 * i)
            snaps = [router._effective(r, 0.01 * i) for r in range(4)]
            chosen = router.route(RequestInfo(flow=i), now=0.01 * i)
            chosen_backlog = next(s.backlog for s in snaps
                                  if s.replica == chosen)
            assert chosen_backlog <= min(s.backlog for s in snaps), \
                f"JSQ routed to backlog {chosen_backlog} with shorter " \
                f"queues in view at step {i}"

    def test_least_kv_prefers_low_occupancy(self):
        router = Router(3, policy="least_kv")
        _feed(router, [0, 0, 0], kv=[0.9, 0.2, 0.7])
        assert router.route(RequestInfo(flow=0)) == 1

    def test_prediction_aware_prefers_least_expected_work(self):
        # JSQ would pick replica 0 (fewest requests); the predictor knows
        # replica 0's single request is a monster
        router = Router(2, policy="prediction_aware")
        router.observe(ReplicaSnapshot(replica=0, ts=0.0, queue_depth=1,
                                       active=0, slots=8,
                                       expected_work=400.0))
        router.observe(ReplicaSnapshot(replica=1, ts=0.0, queue_depth=3,
                                       active=0, slots=8,
                                       expected_work=24.0))
        assert router.route(RequestInfo(flow=0, predicted_decode=8.0)) == 1

    def test_optimistic_bumps_spread_a_burst(self):
        """A burst between view refreshes must not dogpile one replica."""
        router = Router(4, policy="join_shortest_queue", seed=3)
        _feed(router, [0, 0, 0, 0])
        for i in range(40):
            router.route(RequestInfo(flow=i), now=0.0)
        assert max(router.routed_per_replica) <= 11

    def test_stale_view_disables_bumps_and_lags(self):
        router = Router(2, policy="join_shortest_queue", staleness=1.0)
        _feed(router, [0, 10], ts=0.0)
        _feed(router, [50, 0], ts=2.0)   # fresh truth: replica 0 is loaded
        # the stale router still sees the t=0 view (<= now - staleness)
        for i in range(20):
            assert router.route(RequestInfo(flow=i), now=2.5) == 0


def _nodes_of(replica, depths, npr=2):
    return tuple(NodeSnapshot(node=replica * npr + i, queue_depth=d,
                              active=0, slots=8)
                 for i, d in enumerate(depths))


class TestHierarchicalRouting:
    def test_hierarchical_jsq_sees_through_balanced_replica_totals(self):
        # replica totals tie at 8; flat JSQ cannot tell them apart, the
        # hierarchical policy finds replica 0's idle node
        router = Router(2, policy="hierarchical_jsq", seed=1)
        _feed(router, [8, 8], nodes=[_nodes_of(0, [8, 0]),
                                     _nodes_of(1, [4, 4])])
        d = router.route_ex(RequestInfo(flow=0))
        assert d.replica == 0
        assert d.node == 1

    def test_flat_policies_leave_node_placement_to_caller(self):
        router = Router(2, policy="join_shortest_queue", seed=1)
        _feed(router, [1, 8], nodes=[_nodes_of(0, [1, 0]),
                                     _nodes_of(1, [4, 4])])
        d = router.route_ex(RequestInfo(flow=0))
        assert d.replica == 0
        assert d.node == -1

    def test_node_bumps_spread_a_burst_within_the_replica(self):
        router = Router(1, policy="hierarchical_jsq", seed=2)
        _feed(router, [0], nodes=[_nodes_of(0, [0, 0])])
        chosen = [router.route_ex(RequestInfo(flow=i)).node
                  for i in range(10)]
        assert abs(chosen.count(0) - chosen.count(1)) <= 1

    def test_device_counts_break_node_ties(self):
        router = Router(1, policy="hierarchical_jsq", seed=3)
        nodes = (NodeSnapshot(node=0, queue_depth=2, dev_active=(2, 2)),
                 NodeSnapshot(node=1, queue_depth=2, dev_active=(4, 0)))
        _feed(router, [4], nodes=[nodes])
        assert router.route_ex(RequestInfo(flow=0)).node == 1

    def test_prefix_affinity_sticks_sessions_to_their_home(self):
        router = Router(4, policy="prefix_affinity", seed=5)
        homes = {}
        for s in range(16):
            # idle cluster before each route, so no session ever spills
            _feed(router, [0, 0, 0, 0], ts=float(s))
            homes[s] = router.route(RequestInfo(flow=100 + s, session=s),
                                    now=float(s))
        assert len(set(homes.values())) > 1       # ring actually spreads
        # an idle view must reproduce every placement — affinity is a
        # property of the key, not of view churn
        for s, home in homes.items():
            _feed(router, [0, 0, 0, 0], ts=100.0 + s)
            assert router.route(RequestInfo(flow=200 + s, session=s),
                                now=100.0 + s) == home

    def test_prefix_affinity_spills_to_jsq_over_the_load_ceiling(self):
        router = Router(4, policy="prefix_affinity", seed=6)
        _feed(router, [0, 0, 0, 0])
        home = router.route(RequestInfo(flow=0, session=7))
        backlogs = [0, 0, 0, 0]
        backlogs[home] = 50                        # home is drowning
        _feed(router, backlogs, ts=1.0)
        spilled = router.route(RequestInfo(flow=1, session=7), now=1.0)
        assert spilled != home
        assert router.policy.spills >= 1

    def test_prefix_affinity_node_tier_is_sticky_too(self):
        router = Router(1, policy="prefix_affinity", seed=7)
        _feed(router, [0], nodes=[_nodes_of(0, [0, 0, 0, 0], npr=4)])
        first = router.route_ex(RequestInfo(flow=0, session=3)).node
        _feed(router, [0], ts=1.0,
              nodes=[_nodes_of(0, [0, 0, 0, 0], npr=4)])
        again = router.route_ex(
            RequestInfo(flow=1, session=3), now=1.0).node
        assert first == again >= 0


class TestTelemetryBorneView:
    def test_out_of_order_snapshots_insert_in_ts_order(self):
        view = RouterView(1)
        for ts in (0.5, 0.1, 0.9, 0.3, 0.7):
            view.update(ReplicaSnapshot(replica=0, ts=ts,
                                        queue_depth=int(ts * 10)))
        h = view._hist[0]
        assert [s.ts for s in h] == sorted(s.ts for s in h)
        assert view.get(0, 1.0).ts == 0.9          # newest by ts, not arrival
        # the staleness scan is correct again once history is sorted
        assert view.get(0, 1.0, staleness=0.4).ts == 0.5

    def test_shuffled_timestamp_regression(self):
        # the pre-fix append-only history corrupted both the prune cutoff
        # and the reversed() scan under out-of-order ingest
        rng = random.Random(3)
        tss = [i * 0.01 for i in range(200)]
        rng.shuffle(tss)
        view = RouterView(1, max_age=5.0)
        for ts in tss:
            view.update(ReplicaSnapshot(replica=0, ts=ts))
        h = view._hist[0]
        assert [s.ts for s in h] == sorted(s.ts for s in h)
        assert view.latest_ts(0) == max(tss)

    def test_stale_arrival_does_not_drag_prune_cutoff(self):
        view = RouterView(1, max_age=1.0)
        view.update(ReplicaSnapshot(replica=0, ts=5.0))
        view.update(ReplicaSnapshot(replica=0, ts=0.1))   # ancient strays
        view.update(ReplicaSnapshot(replica=0, ts=0.2))
        h = view._hist[0]
        # pruning keys off the newest snapshot HELD (5.0): one boundary
        # entry below the cutoff survives, the rest of the strays go
        assert [s.ts for s in h] == [0.2, 5.0]

    def test_stale_arrival_does_not_clear_optimistic_bumps(self):
        """A late out-of-order snapshot must not erase the dispatch deltas
        accumulated against the newest snapshot the view still serves."""
        router = Router(2, policy="join_shortest_queue", seed=4)
        _feed(router, [0, 0], ts=1.0)
        for i in range(3):          # bumps: 3 on whichever replica won ties
            router.route(RequestInfo(flow=i), now=1.0)
        before = list(router._bump_backlog)
        # a delayed ts=0.5 snapshot lands late for replica 0
        router.observe(ReplicaSnapshot(replica=0, ts=0.5, queue_depth=0))
        assert router._bump_backlog == before      # deltas survive
        assert router.view.get(0, 1.01).ts == 1.0  # newest still served
        # the next burst stays spread instead of dogpiling replica 0
        chosen = [router.route(RequestInfo(flow=10 + i), now=1.01)
                  for i in range(6)]
        assert abs(chosen.count(0) - chosen.count(1)) <= 1

    def test_hierarchical_view_tree_exposes_all_tiers(self):
        router = Router(2, policy="hierarchical_jsq", seed=1)
        _feed(router, [3, 2], nodes=[_nodes_of(0, [2, 1]),
                                     _nodes_of(1, [1, 1])])
        tree = router.view.tree(now=0.0)
        assert set(tree) == {0, 1}
        assert set(tree[0]) == {0, 1} and set(tree[1]) == {2, 3}
        assert tree[0][0].queue_depth == 2
        assert tree[1][3].queue_depth == 1

    def test_view_lag_is_measured_and_gates_optimistic_bumps(self):
        router = Router(2, policy="join_shortest_queue", seed=1)
        _feed(router, [0, 10], ts=0.0)
        assert router.view_lag(0.0) == 0.0
        assert router.view_lag(2.0) == pytest.approx(2.0)
        # nothing fresh arrived for 2 s: bumps are distrusted, so the
        # whole burst dogpiles the replica that *looked* shortest
        for i in range(20):
            assert router.route(RequestInfo(flow=i), now=2.0) == 0
        # a fresh delivery re-enables optimistic accounting
        _feed(router, [0, 0], ts=2.5)
        chosen = [router.route(RequestInfo(flow=100 + i), now=2.5)
                  for i in range(8)]
        assert chosen.count(0) == chosen.count(1) == 4


class TestRouterViewProperty:
    """RouterView.get staleness contract, across random ingest orders,
    staleness depths, and prune pressure."""

    @given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=64),
           st.floats(0.05, 4.0),
           st.floats(0.5, 12.0))
    @settings(max_examples=60, deadline=None)
    def test_get_never_serves_fresher_than_staleness(self, tss, staleness,
                                                     max_age):
        view = RouterView(1, max_age=max_age)
        for ts in tss:
            view.update(ReplicaSnapshot(replica=0, ts=ts))
        h = view._hist[0]
        assert [s.ts for s in h] == sorted(s.ts for s in h)
        assert view.latest_ts(0) == max(tss)    # newest survives pruning
        now = max(tss) + 0.01
        cutoff = now - staleness
        got = view.get(0, now, staleness)
        eligible = [s.ts for s in h if s.ts <= cutoff]
        if eligible:
            # never a snapshot younger than now - staleness when an
            # eligible one exists — and always the newest eligible one
            assert got.ts <= cutoff
            assert got.ts == eligible[-1]
        else:
            assert got.ts == h[0].ts


class TestReplicaSet:
    class _StubSched:
        def __init__(self, slots):
            self.queue = []
            self.running = {}
            self.cfg = dataclasses.make_dataclass(
                "C", ["max_slots"])(max_slots=slots)

    class _StubEngine:
        def __init__(self, slots=8, occ=0.0):
            self.sched = TestReplicaSet._StubSched(slots)
            self._occ = occ
            self.submitted = []

        class _Pool:
            def __init__(self, occ):
                self._occ = occ

            def occupancy(self):
                return self._occ

        @property
        def pool(self):
            return self._Pool(self._occ)

        def submit(self, req):
            self.submitted.append(req)
            self.sched.queue.append(req)

    @dataclasses.dataclass
    class _Req:
        req_id: int
        max_new_tokens: int = 8
        tokens_out: int = 0

        @property
        def prompt_len(self):
            return 16

    def test_no_request_dropped_across_engines(self):
        engines = [self._StubEngine() for _ in range(3)]
        rs = ReplicaSet(engines, policy="join_shortest_queue")
        reqs = [self._Req(req_id=i) for i in range(30)]
        replicas = rs.submit_all(reqs)
        assert len(replicas) == 30
        landed = [len(e.submitted) for e in engines]
        assert sum(landed) == 30           # conservation
        assert max(landed) - min(landed) <= 1   # JSQ keeps it level
        seen = sorted(r.req_id for e in engines for r in e.submitted)
        assert seen == list(range(30))     # each exactly once

    def test_kv_occupancy_reaches_policy(self):
        engines = [self._StubEngine(occ=0.9), self._StubEngine(occ=0.1)]
        rs = ReplicaSet(engines, policy="least_kv")
        rs.submit(self._Req(req_id=0))
        assert engines[1].submitted

    def test_rebalance_action_levels_queued_backlog(self):
        """ReplicaSet is a mitigation actuator: rebalance_replicas drains
        the skewed queues and re-deals them level (the command-bus target
        for the 3d row outside the simulator)."""
        engines = [self._StubEngine() for _ in range(3)]
        for e in engines:
            e.sched.submit = e.sched.queue.append
        rs = ReplicaSet(engines, policy="round_robin")
        # pile the whole backlog on replica 0
        for i in range(12):
            engines[0].sched.queue.append(
                dataclasses.replace(self._Req(req_id=i)))
        assert rs.apply_action("rebalance_replicas", -1, {})
        depths = [len(e.sched.queue) for e in engines]
        assert sum(depths) == 12            # conservation
        assert max(depths) - min(depths) <= 1
        # unknown per-engine knob on a stub engine: politely refused
        assert rs.apply_action("compress_kv", 1, {}) is False

    def test_apply_action_routes_through_node_replica_map(self):
        """Regression: detector findings carry cluster-NODE ids; indexing
        ``engines`` with one conflated node and replica coordinates when a
        replica spans several nodes."""
        calls = []

        class _Actuating(self._StubEngine):
            def apply_action(self, action, node, detail):
                calls.append((id(self), action, node))
                return True

        engines = [_Actuating(), _Actuating()]
        rs = ReplicaSet(engines, policy="round_robin", nodes_per_replica=2)
        assert rs.node_replica(0) == 0
        assert rs.node_replica(3) == 1
        assert rs.node_replica(4) is None     # off the cluster
        assert rs.node_replica(-1) is None    # cluster-wide
        # node 3 must actuate engine 1, never engines[3] (out of range) or
        # engines[... wrong replica]
        assert rs.apply_action("compress_kv", 3, {})
        assert calls and calls[-1][0] == id(engines[1])
        # out-of-range node: refused instead of silently mis-targeted
        assert rs.apply_action("compress_kv", 4, {}) is False

    def test_refresh_is_periodic_not_per_submit(self):
        """Regression: submit() used to re-snapshot every engine per
        request (O(n_replicas) per submit), defeating the staleness model;
        the view now publishes on refresh_period over the modeled link."""
        engines = [self._StubEngine() for _ in range(3)]
        rs = ReplicaSet(engines, policy="join_shortest_queue",
                        refresh_period=0.1)
        for i in range(20):
            rs.submit(self._Req(req_id=i), now=0.0)
        assert rs.view_link.sent == 1          # one publication, not 20
        rs.submit(self._Req(req_id=20), now=0.2)
        assert rs.view_link.sent == 2
        # conservation still holds: bumps carry the load between refreshes
        assert sum(len(e.submitted) for e in engines) == 21

    def test_view_rides_the_modeled_link(self):
        """The router only learns a snapshot when the link delivers it —
        staleness is measured from the transport, not configured."""
        engines = [self._StubEngine() for _ in range(2)]
        rs = ReplicaSet(engines, policy="join_shortest_queue",
                        view_link=LinkParams(delay=0.5),
                        refresh_period=0.05)
        rs.refresh(0.0)
        assert rs.router.view.latest_ts(0) == float("-inf")   # in flight
        rs.refresh(0.6)       # matured: the t=0 snapshot lands now
        assert rs.router.view.latest_ts(0) == 0.0
        assert rs.view_lag(0.6) == pytest.approx(0.6)


class TestReplicaSim:
    def test_replica_dimension_validates(self):
        with pytest.raises(ValueError):
            ClusterSim(SimParams(n_nodes=4, n_replicas=3), WorkloadSpec())

    def test_replica_tagged_telemetry(self):
        params = SimParams(n_nodes=4, n_replicas=2, duration=0.5)
        _, plane, sim = run_scenario(FaultSpec(start=1e9), params,
                                     WorkloadSpec(rate=100.0))
        replicas = {ev.replica for ev in plane.agent.stream
                    if ev.replica >= 0}
        assert replicas == {0, 1}
        # nodes 0,1 -> replica 0; nodes 2,3 -> replica 1
        for ev in plane.agent.stream:
            if ev.replica >= 0 and ev.node >= 0:
                assert ev.replica == ev.node // 2


@pytest.mark.slow
class TestHotReplicaClosedLoop:
    def test_hot_replica_fires_cross_replica_skew(self):
        sc = SCENARIOS["hot_replica"]
        _, plane, _ = run_scenario(dataclasses.replace(sc.fault),
                                   sc.params, sc.workload)
        fired = {f.name for f in plane.findings}
        assert "cross_replica_skew" in fired
        skew = [f for f in plane.findings if f.name == "cross_replica_skew"]
        # the hot replica must be named as the locus
        assert any(f.node == sc.fault.hot_replica for f in skew)

    def test_rebalance_reduces_p99_latency(self):
        """§5 closed loop on the DP layer: detection -> rebalance_replicas
        -> measurably better tail latency and more completions."""
        sc = SCENARIOS["hot_replica"]
        off, _, _ = run_scenario(dataclasses.replace(sc.fault),
                                 sc.params, sc.workload, mitigate=False)
        on, plane, sim = run_scenario(dataclasses.replace(sc.fault),
                                      sc.params, sc.workload, mitigate=True)
        assert any(a.action == "rebalance_replicas" for a in plane.actions)
        assert sim.fault.mitigated
        assert on.p(0.99) < 0.75 * off.p(0.99)
        assert on.p_ttft(0.99) < 0.75 * off.p_ttft(0.99)
        assert on.completed > off.completed

    def test_jsq_beats_round_robin_p99_ttft_under_bursty_skewed_load(self):
        """The router-table headline: queue-aware routing beats static
        rotation on tail TTFT when flows are skewed and arrivals bursty."""
        # rate 55 / seed 13: the np.random.Generator arrival stream needs
        # a partially-loaded regime for queue-aware routing to matter (at
        # 65/s this realization saturates every replica and JSQ ~= RR);
        # ratio holds at 0.72-0.83 across param seeds 3-11
        wl = WorkloadSpec(rate=55.0, duration=3.9, decode_mean=48,
                          decode_cv=0.6, burst_factor=8.0, flow_skew=1.2,
                          seed=13)
        results = {}
        for policy in ("round_robin", "join_shortest_queue"):
            params = SimParams(n_nodes=4, n_replicas=4,
                               router_policy=policy, duration=4.0, seed=3)
            m, _, _ = run_scenario(FaultSpec(start=1e9), params, wl)
            results[policy] = m
        jsq, rr = results["join_shortest_queue"], results["round_robin"]
        assert jsq.p_ttft(0.99) < 0.9 * rr.p_ttft(0.99)
        assert jsq.completed >= 0.95 * rr.completed


class TestRebalanceNodesActuator:
    def test_levels_queues_within_each_replica_only(self):
        sim = ClusterSim(SimParams(n_nodes=4, n_replicas=2),
                         WorkloadSpec(rate=1.0, duration=0.1))
        reqs = [Request(flow=i, arrival=i * 1e-3, prompt_len=8, decode_len=4)
                for i in range(12)]
        # pile replica 0's backlog on node 0, replica 1's on node 2
        for i, r in enumerate(reqs):
            node = 0 if i < 8 else 2
            r.node = node
            sim.queues[node].append(r)
            sim._queued_work[node] += max(r.decode_len, 1)
        assert sim.apply_action("rebalance_nodes", 0, {})
        depths = [len(q) for q in sim.queues]
        # leveled inside each replica; nothing crossed the replica boundary
        assert depths[0] + depths[1] == 8 and abs(depths[0] - depths[1]) <= 1
        assert depths[2] + depths[3] == 4 and abs(depths[2] - depths[3]) <= 1
        for n, q in enumerate(sim.queues):
            assert sim._queued_work[n] == sum(max(r.decode_len, 1)
                                              for r in q)
            for r in q:
                assert r.node == n


@pytest.mark.slow
class TestHierarchicalRoutingClosedLoop:
    def test_intra_replica_pin_fires_only_the_hierarchical_row(self):
        """Replica totals stay balanced under the symmetric pin, so 3d.1
        must stay silent while 3d.2 names the hot node."""
        sc = SCENARIOS["hierarchical_routing_skew"]
        _, plane, _ = run_scenario(dataclasses.replace(sc.fault),
                                   sc.params, sc.workload)
        fired = {f.name for f in plane.findings}
        assert "hierarchical_routing_skew" in fired
        assert "cross_replica_skew" not in fired
        hits = [f for f in plane.findings
                if f.name == "hierarchical_routing_skew"]
        # the locus is a replica's FIRST node (where the pin points)
        assert all(f.node % 2 == 0 for f in hits)

    def test_rebalance_nodes_mitigation_closes_the_loop(self):
        sc = SCENARIOS["hierarchical_routing_skew"]
        off, _, _ = run_scenario(dataclasses.replace(sc.fault),
                                 sc.params, sc.workload, mitigate=False)
        on, plane, sim = run_scenario(dataclasses.replace(sc.fault),
                                      sc.params, sc.workload, mitigate=True)
        assert any(a.action == "rebalance_nodes" for a in plane.actions)
        assert sim.fault.mitigated
        assert on.p_ttft(0.99) < off.p_ttft(0.99)
        assert on.completed >= off.completed
