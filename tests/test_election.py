"""Property-based safety tests for the leader-lease protocol
(``repro.dpu.election``).

The invariant the whole standby design rests on: **at most one sidecar
holds a valid lease at the current term at any instant**, no matter how
renewals, lost renewals (OOB partitions), revocations, grants, and time
advances interleave.  The arbiter enforces it through delivered-horizon
tracking — these tests hammer arbitrary interleavings against it.

Runs under hypothesis when installed, else the seeded fallback
(``proptest_fallback``) draws a fixed batch of examples.
"""

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover
    from proptest_fallback import given, settings, st

from repro.dpu import ElectionArbiter, LeaseParams

HOLDERS = ("primary", "standby", "host")

# one protocol step: (op, holder index, time delta).  dt spans sub-lease
# jitters up to multiple full lease horizons so expiry boundaries are hit.
step_strategy = st.tuples(
    st.sampled_from(["renew", "renew_lost", "revoke", "grant",
                     "grant_lost", "tick"]),
    st.integers(0, len(HOLDERS) - 1),
    st.floats(0.0, 0.3),
)


def _apply(arb: ElectionArbiter, now: float, step) -> float:
    op, hi, dt = step
    now += dt
    holder = HOLDERS[hi]
    if op == "renew":
        arb.renew(now)
    elif op == "renew_lost":
        arb.renew(now, delivered=False)
    elif op == "revoke":
        arb.revoke(holder, now)
    elif op == "grant":
        arb.grant(holder, now)
    elif op == "grant_lost":
        arb.grant(holder, now, delivered=False)
    # "tick": time advances, nothing else
    return now


class TestLeaseSafety:
    @given(st.lists(step_strategy, min_size=1, max_size=60))
    @settings(max_examples=120, deadline=None)
    def test_at_most_one_valid_holder_at_any_instant(self, steps):
        arb = ElectionArbiter(LeaseParams(lease_s=0.12))
        for h in HOLDERS:
            arb.register(h)
        now = 0.0
        arb.grant("primary", now)
        for step in steps:
            now = _apply(arb, now, step)
            # the invariant must hold at the instant of every state change
            # AND just inside every holder's expiry boundary
            instants = [now] + [
                lease.lease_until - 1e-9
                for lease in arb.leases.values()
                if lease.lease_until > now
            ]
            for t in instants:
                valid = arb.valid_holders(t)
                assert len(valid) <= 1, (
                    f"split brain at t={t:.4f}: {valid} "
                    f"(term {arb.registry.term})")

    @given(st.lists(step_strategy, min_size=1, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_terms_never_regress(self, steps):
        arb = ElectionArbiter(LeaseParams())
        for h in HOLDERS:
            arb.register(h)
        now, last_term = 0.0, 0
        arb.grant("primary", now)
        for step in steps:
            now = _apply(arb, now, step)
            assert arb.registry.term >= last_term
            last_term = arb.registry.term
            # no sidecar's local view may ever run ahead of the authority
            for lease in arb.leases.values():
                assert lease.term <= arb.registry.term

    @given(st.lists(step_strategy, min_size=1, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_valid_holder_matches_registry(self, steps):
        # whenever someone's lease is valid, it is the registry's holder:
        # the actuator's fencing view and the lease view never disagree
        arb = ElectionArbiter(LeaseParams())
        for h in HOLDERS:
            arb.register(h)
        now = 0.0
        arb.grant("primary", now)
        for step in steps:
            now = _apply(arb, now, step)
            for h in arb.valid_holders(now):
                assert h == arb.registry.holder
