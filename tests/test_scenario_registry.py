"""Registry completeness, table-driven over ``make_scenarios()``:

  1. every runbook row id has at least one fault-injection scenario,
  2. every scenario is bound to a registered runbook row (or is an
     explicitly-healthy baseline) and a registered controller action,
  3. every scenario's bound detector fires on its own injected fault —
     including scenarios beyond a row's canonical one (a row may have
     several realizations, e.g. the three 3d router faults).

This generalizes tests that check rows one-by-one: a scenario added to
``sim.faults`` without detector coverage, or a row whose scenario entry
rots, fails here by construction.
"""

import dataclasses

import pytest

from repro.core import ACTIONS, ALL_RUNBOOKS, BY_ID
from repro.sim import make_scenarios, run_scenario

FRESH = make_scenarios()


class TestRegistryCompleteness:
    def test_make_scenarios_is_deterministic(self):
        again = make_scenarios()
        assert set(again) == set(FRESH)
        for name, sc in FRESH.items():
            assert again[name].row_id == sc.row_id
            assert again[name].fault == sc.fault

    def test_every_row_has_a_scenario(self):
        rows_with_scenarios = {sc.row_id for sc in FRESH.values()
                               if sc.row_id}
        missing = {e.row_id for e in ALL_RUNBOOKS} - rows_with_scenarios
        assert not missing, f"runbook rows without scenarios: {missing}"

    def test_every_scenario_binds_a_registered_row_and_action(self):
        for name, sc in FRESH.items():
            if not sc.row_id:       # healthy baselines
                assert name.startswith("healthy")
                continue
            assert sc.row_id in BY_ID, f"{name}: unknown row {sc.row_id}"
            assert BY_ID[sc.row_id].action in ACTIONS

    def test_scenario_names_match_fault_names(self):
        for name, sc in FRESH.items():
            assert sc.name == name
            assert sc.fault.name in (name, "healthy")


@pytest.mark.slow
class TestEveryScenarioDetected:
    """The core falsifiability property, over ALL scenarios (not just each
    row's canonical one)."""

    @pytest.mark.parametrize(
        "name", [n for n, sc in FRESH.items() if sc.row_id])
    def test_bound_detector_fires_on_injected_fault(self, name):
        sc = FRESH[name]
        _, plane, _ = run_scenario(dataclasses.replace(sc.fault),
                                   sc.params, sc.workload)
        fired = {f.name for f in plane.findings}
        assert sc.row_id in fired, (
            f"{name}: expected {sc.row_id}, fired {sorted(fired)}")
