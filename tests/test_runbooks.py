"""Per-row validation of the paper's Tables 3(a)/(b)/(c): every runbook row
has a registered detector, a fault-injection scenario, and the detector
fires on its scenario while the healthy baseline stays silent.

This is the reproduction's core experiment (the paper itself is
qualitative; we make each row executable and falsifiable).
"""

import pytest

from repro.core import (
    ALL_DETECTORS,
    ALL_RUNBOOKS,
    BY_TABLE,
    ACTIONS,
    DetectorConfig,
    build_detectors,
)
from repro.core.events import (
    EAST_WEST,
    FORBIDDEN_OBSERVABLES,
    NORTH_SOUTH,
    PCIE,
    EventKind,
)
from repro.sim import SCENARIOS, run_scenario


class TestRegistry:
    """Registry size and wiring now live in one place:
    ``repro.lint.wiring`` (EXPECTED_TABLE_COUNTS + check_wiring).  These
    tests assert against that single source rather than re-hardcoding
    counts; the per-link invariants (detector bijection, scenario
    back-references, action registration, sibling realness, golden
    fixtures, smoke coverage) are all folded into the wiring pass."""

    def test_row_counts_match_declared(self):
        # the paper's 28 rows (3a/3b/3c) + the DP-routing extensions (3d)
        # + the DPU self-diagnosis row + the collective/rail/memory tier
        # (3e) + the monitoring-plane rows (mon) — per-table numbers are
        # declared once, in repro.lint.wiring.EXPECTED_TABLE_COUNTS
        from repro.lint.wiring import EXPECTED_TABLE_COUNTS, expected_rows
        assert len(ALL_RUNBOOKS) == expected_rows()
        for table, n in EXPECTED_TABLE_COUNTS.items():
            assert len(BY_TABLE[table]) == n, table

    def test_wiring_chain_is_clean(self):
        # the full static chain: detector class <-> row, >=1 scenario,
        # golden fixture, attribution rule, registered action,
        # CONFLICT_GROUPS ⊆ ACTIONS, real siblings, smoke-grid coverage
        # (modulo the exclusion pragmas in sim/faults.py, which
        # python -m repro.lint accounts for; here we only allow
        # smoke-coverage findings, everything else must be empty)
        from repro.lint.wiring import check_wiring
        hard = [f for f in check_wiring() if f.rule != "smoke-coverage"]
        assert not hard, "\n".join(f.format() for f in hard)

    def test_one_detector_per_row(self):
        from repro.lint.wiring import expected_rows
        dets = build_detectors()
        assert len(dets) == expected_rows()
        for entry in ALL_RUNBOOKS:
            assert entry.row_id in dets
            assert dets[entry.row_id].name == entry.row_id
            assert dets[entry.row_id].table == entry.table

    def test_every_row_has_scenario(self):
        for entry in ALL_RUNBOOKS:
            assert entry.scenario in SCENARIOS, entry.row_id
            assert SCENARIOS[entry.scenario].row_id == entry.row_id

    def test_detector_count_matches(self):
        from repro.lint.wiring import expected_rows
        assert len(ALL_DETECTORS) == expected_rows()

    def test_row_hit_accepts_declared_siblings_only(self):
        from repro.core.runbooks import row_hit
        # direct hit
        assert row_hit("tp_straggler", {"tp_straggler"})
        assert not row_hit("tp_straggler", {"early_completion_skew"})
        # the early-completion pair: the 3(a) skew row may legitimately
        # claim the decode_early_stop fault first (same physical signature)
        assert row_hit("decode_early_stop_skew", {"early_completion_skew"})
        # but not the reverse unless declared
        from repro.core.runbooks import BY_ID
        if not BY_ID["early_completion_skew"].sibling_rows:
            assert not row_hit("early_completion_skew",
                               {"decode_early_stop_skew"})

    def test_every_runbook_action_is_registered(self):
        # enforced statically by repro.lint.wiring (wiring-action rule);
        # this test documents the invariant where row authors will look
        orphans = {e.action for e in ALL_RUNBOOKS} - set(ACTIONS)
        assert not orphans, f"runbook actions missing from ACTIONS: {orphans}"


class TestObservabilityBoundary:
    """Paper §4.3: the DPU cannot see intra-device compute — enforce it."""

    def test_event_kinds_partition_into_three_vantages(self):
        all_kinds = set(EventKind)
        assert NORTH_SOUTH | PCIE | EAST_WEST == all_kinds
        assert not (NORTH_SOUTH & PCIE)
        assert not (PCIE & EAST_WEST)

    def test_no_intra_device_observables(self):
        import inspect
        from repro.core import events
        src = inspect.getsource(events).lower()
        for bad in FORBIDDEN_OBSERVABLES:
            # the names may appear only in the FORBIDDEN list itself
            occurrences = src.count(f'"{bad}"') + src.count(f"'{bad}'")
            assert src.count(bad) <= occurrences + 1, bad

    def test_detectors_only_consume_dpu_events(self):
        for det_cls in ALL_DETECTORS:
            for kind in det_cls.interested:
                assert isinstance(kind, EventKind)


@pytest.mark.slow
class TestPerRowDetection:
    """Inject each fault; assert its detector fires (28 scenarios)."""

    @pytest.mark.parametrize(
        "name", [s for s, sc in SCENARIOS.items() if sc.row_id])
    def test_scenario_detected(self, name):
        sc = SCENARIOS[name]
        metrics, plane, sim = run_scenario(sc.fault, sc.params, sc.workload)
        fired = {f.name for f in plane.findings}
        assert sc.row_id in fired, (
            f"{name}: expected {sc.row_id}, fired {sorted(fired)}")

    @pytest.mark.parametrize("name", ["healthy", "healthy_replicated"])
    def test_healthy_zero_false_positives(self, name):
        sc = SCENARIOS[name]
        metrics, plane, sim = run_scenario(sc.fault, sc.params, sc.workload)
        assert {f.name for f in plane.findings} == set()


class TestNeverFalseFire:
    """The 3(e) harness: every new row can fire (TestPerRowDetection covers
    that side) and never false-fires — silent on every healthy baseline,
    silent when the new emission tiers are switched on without a fault, and
    each new scenario trips only its own row among the new three."""

    NEW_ROWS = ("collective_straggler", "rail_congestion",
                "hbm_bandwidth_cliff")

    @pytest.mark.parametrize("name", ["healthy", "healthy_replicated"])
    def test_silent_on_baselines(self, name):
        sc = SCENARIOS[name]
        _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        assert not {f.name for f in plane.findings} & set(self.NEW_ROWS)

    def test_silent_with_emission_tiers_on(self):
        # healthy cluster, but every new telemetry tier enabled: the
        # per-collective rounds, the rail/NVLink-domain legs, and the HBM
        # knee (set above the healthy operating point)
        import dataclasses
        sc = SCENARIOS["healthy"]
        params = dataclasses.replace(sc.params, per_collective=True,
                                     rail_domain_size=2, hbm_knee=12)
        _, plane, _ = run_scenario(sc.fault, params, sc.workload)
        assert {f.name for f in plane.findings} == set()

    @pytest.mark.slow
    @pytest.mark.parametrize("name", NEW_ROWS)
    def test_new_scenarios_fire_only_their_row(self, name):
        sc = SCENARIOS[name]
        _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        fired = {f.name for f in plane.findings}
        assert sc.row_id in fired
        assert fired & set(self.NEW_ROWS) == {sc.row_id}


class TestMonNeverFalseFire:
    """The monitoring-plane rows watch the watcher, so their false-fire
    budget is the strictest: a spurious dpu_outage fails over the whole
    control plane.  Silent on every baseline, silent when the supervision
    machinery (watchdog probes, liveness pings, checksummed batches) is
    fully enabled on a healthy monitoring plane, and each chaos scenario
    trips only its own row — plus the one declared cascade (a DPU restart
    really does leave a telemetry gap behind)."""

    MON_ROWS = ("dpu_outage", "telemetry_blackout", "command_partition",
                "standby_lag", "split_brain_fenced")

    @pytest.mark.parametrize("name", ["healthy", "healthy_replicated"])
    def test_silent_on_baselines(self, name):
        sc = SCENARIOS[name]
        _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        assert not {f.name for f in plane.findings} & set(self.MON_ROWS)

    def test_silent_with_supervision_on(self):
        # healthy cluster under the full monitoring-plane stack: sidecar
        # with liveness pings and batch checksums, watchdog probing over
        # the OOB port.  Nothing may fire and the watchdog must never
        # fail over.
        import dataclasses
        from repro.dpu import DPUParams, LinkParams, WatchdogParams
        sc = SCENARIOS["healthy"]
        params = dataclasses.replace(
            sc.params, control="dpu",
            dpu=DPUParams(ping_every=0.02,
                          uplink=LinkParams(delay=1e-3, corrupt_p=1e-9)),
            watchdog=WatchdogParams())
        _, plane, _ = run_scenario(sc.fault, params, sc.workload)
        assert {f.name for f in plane.findings} == set()
        assert plane.failovers == 0
        assert plane.sidecar.guard.gaps == 0

    @pytest.mark.slow
    @pytest.mark.parametrize("name", MON_ROWS)
    def test_mon_scenarios_fire_only_their_row(self, name):
        sc = SCENARIOS[name]
        _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        fired = {f.name for f in plane.findings}
        assert sc.row_id in fired
        # the restart path legitimately cascades: a crashed-then-restarted
        # DPU resumes mid-stream, and that sequence gap IS a blackout
        allowed = {sc.row_id}
        if name == "dpu_outage":
            allowed.add("telemetry_blackout")
        elif name == "standby_lag":
            # the standby's own uplink blackout latches its (merged-in)
            # blackout self-telemetry — same physical gap, second vantage
            allowed.add("telemetry_blackout")
        elif name == "split_brain_fenced":
            # the downlink partition that blinds the corroborating probe
            # also burns the primary's ping retries (its own obituary),
            # and the OOB heartbeat silence reads as an outage
            allowed.update({"command_partition", "dpu_outage"})
        assert fired & set(self.MON_ROWS) <= allowed

    def test_silent_with_hot_standby_on(self):
        # healthy cluster under the *redundant* monitoring-plane stack: a
        # hot standby shadowing the tap, lease renewals every probe.  No
        # findings, no promotion, no fencing, and the primary must still
        # hold the original term at the end.
        import dataclasses
        from repro.dpu import DPUParams, WatchdogParams
        sc = SCENARIOS["healthy"]
        params = dataclasses.replace(
            sc.params, control="dpu",
            dpu=DPUParams(ping_every=0.02),
            standby=DPUParams(), watchdog=WatchdogParams())
        _, plane, _ = run_scenario(sc.fault, params, sc.workload)
        assert {f.name for f in plane.findings} == set()
        assert plane.failovers == 0
        assert plane.promotions == 0
        assert plane.arbiter.registry.term == 1
        assert plane.arbiter.registry.holder == "primary"
        assert len(plane.arbiter.registry.fenced) == 0


class TestAttribution:
    def test_host_symptom_localizes_host_side(self):
        sc = SCENARIOS["host_cpu_bottleneck"]
        _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        loci = {a.locus for a in plane.attributions}
        # §4.2: E-W straggler symptom must NOT be blamed on the network
        assert "internode_network" not in loci
        assert loci & {"host_cpu", "pcie_transfer", "device_scheduling"}

    def test_egress_stall_with_healthy_pcie_is_network_side(self):
        sc = SCENARIOS["egress_backlog"]
        _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        prim = [a for a in plane.attributions
                if a.primary.name == "egress_backlog_queueing"]
        assert prim and all(a.locus == "egress_path" for a in prim)

    def test_early_stop_is_workload_locus(self):
        sc = SCENARIOS["early_completion"]
        _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        prim = [a for a in plane.attributions
                if a.primary.name == "early_completion_skew"]
        assert prim and all(a.locus == "workload_shape" for a in prim)


class TestMitigationClosedLoop:
    def test_early_completion_mitigation_improves_throughput(self):
        import dataclasses
        sc = SCENARIOS["early_completion"]
        off, _, _ = run_scenario(dataclasses.replace(sc.fault),
                                 sc.params, sc.workload, mitigate=False)
        on, plane, _ = run_scenario(dataclasses.replace(sc.fault),
                                    sc.params, sc.workload, mitigate=True)
        assert plane.actions, "controller issued no actions"
        t_off = off.throughput(sc.params.duration)
        t_on = on.throughput(sc.params.duration)
        assert t_on > 1.5 * t_off
        assert on.idle_frac() < off.idle_frac()

    def test_hysteresis_requires_confirmation(self):
        from repro.core.mitigation import MitigationController, NullEngine
        from repro.core.attribution import Attribution
        from repro.core.detectors import Finding
        eng = NullEngine()
        ctl = MitigationController(eng, confirmations=2)
        f = Finding(name="tp_straggler", table="3c", ts=1.0,
                    severity="warn", node=1, device=-1, stage="s",
                    root_cause="r", directive="d", score=5.0)
        a = Attribution(ts=1.0, locus="device_scheduling", node=1,
                        confidence=0.9, primary=f, supporting=(),
                        narrative="n")
        assert ctl.consider(a) is None          # first sighting: hold
        assert ctl.consider(a) is not None      # confirmed: actuate
        assert eng.calls
