"""Benchmarks must not rot: run ``benchmarks/run.py --only <table>`` for one
cheap table per family and assert zero ERROR rows.

Families and their cheap representatives:
  telemetry-overhead -> table2_signals
  columnar ingest    -> telemetry_perf (batched vs per-event, 3a mix)
  producer synthesis -> sim_perf      (columnar vs scalar_synth; smoke
                        scale via SIM_PERF_SCALE/REPS so the suite stays
                        bounded — CI's bench step runs the larger scale)
  per-row detection  -> table3d      (1 row + healthy baseline)
  router policies    -> router       (4 sim runs, no model compile)
  closed-loop        -> mitigation   (sim only)
  control topology   -> control_loop (dpu vs instant vs none; smoke grid
                        via CONTROL_LOOP_SCENARIOS — CI's bench step runs
                        the whole registry)
  artifact readouts  -> roofline     (pure file scan; 'missing' row is fine)

The jax-compiling tables (table1, serving, kernels) are exercised by their
own unit/integration tests; compiling them again here would double suite
time for no added coverage.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# sim_perf is exercised by its dedicated assertion test below (running it
# in the family sweep too would double its cost for no added coverage)
CHEAP_TABLES = ["table2_signals", "telemetry_perf", "table3d", "router",
                "mitigation", "roofline"]

# control_loop smoke grid: one scenario only the DPU path can recover
# (d2h_bottleneck: per-node hysteresis can never confirm its one-shot
# findings), one both paths recover (early_completion), one whose fault
# is claimed first by a declared sibling row (decode_early_stop ->
# early_completion_skew; exercises the row_hit sibling gate), one
# healthy baseline for the zero-false-positive-actions property, and
# the two hot-standby mon rows (structural standby pair in their
# params; only the dpu cell can see their faults, which exercises the
# instant-unrecovered accounting)
CONTROL_LOOP_SMOKE = ("early_completion,d2h_bottleneck,decode_early_stop,"
                      "standby_lag,split_brain_fenced,healthy")


def _run_only(only: str) -> str:
    env = {**os.environ,
           "PYTHONPATH": SRC + os.pathsep + REPO,
           # sim_perf: tiny synthesis grid + smoke sweep in the suite;
           # CI's bench step runs the larger scale and the full registry
           "SIM_PERF_SCALE": "2", "SIM_PERF_REPS": "1",
           "SIM_PERF_SWEEP": "smoke",
           "CONTROL_LOOP_SCENARIOS": CONTROL_LOOP_SMOKE}
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--only", only],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (
        f"--only {only} exited {out.returncode}:\n"
        f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("only", CHEAP_TABLES)
def test_table_family_has_no_error_rows(only):
    stdout = _run_only(only)
    lines = [ln for ln in stdout.strip().splitlines() if ln]
    assert lines and lines[0].startswith("name,"), stdout[:500]
    rows = lines[1:]
    assert rows, f"--only {only} produced no rows"
    errors = [r for r in rows if "/ERROR," in r]
    assert not errors, f"ERROR rows from --only {only}: {errors}"


@pytest.mark.slow
def test_telemetry_perf_batched_faster_and_identical():
    """Columnar ingest must beat the per-event path by a wide margin AND
    produce bit-identical findings.  The benchmark's own headline target is
    >= 10x on an idle box; assert a conservative floor here so a noisy,
    throttled CI runner can't flake the suite."""
    stdout = _run_only("telemetry_perf")
    rows = {}
    for line in stdout.strip().splitlines()[1:]:
        name, _, derived = line.split(",", 2)
        rows[name.split("/", 1)[1]] = dict(
            kv.split("=", 1) for kv in derived.split(";"))
    assert rows["scalar"]["identical_findings"] == "1"
    assert rows["batched"]["identical_findings"] == "1"
    speedup = float(rows["scalar"]["batched_speedup"])
    assert speedup >= 4.0, f"batched ingest only {speedup}x over per-event"


@pytest.mark.slow
def test_sim_perf_columnar_faster_with_identical_traces_and_golden():
    """Producer-plane acceptance, asserted on the benchmark output: the
    vectorized synthesis must beat the per-event reference even at the
    tiny smoke scale (the margin grows with cluster size — CI's bench
    step runs SIM_PERF_SCALE=8; see README for the line-rate numbers),
    with the identical event multiset and golden finding parity."""
    stdout = _run_only("sim_perf")
    rows = {}
    for line in stdout.strip().splitlines()[1:]:
        name, _, derived = line.split(",", 2)
        rows[name.split("/", 1)[1]] = dict(
            kv.split("=", 1) for kv in derived.split(";"))
    col = rows["columnar"]
    assert col["identical_traces"] == "1"
    assert col["golden_parity"] == "1"
    assert float(col["speedup"]) >= 1.3, (
        f"columnar synthesis only {col['speedup']}x over scalar reference")
    sweep = rows["registry_sweep"]
    assert sweep["hit_rate"] == "1.000"
    assert sweep["healthy_false_positives"] == "0"


@pytest.mark.slow
def test_control_loop_dpu_recovers_and_pays_measured_latency():
    """The DPU control-plane acceptance, asserted on the benchmark output:
    dpu mode recovers every scenario in the smoke grid (including the one
    instant mode cannot), takes zero actions on the healthy baseline, and
    its time-to-mitigate is strictly greater than instant's wherever both
    recover — the feedback path's cost is measured, not assumed."""
    stdout = _run_only("control_loop")
    rows = {}
    for line in stdout.strip().splitlines()[1:]:
        name, _, derived = line.split(",", 2)
        rows[name.split("/", 1)[1]] = dict(
            kv.split("=", 1) for kv in derived.split(";"))
    summ = rows["summary"]
    assert summ["dpu_hit_rate"] == "1.000"
    assert summ["dpu_recovered_all"] == "1"
    assert summ["dpu_ttm_gt_instant"] == "1"
    assert summ["healthy_fp_actions"] == "0"
    # per-cell spot checks behind the summary flags
    assert rows["d2h_bottleneck/instant"]["recovered"] == "0"
    assert rows["d2h_bottleneck/dpu"]["recovered"] == "1"
    # sibling-gate regression: the early_completion_skew sibling claims
    # this fault first, yet the cell still counts as hit + recovered
    # (before row_hit this was the registry's one standing gate failure)
    assert rows["decode_early_stop/dpu"]["hit"] == "1"
    assert rows["decode_early_stop/dpu"]["recovered"] == "1"
    assert (float(rows["early_completion/dpu"]["t_recover_s"])
            > float(rows["early_completion/instant"]["t_recover_s"]) > 0)
    assert rows["healthy/dpu"]["actions"] == "0"


@pytest.mark.slow
def test_router_table_acceptance_headlines():
    """Both router acceptance headlines, asserted on the benchmark output:
    queue-aware beats static rotation on tail TTFT (general lane), and
    prefix affinity beats flat JSQ on the prefix-heavy lane while its
    load-ceiling spill holds routed imbalance <= 1.25."""
    stdout = _run_only("router")
    rows = {}
    for line in stdout.strip().splitlines()[1:]:
        name, _, derived = line.split(",", 2)
        rows[name.split("/", 1)[1]] = dict(
            kv.split("=", 1) for kv in derived.split(";"))
    assert (float(rows["join_shortest_queue"]["p99_ttft_ms"])
            < float(rows["round_robin"]["p99_ttft_ms"]))
    summ = rows["prefix/summary"]
    assert summ["affinity_beats_jsq_p99"] == "1"
    assert summ["imbalance_ok"] == "1"
    assert (float(rows["prefix/prefix_affinity"]["prefix_hit_rate"])
            > float(rows["prefix/join_shortest_queue"]["prefix_hit_rate"]))
