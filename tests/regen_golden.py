"""Regenerate the golden per-scenario fixtures in ``tests/golden/``.

The fixture captures, for every registry scenario, the detector findings
and SimMetrics produced by the **scalar-synthesis reference path**
(``SimParams.scalar_synth=True``) at canonical scale.  The vectorized
producer must reproduce it bit-for-bit (``tests/test_sim_columnar.py``;
``benchmarks sim_perf`` asserts the same in-bench).

Regenerate ONLY when an intentional change to the simulator/workload/
detectors shifts the reference behavior::

    PYTHONPATH=src python tests/regen_golden.py
"""

from __future__ import annotations

import json
import os

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "scenario_findings.json")


def generate() -> dict:
    from repro.sim import SCENARIOS
    from repro.sim.cluster import run_scenario

    scenarios = {}
    for name in sorted(SCENARIOS):
        sc = SCENARIOS[name].variant(scalar_synth=True)
        m, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        scenarios[name] = {
            "row_id": sc.row_id,
            "findings": [[f.name, f.node, f.ts, f.severity, f.score]
                         for f in plane.findings],
            "metrics": {
                "completed": m.completed,
                "tokens_out": m.tokens_out,
                "first_finding_ts": m.first_finding_ts,
                "p50_latency": m.p(0.5),
                "p99_latency": m.p(0.99),
                "p50_ttft": m.p_ttft(0.5),
                "p99_ttft": m.p_ttft(0.99),
            },
        }
    return {
        "format": 1,
        "note": ("scalar-synthesis reference findings/metrics per scenario;"
                 " regenerate with tests/regen_golden.py"),
        "scenarios": scenarios,
    }


def main() -> None:
    data = generate()
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as fh:
        json.dump(data, fh, indent=1, sort_keys=True)
        fh.write("\n")
    n = sum(len(s["findings"]) for s in data["scenarios"].values())
    print(f"wrote {GOLDEN_PATH}: {len(data['scenarios'])} scenarios, "
          f"{n} findings")


if __name__ == "__main__":
    main()
