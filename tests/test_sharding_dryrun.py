"""Sharding-rule unit tests + a small-mesh dry-run smoke executed in a
subprocess (so XLA_FLAGS device-count forcing never leaks into this test
process, which must keep seeing 1 CPU device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_main_process_sees_one_device():
    assert len(jax.devices()) == 1


class TestFit:
    def test_drops_nondividing_axes(self):
        from jax.sharding import PartitionSpec as P
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax
            from jax.sharding import PartitionSpec as P
            from repro.parallel.sharding import fit
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            # batch=1 cannot shard over data
            assert fit(mesh, (1, 64), (("data",), "model")) == P(None, "model")
            # dim divisible by both axes keeps both
            assert fit(mesh, (8, 64), (("data", "model"), None)) == \\
                P(("data", "model"), None)
            # 6 divisible by 2 but not 4
            assert fit(mesh, (6, 12), ("data", "model")) == P("data", "model")
            assert fit(mesh, (6, 2), ("data", "model")) == P("data", None)
            print("FIT_OK")
        """)
        out = subprocess.run(
            [sys.executable, "-c", code],
            env={**os.environ, "PYTHONPATH": SRC},
            capture_output=True, text=True, timeout=300)
        assert "FIT_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_dryrun_small_mesh_all_families():
    """Lower+compile one cell per family on an 8-device mesh (subprocess)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.models import build_model
        from repro.parallel.sharding import MeshRules
        from repro.models.model import ShapeSpec
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = MeshRules(mesh, fsdp=True)
        for arch in ["llama3.2-3b", "qwen2-moe-a2.7b", "zamba2-7b",
                     "xlstm-125m", "seamless-m4t-large-v2",
                     "llava-next-mistral-7b"]:
            cfg = ARCHS[arch].reduced(d_model=256, n_heads=8, n_kv_heads=4,
                                      head_dim=32, vocab=1024)
            m = build_model(cfg)
            ps = jax.eval_shape(m.init, jax.random.key(0))
            psh = rules.shardings_of(rules.param_specs(ps))
            shape = ShapeSpec("t", "train", 64, 8)
            specs = m.input_specs(shape)
            bsh = rules.shardings_of(rules.batch_specs(specs["batch"]))
            def loss(p, b):
                return m.loss(p, b, shard=rules)
            with mesh:
                c = jax.jit(loss, in_shardings=(psh, bsh)).lower(
                    ps, specs["batch"]).compile()
            cost = c.cost_analysis()
            if isinstance(cost, (list, tuple)):   # older jax: per-device list
                cost = cost[0] if cost else {}
            assert cost["flops"] > 0
            # decode too
            dshape = ShapeSpec("d", "decode", 64, 8)
            dspecs = m.input_specs(dshape)
            csh = rules.shardings_of(rules.cache_specs(dspecs["cache"]))
            tsh = rules.shardings_of(rules.batch_specs(
                {"tokens": dspecs["tokens"]}))["tokens"]
            def step(p, t, c_):
                return m.decode_step(p, t, c_, shard=rules)
            with mesh:
                jax.jit(step, in_shardings=(psh, tsh, csh)).lower(
                    ps, dspecs["tokens"], dspecs["cache"]).compile()
            print("OK", arch)
        print("DRYRUN_SMALL_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=1200)
    assert "DRYRUN_SMALL_OK" in out.stdout, \
        out.stdout[-1500:] + out.stderr[-3000:]


@pytest.mark.slow
def test_pipeline_parallel_over_pod_axis():
    """GPipe over a 2-stage 'pod' axis matches the sequential reference."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_forward, bubble_fraction
        mesh = jax.make_mesh((2, 4), ("pod", "model"))
        n_stages, n_micro, mb, d = 2, 4, 2, 16
        key = jax.random.key(0)
        w = jax.random.normal(key, (n_stages, d, d)) * 0.1
        x = jax.random.normal(jax.random.key(1), (n_micro, mb, d))
        def stage_fn(params, x):
            return jnp.tanh(x @ params["w"])
        outs = pipeline_forward(stage_fn, {"w": w}, x, mesh=mesh, axis="pod")
        # sequential reference
        want = x
        for s in range(n_stages):
            want = jnp.tanh(want @ w[s])
        np.testing.assert_allclose(np.asarray(outs), np.asarray(want),
                                   atol=1e-5)
        assert abs(bubble_fraction(2, 4) - 0.2) < 1e-9
        print("PIPELINE_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in out.stdout, \
        out.stdout[-1500:] + out.stderr[-3000:]


@pytest.mark.slow
def test_elastic_remesh_preserves_values():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.sharding import MeshRules
        from repro.training.elastic import plan_remesh, remesh
        old = jax.make_mesh((4, 2), ("data", "model"))
        rules = MeshRules(old)
        params = {"wq": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        sh = rules.shardings_of(rules.param_specs(params))
        params = jax.tree.map(jax.device_put, params, sh)
        plan = plan_remesh(old, failed_nodes=2)
        assert plan.new_shape["data"] == 2 and plan.micro_scale == 2
        new_mesh = jax.make_mesh((2, 2), ("data", "model"))
        new_params, _ = remesh(params, rules, new_mesh)
        np.testing.assert_array_equal(np.asarray(new_params["wq"]),
                                      np.arange(64).reshape(8, 8))
        print("ELASTIC_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=600)
    assert "ELASTIC_OK" in out.stdout, \
        out.stdout[-1500:] + out.stderr[-3000:]
