"""Run every runbook row's fault scenario and print the full drill-down:
detection, latency, attribution locus, and the paper's mitigation
directive — Tables 3(a)/(b)/(c) as a live demo.

Run:  PYTHONPATH=src python examples/pathology_drilldown.py [row_id]
"""

import sys

from repro.core.runbooks import ALL_RUNBOOKS, BY_ID
from repro.sim import SCENARIOS, run_scenario


def drill(row_id: str) -> None:
    entry = BY_ID[row_id]
    sc = SCENARIOS[entry.scenario]
    print(f"\n=== {entry.table} — {entry.title} ===")
    print(f"signal     : {entry.signal}")
    print(f"injecting  : scenario '{entry.scenario}' "
          f"(fault starts t={sc.fault.start}s)")
    metrics, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
    hits = [f for f in plane.findings if f.name == row_id]
    if not hits:
        print("!! detector did not fire")
        return
    f = hits[0]
    print(f"detected   : t={f.ts:.2f}s severity={f.severity} "
          f"node={f.node} score={f.score:.1f}")
    if metrics.first_finding_ts > 0:
        print(f"latency    : {metrics.first_finding_ts - sc.fault.start:.2f}s "
              "after onset")
    atts = [a for a in plane.attributions if a.primary.name == row_id]
    if atts:
        print(f"attribution: {atts[0].locus} — {atts[0].narrative}")
    print(f"root cause : {entry.root_cause}")
    print(f"mitigation : {entry.mitigation}")


def main() -> None:
    if len(sys.argv) > 1:
        drill(sys.argv[1])
        return
    for entry in ALL_RUNBOOKS:
        drill(entry.row_id)


if __name__ == "__main__":
    main()
