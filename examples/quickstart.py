"""Quickstart: the paper's pipeline in 60 seconds (CPU, reduced scale).

1. Build a model from the assigned-architecture registry.
2. Serve a few requests on the continuous-batching engine with the
   DPU-analog telemetry plane attached.
3. Inject a pathology in the cluster simulator, watch the runbook
   detector fire, the §4.2 attributor localize it, and the §5 mitigation
   controller fix it.
4. Route a skewed workload across data-parallel replicas and watch the
   cross-replica router + the 3d closed loop at work.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import random

import jax

from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import EngineConfig, InferenceEngine, ServeRequest
from repro.sim import SCENARIOS, run_scenario


def main() -> None:
    # ---- 1. a model from the zoo --------------------------------------
    cfg = ARCHS["llama3.2-3b"].reduced()     # same family, smoke width
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    print(f"model: {cfg.name} ({cfg.family}), "
          f"full-size params would be {ARCHS['llama3.2-3b'].param_count():.2e}")

    # ---- 2. serve with telemetry --------------------------------------
    engine = InferenceEngine(model, params, EngineConfig(
        max_slots=4, max_seq=128, n_pages=128, page_size=16))
    rng = random.Random(0)
    requests = [ServeRequest(
        req_id=i, arrival=i * 0.003,
        prompt=[rng.randrange(cfg.vocab) for _ in range(rng.randrange(8, 32))],
        max_new_tokens=rng.randrange(4, 12)) for i in range(10)]
    report = engine.run(requests)
    print(f"served {report['completed']} requests, "
          f"{report['tokens_per_step']:.2f} tok/step, "
          f"p50 latency {report['p50_latency'] * 1e3:.1f} ms, "
          f"telemetry {report['telemetry']['events']} events, "
          f"{report['telemetry']['findings']} findings (healthy => 0)")

    # ---- 3. pathology -> detect -> attribute -> mitigate ---------------
    sc = SCENARIOS["tp_straggler"]
    metrics, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
    finding = next(f for f in plane.findings if f.name == "tp_straggler")
    att = next(a for a in plane.attributions
               if a.primary.name == "tp_straggler")
    print(f"\ninjected: TP straggler on node {sc.fault.straggler_node}")
    print(f"detected: '{finding.name}' on node {finding.node} "
          f"(severity {finding.severity}, "
          f"{metrics.first_finding_ts - sc.fault.start:.2f}s after onset)")
    print(f"attributed: locus={att.locus} — {att.narrative}")
    print(f"runbook directive: {finding.directive}")

    # ---- 4. data-parallel routing: hot replica -> rebalance -------------
    sc = SCENARIOS["hot_replica"]
    off, _, _ = run_scenario(dataclasses.replace(sc.fault), sc.params,
                             sc.workload, mitigate=False)
    on, plane, _ = run_scenario(dataclasses.replace(sc.fault), sc.params,
                                sc.workload, mitigate=True)
    print(f"\ninjected: affinity pinning {sc.fault.hot_replica_frac:.0%} of "
          f"flows onto replica {sc.fault.hot_replica}")
    acts = [a.action for a in plane.actions]
    print(f"closed loop: actions={acts}")
    print(f"p99 latency {off.p(0.99) * 1e3:.0f} ms -> "
          f"{on.p(0.99) * 1e3:.0f} ms, completions "
          f"{off.completed} -> {on.completed}")


if __name__ == "__main__":
    main()
