"""End-to-end training driver: train a ~100M-param qwen3-family model for a
few hundred steps on synthetic packed data, with checkpoint/restart,
gradient compression, and step telemetry.

CPU demo (default): a reduced model, 40 steps.
Full:  --full trains the real ~100M config (slow on CPU; sized for 1 host).

Run:  PYTHONPATH=src python examples/train_100m.py [--full] [--steps N]
"""

import argparse
import dataclasses

import jax

from repro.configs import ARCHS
from repro.data import DataConfig, Prefetcher, SyntheticCorpus, pack_documents
from repro.models import build_model
from repro.training import AdamWConfig, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params instead of the smoke model")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--ckpt", default="/tmp/repro_train_100m")
    args = ap.parse_args()

    base = ARCHS["qwen3-0.6b"]
    if args.full:
        # ~100M-param family member: 12 layers, d=768, vocab 32k
        cfg = dataclasses.replace(
            base, name="qwen3-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32768,
            dtype="float32", remat=False)
        batch, seq = 8, 512
    else:
        cfg = base.reduced()
        batch, seq = 8, 64
    print(f"training {cfg.name}: ~{cfg.param_count():.2e} params")

    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, batch=batch, seed=0)
    data = Prefetcher(pack_documents(SyntheticCorpus(dcfg),
                                     args.steps + 8))
    tcfg = TrainConfig(steps=args.steps, n_micro=2, compress_grads=True,
                       ckpt_dir=args.ckpt, ckpt_every=max(args.steps // 4, 1),
                       optimizer=AdamWConfig(lr=3e-4, warmup_steps=10,
                                             total_steps=args.steps))
    trainer = Trainer(model, params, tcfg)
    if trainer.maybe_restore():
        print(f"resumed from checkpoint at step {trainer.step}")
    hist = trainer.run(data)
    if hist:
        print(f"step {hist[0]['step']}: loss {hist[0]['loss']:.3f}  ->  "
              f"step {hist[-1]['step']}: loss {hist[-1]['loss']:.3f}")
        print(f"mean step time {sum(h['sec'] for h in hist) / len(hist):.3f}s,"
              f" checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
