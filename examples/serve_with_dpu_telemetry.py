"""Serving demo with an induced pathology on the LIVE engine: a skewed
workload (a few very long generations among short ones) under static
batching starves decode slots — the paper's 'early completion skew' — and
the telemetry plane detects it and flips the engine to continuous batching
(inflight remap), recovering throughput.

Run:  PYTHONPATH=src python examples/serve_with_dpu_telemetry.py
"""

import random

import jax

from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import EngineConfig, InferenceEngine, ServeRequest


def make_requests(cfg, n=16):
    rng = random.Random(7)
    # one long generation per 4 short ones: under static batching the long
    # one pins its batch while 3 slots idle for ~0.4 s — long enough for
    # the windowed early-completion detector to confirm the decay
    return [ServeRequest(
        req_id=i, arrival=0.0,
        prompt=[rng.randrange(cfg.vocab) for _ in range(8)],
        max_new_tokens=(200 if i % 4 == 0 else 4)) for i in range(n)]


def main() -> None:
    cfg = ARCHS["qwen3-0.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    print("--- static batching (pathological: no remap of freed slots) ---")
    eng = InferenceEngine(model, params, EngineConfig(
        max_slots=4, max_seq=128, n_pages=256, telemetry=True,
        mitigate=False))
    eng.sched.set_continuous(False)
    rep_static = eng.run(make_requests(cfg), max_steps=800)
    print(f"steps={rep_static['steps']} "
          f"tok/step={rep_static['tokens_per_step']:.2f} "
          f"findings={rep_static['telemetry']['findings_by_row']}")

    print("\n--- same workload, mitigation controller ON ---")
    eng2 = InferenceEngine(model, params, EngineConfig(
        max_slots=4, max_seq=128, n_pages=256, telemetry=True,
        mitigate=True))
    eng2.sched.set_continuous(False)       # starts in the pathological mode
    rep_mit = eng2.run(make_requests(cfg), max_steps=800)
    acts = rep_mit["telemetry"]["actions"]
    print(f"steps={rep_mit['steps']} "
          f"tok/step={rep_mit['tokens_per_step']:.2f} "
          f"actions={[(round(t, 3), a) for t, a, _ in acts]}")
    if rep_mit["steps"] < rep_static["steps"]:
        print(f"\nmitigation recovered "
              f"{rep_static['steps'] - rep_mit['steps']} decode steps "
              f"({(1 - rep_mit['steps'] / rep_static['steps']) * 100:.0f}% "
              "fewer): the closed loop works.")

    print("\n--- same loop through the modeled DPU sidecar ---")
    eng3 = InferenceEngine(model, params, EngineConfig(
        max_slots=4, max_seq=128, n_pages=256, telemetry=True,
        mitigate=True, control="dpu"))
    eng3.sched.set_continuous(False)       # starts in the pathological mode
    rep_dpu = eng3.run(make_requests(cfg), max_steps=800)
    acts = rep_dpu["telemetry"]["actions"]
    print(f"steps={rep_dpu['steps']} "
          f"tok/step={rep_dpu['tokens_per_step']:.2f} "
          f"actions={[(round(t, 3), a) for t, a, _ in acts]}")
    print(f"sidecar: {eng3.dpu.report()}")
    if rep_dpu["steps"] < rep_static["steps"]:
        print("the asynchronous loop recovers too — a few steps later "
              "than the instant controller (the commands rode a wire).")


if __name__ == "__main__":
    main()
