"""Benchmark harness: one function per paper table. Prints
``name,us_per_call,derived`` CSV rows (see tables.py for definitions);
``--json PATH`` additionally writes the rows as a JSON artifact (used by CI
to archive benchmark history); ``--seed N`` threads a seed into every table
function that accepts one, so perf rows are reproducible run-to-run."""

import argparse
import inspect
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on table function names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON array to PATH")
    ap.add_argument("--seed", type=int, default=None,
                    help="seed for tables with a seed parameter")
    args = ap.parse_args()

    from benchmarks.tables import ALL_TABLES
    print("name,us_per_call,derived")
    failures = 0
    records = []
    for fn in ALL_TABLES:
        if args.only and args.only not in fn.__name__:
            continue
        kwargs = {}
        if (args.seed is not None
                and "seed" in inspect.signature(fn).parameters):
            kwargs["seed"] = args.seed
        try:
            for name, us, derived in fn(**kwargs):
                print(f"{name},{us:.1f},{derived}", flush=True)
                records.append({"name": name, "us_per_call": us,
                                "derived": derived})
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__}/ERROR,0,{type(e).__name__}: {e}",
                  flush=True)
            records.append({"name": f"{fn.__name__}/ERROR",
                            "us_per_call": 0.0,
                            "derived": f"{type(e).__name__}: {e}"})
    if args.json:
        out_dir = os.path.dirname(args.json)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(records, fh, indent=2)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
