"""Benchmark harness: one function per paper table. Prints
``name,us_per_call,derived`` CSV rows (see tables.py for definitions)."""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on table function names")
    args = ap.parse_args()

    from benchmarks.tables import ALL_TABLES
    print("name,us_per_call,derived")
    failures = 0
    for fn in ALL_TABLES:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__}/ERROR,0,{type(e).__name__}: {e}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
