"""Benchmark implementations — one function per paper table/figure.

Each returns a list of (name, us_per_call, derived) rows; run.py prints CSV.

  table1_archzoo    — Table 1 analog: the open-weight model zoo as runnable
                      configs (reduced fwd-step timing per arch)
  table2_signals    — Table 2(b): telemetry signal collection overhead
  table3a/b/c       — Tables 3(a)/(b)/(c): per-row detection latency,
                      hit/miss, and healthy-run false positives
  mitigation_loop   — §5 closed loop: throughput/latency with mitigation
                      off vs on
  control_loop      — closed-loop topology comparison (dpu / instant /
                      none): time-to-detect/actuate/recover + p99 per
                      scenario; CONTROL_LOOP_SCENARIOS narrows the grid
  kernels_bench     — Pallas kernel hot spots vs jnp oracle (CPU interpret
                      overhead is not meaningful; we time the oracle path
                      and validate the kernel separately)
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, n=5, warmup=2, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / n * 1e6     # us


# ----------------------------------------------------------------------

def table1_archzoo() -> list[tuple]:
    """Reduced-config forward-step timing for every assigned architecture."""
    from repro.configs import ARCHS, ASSIGNED
    from repro.models import build_model
    rows = []
    for arch in ASSIGNED:
        cfg = ARCHS[arch].reduced()
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        toks = jnp.ones((2, 32), jnp.int32)
        batch = {"tokens": toks}
        if cfg.family == "encdec":
            batch["frontend"] = jnp.ones((2, 16, cfg.d_model))
        if cfg.family == "vlm":
            batch["frontend"] = jnp.ones((2, cfg.frontend_tokens,
                                          cfg.d_model))
        fwd = jax.jit(lambda p, b: m.forward(p, b)[0])
        fwd(params, batch).block_until_ready()
        us = _time(lambda: fwd(params, batch).block_until_ready(), n=5)
        rows.append((f"table1/{arch}", us,
                     f"params_full={ARCHS[arch].param_count():.3e}"))
    return rows


def table2_signals() -> list[tuple]:
    """Telemetry plane overhead: ns/event with the full detector set live."""
    import random
    from repro.core import ALL_DETECTORS, TelemetryPlane
    from repro.core.events import Event, EventKind
    rows = []
    for tables, label in ((("3a",), "ns_table3a"),
                          (("3a", "3b", "3c", "3d"),
                           f"full_{len(ALL_DETECTORS)}_detectors")):
        plane = TelemetryPlane(n_nodes=4, mitigate=False, tables=tables)
        rng = random.Random(0)
        kinds = [EventKind.INGRESS_PKT, EventKind.EGRESS_PKT,
                 EventKind.H2D_XFER, EventKind.D2H_XFER,
                 EventKind.DISPATCH, EventKind.COLLECTIVE_BURST,
                 EventKind.QUEUE_SAMPLE]
        t = 0.0
        t0 = time.perf_counter()
        n = 30_000
        for i in range(n):
            t += rng.expovariate(20000.0)
            plane.observe(Event(ts=t, kind=kinds[i % len(kinds)],
                                node=i % 4, device=i % 4, flow=i % 64,
                                size=4096, group=0, meta=i % 500))
        wall = (time.perf_counter() - t0) / n * 1e6
        rows.append((f"table2/{label}", wall,
                     f"events={n};findings={len(plane.findings)}"))
    return rows


def _record_3a_traces():
    """Run every table-3a scenario and record its columnar event trace.

    Returns ``[(scenario_name, chunks)]`` where each scenario's per-round
    batches are coalesced into ring-DMA-sized, time-sorted EventBatch
    chunks — the granularity a DPU ring-buffer DMA would hand the host.
    Each scenario is an independent deployment trace and replays through
    its own plane.
    """
    from repro.core.events import EventTraceRecorder
    from repro.core.runbooks import BY_TABLE
    from repro.sim import SCENARIOS
    from repro.sim.cluster import ClusterSim

    traces = []
    for entry in BY_TABLE["3a"]:
        sc = SCENARIOS[entry.scenario]
        params = dataclasses.replace(sc.params)
        wl = dataclasses.replace(sc.workload, duration=params.duration * 0.98)
        rec = EventTraceRecorder()
        sim = ClusterSim(params, wl, dataclasses.replace(sc.fault), plane=rec)
        sim.run()
        chunks, acc, acc_n = [], [], 0
        for b in rec.batches:
            if not len(b):
                continue
            acc.append(b)
            acc_n += len(b)
            if acc_n >= 8192:
                chunks.append(_concat_batches(acc))
                acc, acc_n = [], 0
        if acc:
            chunks.append(_concat_batches(acc))
        traces.append((entry.scenario, chunks))
    return traces


def _concat_batches(batches):
    import numpy as np
    from repro.core.events import BATCH_COLUMNS, EventBatch
    cols = [np.concatenate([getattr(b, c) for b in batches])
            for c in BATCH_COLUMNS]
    # per-round batches are locally sorted but a round can emit past the
    # next round's start; the ring view is globally time-ordered
    order = np.argsort(cols[0], kind="stable")
    return EventBatch(*(c[order] for c in cols))


def telemetry_perf() -> list[tuple]:
    """Columnar vs per-event telemetry ingest on the table-3a scenario mix.

    Three lanes over the identical trace, identical detector set (table 3a),
    asserting identical findings:
      batched   — EventBatch chunks through ``plane.observe_batch``
      scalar    — the per-event path consuming the same columnar wire format
                  (materialize each record, then ``plane.observe``)
      scalar_prestaged — per-event path with materialization excluded
                  (pre-built Event list; isolates dispatch+detector cost)
    """
    from repro.core import TelemetryPlane
    from repro.core.events import EventBatch

    traces = _record_3a_traces()
    n_events = sum(len(c) for _, chunks in traces for c in chunks)

    def _fresh():
        return TelemetryPlane(n_nodes=4, mitigate=False, tables=("3a",))

    def _best_of(n, run):
        """min-of-n, fresh planes each rep (throttled CI boxes jitter);
        returns (best_seconds, last planes) — findings identical every rep."""
        best, planes = float("inf"), None
        for _ in range(n):
            planes = [_fresh() for _ in traces]
            t0 = time.perf_counter()
            run(planes)
            best = min(best, time.perf_counter() - t0)
        return best, planes

    def _batched(planes):
        for plane, (_, chunks) in zip(planes, traces):
            for c in chunks:
                plane.observe_batch(c)

    def _scalar(planes):
        # the per-event path consuming the same columnar wire format: each
        # ring record is materialized, then observed one at a time (fresh
        # uncached copies so every rep pays the real per-event cost)
        for plane, (_, chunks) in zip(planes, traces):
            for c in chunks:
                for ev in EventBatch(*c.columns()).iter_events():
                    plane.observe(ev)

    events = [[ev for c in chunks for ev in c.iter_events()]
              for _, chunks in traces]

    def _prestaged(planes):
        for plane, evs in zip(planes, events):
            for ev in evs:
                plane.observe(ev)

    dt_batched, planes_b = _best_of(2, _batched)
    dt_scalar, planes_s = _best_of(2, _scalar)
    dt_prestaged, planes_p = _best_of(2, _prestaged)

    def key(planes):
        return [(f.name, f.node, f.ts, f.severity, f.score)
                for p in planes for f in p.findings]
    identical = int(key(planes_b) == key(planes_s) == key(planes_p))

    def row(label, dt, speedup=False):
        evps = n_events / dt
        derived = (f"events={n_events};events_per_sec={evps:.0f};"
                   f"ns_per_event={dt / n_events * 1e9:.0f}")
        if speedup:
            derived += f";batched_speedup={dt / dt_batched:.2f}"
        derived += f";identical_findings={identical}"
        return (f"telemetry_perf/{label}", dt / n_events * 1e6, derived)

    rows = [
        row("batched", dt_batched),
        row("scalar", dt_scalar, speedup=True),
        row("scalar_prestaged", dt_prestaged, speedup=True),
    ]
    # per-detector ns/event breakdown (sampled every-Nth window on an
    # offset slot so it never sits inside the plane-wide timing windows);
    # aggregated across the batched lane's planes
    det_s: dict[str, float] = {}
    det_n: dict[str, int] = {}
    for p in planes_b:
        for k, v in p.stats.det_seconds.items():
            det_s[k] = det_s.get(k, 0.0) + v
        for k, v in p.stats.det_events.items():
            det_n[k] = det_n.get(k, 0) + v
    for name in sorted(det_s):
        n = det_n.get(name, 0)
        if not n:
            continue
        ns = det_s[name] / n * 1e9
        rows.append((f"telemetry_perf/detector/{name}", ns / 1e3,
                     f"ns_per_event={ns:.0f};timed_events={n}"))
    return rows


def _table3(table: str, seed: int = 0) -> list[tuple]:
    from repro.core.runbooks import BY_TABLE
    from repro.sim import SCENARIOS, run_scenario
    rows = []
    for entry in BY_TABLE[table]:
        sc = SCENARIOS[entry.scenario].variant(seed=seed)
        t0 = time.perf_counter()
        metrics, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        wall = (time.perf_counter() - t0) * 1e6
        fired = {f.name for f in plane.findings}
        hit = entry.row_id in fired
        det_latency = (metrics.first_finding_ts - sc.fault.start
                       if metrics.first_finding_ts > 0 else float("nan"))
        rows.append((f"table{table}/{entry.row_id}", wall,
                     f"hit={int(hit)};detect_latency_s={det_latency:.3f};"
                     f"co_fired={len(fired - {entry.row_id})}"))
    # healthy false-positive budget for this table's detectors
    sc = SCENARIOS["healthy"].variant(seed=seed)
    _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
    fps = [f for f in plane.findings
           if any(e.row_id == f.name for e in BY_TABLE[table])]
    rows.append((f"table{table}/healthy_false_positives", 0.0,
                 f"count={len(fps)}"))
    return rows


def table3a(seed: int = 0) -> list[tuple]:
    return _table3("3a", seed)


def table3b(seed: int = 0) -> list[tuple]:
    return _table3("3b", seed)


def table3c(seed: int = 0) -> list[tuple]:
    return _table3("3c", seed)


def table3d(seed: int = 0) -> list[tuple]:
    return _table3("3d", seed)


def table3e(seed: int = 0) -> list[tuple]:
    return _table3("3e", seed)


def sim_perf(seed: int = 0) -> list[tuple]:
    """Producer-plane synthesis throughput: columnar vs per-event reference.

    Mirrors ``telemetry_perf`` for the *producer* side.  Two lanes run the
    table-3a scenario mix at line-rate scale (``SIM_PERF_SCALE`` x nodes
    and arrival rate, default 16 -> 64 nodes) into a trace recorder:

      columnar     — vectorized synthesis, ring-DMA flush windows
      scalar_synth — the per-event reference: same seeded RNG stream and
                     row order, one ``add`` per event, per-round flush
                     (the pre-columnar producer's cadence)

    Both lanes must synthesize the identical event multiset (asserted via
    a full-column lexicographic sort); finding parity at canonical scale
    is asserted against the committed golden fixtures
    (``tests/golden/scenario_findings.json``).  A final row times a full
    scenario-registry sweep through ``repro.sim.sweep``.
    """
    import json
    import os

    from repro.core.events import BATCH_COLUMNS, EventTraceRecorder
    from repro.core.runbooks import BY_TABLE
    from repro.sim import SCENARIOS, SweepConfig, run_sweep
    from repro.sim.cluster import ClusterSim

    scale = int(os.environ.get("SIM_PERF_SCALE", "16"))
    reps = int(os.environ.get("SIM_PERF_REPS", "2"))
    names = [e.scenario for e in BY_TABLE["3a"]]

    def lane(scalar: bool):
        best_dt, events, traces = float("inf"), 0, None
        for _ in range(reps):
            dt_tot, ev_tot, tr = 0.0, 0, []
            for name in names:
                sc = SCENARIOS[name].variant(seed=seed,
                                             scalar_synth=scalar,
                                             scale=scale)
                params = dataclasses.replace(
                    sc.params, flush_events=1 if scalar else 65536)
                wl = dataclasses.replace(sc.workload,
                                         duration=params.duration * 0.98)
                rec = EventTraceRecorder()
                sim = ClusterSim(params, wl, sc.fault, plane=rec)
                t0 = time.perf_counter()
                sim.run()
                dt_tot += time.perf_counter() - t0
                ev_tot += sum(len(b) for b in rec.batches)
                tr.append(rec.batches)
            if dt_tot < best_dt:
                best_dt, events, traces = dt_tot, ev_tot, tr
        return best_dt, events, traces

    def canon(batches):
        """Order-independent canonical form of one scenario's trace."""
        cols = [np.concatenate([getattr(b, c) for b in batches])
                for c in BATCH_COLUMNS]
        order = np.lexsort(cols[::-1])
        return [c[order] for c in cols]

    dt_vec, ev_vec, tr_vec = lane(False)
    dt_sca, ev_sca, tr_sca = lane(True)
    identical = int(ev_vec == ev_sca and all(
        all(np.array_equal(a, b) for a, b in zip(canon(tv), canon(ts_)))
        for tv, ts_ in zip(tr_vec, tr_sca)))

    # golden-fixture finding parity at canonical scale (the committed
    # fixture is generated from the scalar reference path)
    golden_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "tests", "golden",
        "scenario_findings.json")
    parity, checked = 1, 0
    with open(golden_path) as fh:
        golden = json.load(fh)["scenarios"]
    from repro.sim import run_scenario
    for name in names:
        sc = SCENARIOS[name].variant(scalar_synth=False)
        _, plane, _ = run_scenario(sc.fault, sc.params, sc.workload)
        got = [[f.name, f.node, f.ts, f.severity, f.score]
               for f in plane.findings]
        checked += 1
        if got != golden[name]["findings"]:
            parity = 0

    sweep_scenarios = (("healthy", "tp_straggler", "hot_replica")
                       if os.environ.get("SIM_PERF_SWEEP") == "smoke"
                       else None)      # None = the whole registry
    sweep = run_sweep(SweepConfig(seeds=(seed,),
                                  scenarios=sweep_scenarios))
    summ = sweep.summary()

    def row(label, dt, ev, extra=""):
        return (f"sim_perf/{label}", dt / max(ev, 1) * 1e6,
                f"events={ev};events_per_sec={ev / dt:.0f};"
                f"scale={scale};reps={reps}" + extra)

    return [
        row("columnar", dt_vec, ev_vec,
            f";speedup={dt_sca / dt_vec * ev_vec / max(ev_sca, 1):.2f};"
            f"identical_traces={identical};golden_parity={parity};"
            f"golden_checked={checked}"),
        row("scalar_synth", dt_sca, ev_sca,
            f";identical_traces={identical}"),
        (f"sim_perf/registry_sweep", sweep.wall_s * 1e6,
         f"cells={summ['cells']};workers={summ['workers']};"
         f"wall_s={summ['wall_s']};events={summ['events']};"
         f"events_per_sec={summ['events_per_sec']};"
         f"hit_rate={summ['hit_rate']:.3f};"
         f"healthy_false_positives={summ['healthy_false_positives']}"),
    ]


def router_policies(seed: int = 0) -> list[tuple]:
    """Hierarchical router: policies vs throughput / TTFT on two workloads.

    Lane 1 (``router/<policy>``): the bursty, flow-skewed general workload
    (4 single-node DP replicas, no injected fault — the policy itself is
    the variable).

    Lane 2 (``router/prefix/<policy>``): the prefix-heavy workload — a few
    dozen sticky sessions against bounded per-node prefix caches, with the
    prefill model charging each miss real admission capacity.  This is the
    affinity-vs-balance tension made measurable, and it is a GATE:
    ``prefix_affinity`` must beat flat JSQ on p99 TTFT while holding its
    routed imbalance <= 1.25 (the load-ceiling spill doing its job), or
    the table exits nonzero.
    """
    from repro.sim import FaultSpec, SimParams, WorkloadSpec, run_scenario
    from repro.serving.router import POLICIES
    rows = []
    dur = 4.0
    # rate 55 / seed 13: partially-loaded regime where routing policy
    # matters (see tests/test_router.py's closed-loop headline)
    wl = WorkloadSpec(rate=55.0, duration=dur - 0.1, decode_mean=48,
                      decode_cv=0.6, burst_factor=8.0, flow_skew=1.2,
                      seed=13 + 2003 * seed)
    for policy in POLICIES:
        params = SimParams(n_nodes=4, n_replicas=4, router_policy=policy,
                           duration=dur, seed=3 + 1009 * seed)
        t0 = time.perf_counter()
        m, _, sim = run_scenario(FaultSpec(start=1e9), params, wl,
                                 mitigate=False)
        wall = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"router/{policy}", wall,
            f"tput={m.throughput(dur):.0f};completed={m.completed};"
            f"p50_ttft_ms={m.p_ttft(0.5) * 1e3:.1f};"
            f"p99_ttft_ms={m.p_ttft(0.99) * 1e3:.1f};"
            f"p99_latency_s={m.p(0.99):.3f};"
            f"routed_imbalance={sim.router.imbalance():.2f}"))
    # --- prefix-heavy lane: 24 sticky sessions, 8-session per-node LRU ---
    wl_pfx = WorkloadSpec(rate=55.0, duration=dur - 0.1, decode_mean=48,
                          decode_cv=0.6, burst_factor=8.0, n_sessions=24,
                          seed=13 + 2003 * seed)
    stats = {}
    for policy in ("join_shortest_queue", "prefix_affinity",
                   "hierarchical_jsq"):
        params = SimParams(n_nodes=4, n_replicas=4, router_policy=policy,
                           duration=dur, seed=3 + 1009 * seed,
                           prefix_cache=True, prefix_cache_sessions=8)
        t0 = time.perf_counter()
        m, _, sim = run_scenario(FaultSpec(start=1e9), params, wl_pfx,
                                 mitigate=False)
        wall = (time.perf_counter() - t0) * 1e6
        hit_rate = m.prefix_hits / max(m.prefix_hits + m.prefix_misses, 1)
        imb = sim.router.imbalance()
        stats[policy] = (m.p_ttft(0.99), imb)
        rows.append((
            f"router/prefix/{policy}", wall,
            f"tput={m.throughput(dur):.0f};completed={m.completed};"
            f"p50_ttft_ms={m.p_ttft(0.5) * 1e3:.1f};"
            f"p99_ttft_ms={m.p_ttft(0.99) * 1e3:.1f};"
            f"prefix_hit_rate={hit_rate:.2f};"
            f"routed_imbalance={imb:.2f}"))
    aff_p99, aff_imb = stats["prefix_affinity"]
    jsq_p99, _ = stats["join_shortest_queue"]
    ok_p99 = aff_p99 < jsq_p99
    ok_imb = aff_imb <= 1.25
    rows.append((
        "router/prefix/summary", 0.0,
        f"affinity_beats_jsq_p99={int(ok_p99)};"
        f"affinity_imbalance={aff_imb:.2f};imbalance_ok={int(ok_imb)}"))
    if not (ok_p99 and ok_imb):
        raise AssertionError(
            "router prefix-lane acceptance failed: "
            f"affinity p99_ttft={aff_p99 * 1e3:.1f}ms vs "
            f"jsq {jsq_p99 * 1e3:.1f}ms, imbalance={aff_imb:.2f}")
    return rows


def mitigation_loop() -> list[tuple]:
    """§5 closed loop: detection -> attribution -> actuation benefit."""
    from repro.sim import SCENARIOS, run_scenario
    rows = []
    for name in ("early_completion", "decode_early_stop", "hot_replica"):
        sc = SCENARIOS[name]
        off, _, _ = run_scenario(dataclasses.replace(sc.fault), sc.params,
                                 sc.workload, mitigate=False)
        on, plane, _ = run_scenario(dataclasses.replace(sc.fault),
                                    sc.params, sc.workload, mitigate=True)
        t_off = off.throughput(sc.params.duration)
        t_on = on.throughput(sc.params.duration)
        rows.append((f"mitigation/{name}", 0.0,
                     f"tput_off={t_off:.0f};tput_on={t_on:.0f};"
                     f"speedup={t_on / max(t_off, 1):.2f};"
                     f"idle_off={off.idle_frac():.2f};"
                     f"idle_on={on.idle_frac():.2f};"
                     f"actions={len(plane.actions)}"))
    return rows


def _ttm_columns(sim, sc, m, validate_report, bad, eps, ctx) -> str:
    """Derived-column suffix with the traced TTM decomposition for one
    dpu-mode cell, plus the gate bookkeeping: a fault scenario must carry
    exactly one schema-valid incident report whose phases telescope back
    to the scalar ``t_recover`` within ``eps`` (one detector poll); a
    healthy cell must carry none."""
    tracer = getattr(sim, "tracer", None)
    incs = tracer.incidents if tracer is not None else []
    if not sc.row_id:
        if incs:
            bad.append(f"{ctx}:healthy_incident")
        return ""
    if not incs:
        bad.append(f"{ctx}:no_incident")
        return ""
    rep = incs[0].to_report()
    errs = validate_report(rep)
    if errs:
        bad.append(f"{ctx}:schema:{errs[0]}")
    t = rep["ttm"]

    def _f(v):
        return f"{v:.3f}" if v is not None else "nan"

    if sim.fault.mitigated and m.mitigated_ts >= 0:
        phases = [t[k] for k in ("t_detect", "t_attribute", "t_decide",
                                 "t_bus_rtt", "t_apply")]
        if any(p is None for p in phases):
            bad.append(f"{ctx}:phase_missing")
        else:
            total = sum(phases)
            t_rec = m.mitigated_ts - sc.fault.start
            if abs(total - t_rec) > eps:
                bad.append(f"{ctx}:sum:{total:.3f}!={t_rec:.3f}")
    return (f";ttm_detect_s={_f(t['t_detect'])}"
            f";ttm_attr_s={_f(t['t_attribute'])}"
            f";ttm_decide_s={_f(t['t_decide'])}"
            f";ttm_bus_s={_f(t['t_bus_rtt'])}"
            f";ttm_apply_s={_f(t['t_apply'])}")


def control_loop(seed: int = 0) -> list[tuple]:
    """Closed-loop topology comparison: ``dpu`` vs ``instant`` vs ``none``.

    Every registry fault scenario (plus the healthy baselines) runs under
    three control topologies:

      none    — detection only, nobody acts (the damage baseline)
      instant — the legacy in-process controller (zero loop latency)
      dpu     — the DPUSidecar: modeled transport + on-DPU budget + policy
                arbitration + command bus (the paper's actual deployment)

    Per-cell derived fields: ``hit`` (bound detector fired),
    ``t_detect_s`` (host round the loop first saw the bound finding,
    relative to fault start), ``t_actuate_s`` (first applied action),
    ``t_recover_s`` (fault neutralized), ``recovered``, ``p99_latency_s``,
    ``actions``.  Scenario durations are extended by 1 s over canonical so
    slow-confirming rows fit their confirmation + actuation inside the run.

    dpu cells additionally run with causal tracing attached and carry the
    decomposed TTM columns (``ttm_detect_s``, ``ttm_attr_s``,
    ``ttm_decide_s``, ``ttm_bus_s``, ``ttm_apply_s``) from the incident
    report — the telescoped phases of ``t_recover_s``:

      ttm_detect_s — fault injection to the first bound finding
      ttm_attr_s   — finding to first attribution (same poll: 0)
      ttm_decide_s — attribution to the recovering command's issue time
                     (absorbs confirmation dwell + policy arbitration)
      ttm_bus_s    — command issue to host delivery (modeled command-bus
                     RTT incl. retries; 0 on bus-less paths)
      ttm_apply_s  — delivery to fault neutralization (0 in the sim:
                     applies are instantaneous)

    The summary row asserts the acceptance properties: dpu recovers every
    fault scenario with hit_rate 1.0, healthy runs take zero actions in
    every mode, time-to-mitigate under dpu is strictly greater than
    instant wherever instant recovers at all, and every dpu cell's phases
    sum back to ``t_recover_s`` within one detector poll interval.
    """
    import os

    from repro.core.runbooks import row_hit
    from repro.obs import validate_report
    from repro.sim import SCENARIOS, run_scenario

    names = os.environ.get("CONTROL_LOOP_SCENARIOS")
    if names:
        picked = names.split(",")
    else:
        picked = [n for n, sc in SCENARIOS.items()]
    rows = []
    recover = {}
    hits = {}
    healthy_actions = 0
    ttm_bad = []
    # dpu cells run traced: the incident report's decomposed TTM phases
    # (detect/attribute/decide/bus/apply) must telescope back to the
    # scalar t_recover within one detector poll interval
    TTM_SUM_EPS = 0.25
    for name in picked:
        sc = SCENARIOS[name].variant(seed=seed)
        for mode in ("none", "instant", "dpu"):
            params = dataclasses.replace(
                sc.params, duration=sc.params.duration + 1.0, control=mode,
                trace=(mode == "dpu"))
            t0 = time.perf_counter()
            m, plane, sim = run_scenario(
                dataclasses.replace(sc.fault), params, sc.workload,
                mitigate=(mode != "none"))
            wall = (time.perf_counter() - t0) * 1e6
            ttm_txt = ""
            if mode == "dpu":
                ttm_txt = _ttm_columns(
                    sim, sc, m, validate_report, ttm_bad,
                    TTM_SUM_EPS, f"control_loop:{name}")
            fired = {f.name for f in plane.findings}
            start = sc.fault.start if sc.row_id else 0.0
            if sc.row_id:
                # sibling-aware: a scenario whose fault is legitimately
                # claimed first by a declared sibling row (e.g. the early-
                # completion pair) still counts as a hit — the runbook entry
                # names which rows may stand in for it
                hit = row_hit(sc.row_id, fired)
                hits.setdefault(name, {})[mode] = hit
                recover.setdefault(name, {})[mode] = (
                    sim.fault.mitigated, m.mitigated_ts - start
                    if m.mitigated_ts >= 0 else float("nan"))
            else:
                hit = not fired
                healthy_actions += len(plane.actions)
            rows.append((
                f"control_loop/{name}/{mode}", wall,
                f"hit={int(hit)};"
                f"t_detect_s={m.detect_wall_ts - start:.3f};"
                f"t_actuate_s={m.first_action_ts - start:.3f};"
                f"t_recover_s={m.mitigated_ts - start:.3f};"
                f"recovered={int(sim.fault.mitigated)};"
                f"p99_latency_s={m.p(0.99):.3f};"
                f"actions={len(plane.actions)}" + ttm_txt))
    faulted = [n for n in picked if SCENARIOS[n].row_id]
    dpu_recovered = all(recover[n]["dpu"][0] for n in faulted)
    dpu_hit = all(hits[n]["dpu"] for n in faulted)
    both = [n for n in faulted if recover[n]["instant"][0]]
    strictly_slower = all(recover[n]["dpu"][1] > recover[n]["instant"][1]
                          for n in both)
    only_dpu = [n for n in faulted if not recover[n]["instant"][0]]
    summary = (
        f"scenarios={len(faulted)};"
        f"dpu_hit_rate={1.0 if dpu_hit else 0.0:.3f};"
        f"dpu_recovered_all={int(dpu_recovered)};"
        f"dpu_ttm_gt_instant={int(strictly_slower)};"
        f"instant_unrecovered={len(only_dpu)};"
        f"healthy_fp_actions={healthy_actions};"
        f"ttm_decomposed_ok={int(not ttm_bad)}")
    rows.append(("control_loop/summary", 0.0, summary))
    # the acceptance properties are a GATE, not a printout: a regression on
    # any grid (smoke or the CI full registry) must exit nonzero
    if not (dpu_hit and dpu_recovered and strictly_slower
            and healthy_actions == 0 and not ttm_bad):
        failed = sorted(n for n in faulted
                        if not (hits[n]["dpu"] and recover[n]["dpu"][0]))
        raise AssertionError(
            f"control_loop acceptance failed ({summary}); "
            f"bad scenarios: {failed or ttm_bad or 'ttm/healthy property'}")
    return rows


def collective(seed: int = 0) -> list[tuple]:
    """Table 3(e) lane: per-collective fidelity through the closed loop.

    The three collective/rail/memory rows run under all three control
    topologies, like ``control_loop`` but scoped so the lane stays
    CI-sized.  A fourth cell replays the healthy baseline with every 3(e)
    emission tier switched on (per-collective rounds, rail legs, the HBM
    knee) — the new telemetry must never false-fire on a healthy cluster.

    Gate: each row detects under ``none``, recovers under both ``instant``
    and ``dpu``, dpu time-to-mitigate is strictly greater than instant,
    and the knobs-on healthy run yields zero findings and zero actions.
    """
    from repro.core.runbooks import BY_TABLE
    from repro.sim import SCENARIOS, run_scenario

    rows = []
    bad = []
    for entry in BY_TABLE["3e"]:
        sc = SCENARIOS[entry.scenario].variant(seed=seed)
        cells = {}
        for mode in ("none", "instant", "dpu"):
            params = dataclasses.replace(
                sc.params, duration=sc.params.duration + 1.0, control=mode)
            t0 = time.perf_counter()
            m, plane, sim = run_scenario(
                dataclasses.replace(sc.fault), params, sc.workload,
                mitigate=(mode != "none"))
            wall = (time.perf_counter() - t0) * 1e6
            fired = {f.name for f in plane.findings}
            start = sc.fault.start
            hit = entry.row_id in fired
            ttm = (m.mitigated_ts - start if m.mitigated_ts >= 0
                   else float("nan"))
            cells[mode] = (hit, sim.fault.mitigated, ttm)
            rows.append((
                f"collective/{entry.scenario}/{mode}", wall,
                f"hit={int(hit)};"
                f"t_detect_s={m.detect_wall_ts - start:.3f};"
                f"t_actuate_s={m.first_action_ts - start:.3f};"
                f"t_recover_s={ttm:.3f};"
                f"recovered={int(sim.fault.mitigated)};"
                f"p99_latency_s={m.p(0.99):.3f};"
                f"tokens_out={m.tokens_out};"
                f"actions={len(plane.actions)}"))
        ok = (cells["none"][0] and cells["instant"][1] and cells["dpu"][1]
              and cells["dpu"][2] > cells["instant"][2])
        if not ok:
            bad.append(entry.scenario)
    # healthy baseline with every new emission tier enabled: the whole
    # point of the never-false-fire harness, exercised at bench scale
    base = SCENARIOS["healthy"].variant(seed=seed)
    params = dataclasses.replace(
        base.params, per_collective=True, rail_domain_size=2, hbm_knee=12,
        control="dpu")
    t0 = time.perf_counter()
    m, plane, _sim = run_scenario(
        dataclasses.replace(base.fault), params, base.workload,
        mitigate=True)
    wall = (time.perf_counter() - t0) * 1e6
    fps = sorted({f.name for f in plane.findings})
    rows.append((
        "collective/healthy_knobs_on/dpu", wall,
        f"false_positives={len(plane.findings)};"
        f"actions={len(plane.actions)};"
        f"tokens_out={m.tokens_out}"))
    rows.append(("collective/summary", 0.0,
                 f"scenarios={len(BY_TABLE['3e'])};"
                 f"dpu_recovered_all={int(not bad)};"
                 f"healthy_fp={len(plane.findings)}"))
    if bad or plane.findings or plane.actions:
        raise AssertionError(
            f"collective lane acceptance failed: bad scenarios={bad}; "
            f"healthy knobs-on findings={fps}, "
            f"actions={len(plane.actions)}")
    return rows


def chaos(seed: int = 0) -> list[tuple]:
    """Monitoring-plane chaos lane: the watcher itself under fire.

    Part A (false-actuation gate): five chaos schedules — uplink
    blackout, DPU crash/restart, uplink corruption, frame duplication,
    command-downlink partition — run against the HEALTHY workload under
    the full supervision stack (sidecar with liveness pings and batch
    checksums, host-side watchdog over the OOB port).  The plane may heal
    itself (``mon``-table actions: ``resync_telemetry`` /
    ``failover_controller``) but must never invent a cluster pathology:
    zero non-mon findings, zero non-mon actions, and no failover except
    under the schedules that actually kill the DPU or its command
    channel.

    Part B (bounded-recovery gate): every registry fault scenario re-runs
    in dpu mode with the watchdog attached and a DPU crash injected in
    its detection window (crash at ``fault.start + 0.2``, warm restart
    0.4 s later), with 2 s of duration headroom over the canonical run
    (quorum rows re-seed their escalation dwell at the failback handover,
    so recovery can land a full dwell after the canonical time).
    The gate: every scenario still detects its row and still recovers —
    losing the monitoring plane mid-incident delays mitigation but never
    loses it.

    Every Part-B cell runs with causal tracing attached and carries the
    decomposed TTM columns (``ttm_detect_s``/``ttm_attr_s``/
    ``ttm_decide_s``/``ttm_bus_s``/``ttm_apply_s`` — see
    :func:`control_loop` for definitions); the summary's
    ``{hot,deg}_t_*_mean`` fields attribute the hot-vs-degraded gap to
    named phases: the hot path pays a command-bus RTT (``t_bus_rtt`` > 0)
    that the in-process degraded fallback never does, while the degraded
    path's extra latency lands in ``t_decide``/``t_detect`` (re-seeded
    detector state after failover).  Phases must telescope back to the
    scalar recovery time within one detector poll interval.

    Part B also runs every non-structural scenario a second time with a
    hot standby sidecar attached (``chaos/hot/*`` rows): the standby
    shadows the same tap and takes over under an OOB lease when the
    primary dies.  Gate: the hot path recovers every scenario with at
    least one promotion and zero stale-term applies, is never materially
    slower than the degraded host failover (``ttm_hot <= ttm_deg +
    TTM_EPS`` — the epsilon covers the modeled command-bus round trip
    and one detector poll of phase, which the in-process host controller
    does not pay), and is strictly faster in aggregate.  Scenarios whose
    fault targets the standby pair itself (``standby_lag``,
    ``split_brain_fenced``) are structural — they run hot-only.

    Part C (election-safety gate): three schedules against the HEALTHY
    workload with the full hot pair attached.  ``split_brain`` (OOB
    partition + a downlink blip) must promote exactly once, fence every
    stale-term command from the deposed-but-alive primary, never apply
    one, and never degrade to host mode.  ``dual_dark`` (both sidecars
    killed) must land in degraded host mode and fail back.
    ``hot_healthy`` (no chaos) must stay completely quiet: term 1, zero
    promotions, zero fences, zero findings.
    """
    from repro.core.runbooks import BY_TABLE, row_hit
    from repro.dpu import DPUParams, WatchdogParams
    from repro.sim import SCENARIOS, run_scenario

    mon_rows = {e.row_id for e in BY_TABLE["mon"]}
    mon_actions = {e.action for e in BY_TABLE["mon"]}
    rows = []
    bad = []

    # -- part A: chaos on a healthy cluster must never actuate -------------
    schedules = {
        "blackout": dict(uplink_blackout_start=1.0, uplink_blackout_s=0.3),
        "crash_restart": dict(dpu_crash_at=1.0, dpu_restart_after=0.5),
        "corruption": dict(uplink_corrupt_p=0.05),
        "duplication": dict(uplink_duplicate_p=0.05),
        "partition": dict(downlink_partition_start=1.0,
                          downlink_partition_s=0.7),
    }
    # only schedules that kill the DPU or its command channel may trip the
    # watchdog; an uplink-side blackout/corruption must not
    may_failover = {"crash_restart", "partition"}
    base = SCENARIOS["healthy"].variant(seed=seed)
    for name, knobs in schedules.items():
        fault = dataclasses.replace(base.fault, **knobs)
        params = dataclasses.replace(
            base.params, duration=3.0, control="dpu",
            dpu=DPUParams(ping_every=0.02), watchdog=WatchdogParams())
        t0 = time.perf_counter()
        m, plane, _sim = run_scenario(fault, params, base.workload,
                                      mitigate=True)
        wall = (time.perf_counter() - t0) * 1e6
        false_findings = sorted({f.name for f in plane.findings} - mon_rows)
        false_acts = [r for r in plane.actions if r.action not in mon_actions]
        mon_acts = [r for r in plane.actions if r.action in mon_actions]
        spurious = name not in may_failover and plane.failovers > 0
        guard = plane.sidecar.guard
        rows.append((
            f"chaos/{name}/healthy", wall,
            f"false_findings={len(false_findings)};"
            f"false_actions={len(false_acts)};"
            f"mon_actions={len(mon_acts)};"
            f"failovers={plane.failovers};"
            f"failbacks={plane.failbacks};"
            f"gaps={guard.gaps};replays={guard.replays};"
            f"corrupt={guard.corrupt};"
            f"tokens_out={m.tokens_out}"))
        if false_findings or false_acts or spurious:
            bad.append(f"A:{name}:{false_findings or [r.action for r in false_acts] or 'failover'}")

    # -- part B: every fault scenario survives a mid-incident DPU crash ----
    # hot-vs-degraded epsilon: the standby actuates over the modeled
    # command bus (one RTT) and keeps its detector poll phase instead of
    # re-seeding it at failover; both together bound at one probe period
    # plus one poll — anything beyond that is a real regression
    TTM_EPS = 0.06
    from repro.obs import validate_report
    faulted = [n for n, sc in SCENARIOS.items() if sc.row_id]
    ttm_deg_all, ttm_hot_all = [], []
    # decomposed-phase accumulators: both modes run traced, so the
    # hot-vs-degraded gap is attributable to named phases (the degraded
    # path re-pays detection after failback; the hot path pays a
    # command-bus RTT the in-process host fallback never does)
    phase_sums = {"hot": {}, "deg": {}}
    phase_cells = {"hot": 0, "deg": 0}
    for name in faulted:
        sc = SCENARIOS[name].variant(seed=seed)
        # scenarios whose fault targets the standby pair itself carry a
        # structural standby in their params — no degraded twin exists
        structural = sc.params.standby is not None
        start = sc.fault.start
        per_mode = {}
        for mode in (("hot",) if structural else ("deg", "hot")):
            fault = dataclasses.replace(sc.fault,
                                        dpu_crash_at=start + 0.2,
                                        dpu_restart_after=0.4)
            params = dataclasses.replace(
                sc.params, duration=sc.params.duration + 2.0,
                control="dpu", trace=True,
                standby=(sc.params.standby if structural
                         else DPUParams() if mode == "hot" else None),
                watchdog=WatchdogParams())
            t0 = time.perf_counter()
            m, plane, sim = run_scenario(fault, params, sc.workload,
                                         mitigate=True)
            wall = (time.perf_counter() - t0) * 1e6
            fired = {f.name for f in plane.findings}
            hit = row_hit(sc.row_id, fired)
            ttm = (m.mitigated_ts - start if m.mitigated_ts >= 0
                   else float("nan"))
            ttm_txt = _ttm_columns(sim, sc, m, validate_report, bad,
                                   0.25, f"B:{mode}:{name}")
            if sim.tracer is not None and sim.tracer.incidents \
                    and sim.fault.mitigated:
                for k, v in sim.tracer.incidents[0].to_report()[
                        "ttm"].items():
                    if v is not None:
                        phase_sums[mode][k] = \
                            phase_sums[mode].get(k, 0.0) + v
                phase_cells[mode] += 1
            per_mode[mode] = (ttm, hit, sim.fault.mitigated, plane, wall,
                              ttm_txt)
        if "deg" in per_mode:
            ttm, hit, rec, plane, wall, ttm_txt = per_mode["deg"]
            rows.append((
                f"chaos/midcrash/{name}", wall,
                f"hit={int(hit)};"
                f"t_recover_s={ttm:.3f};"
                f"recovered={int(rec)};"
                f"restarts={plane.sidecar.restarts};"
                f"failovers={plane.failovers};"
                f"actions={len(plane.actions)}" + ttm_txt))
            if not (hit and rec):
                bad.append(f"B:{name}")
        ttm_h, hit, rec, plane, wall, ttm_txt = per_mode["hot"]
        el = plane.arbiter.report()
        ttm_d = per_mode["deg"][0] if "deg" in per_mode else float("nan")
        rows.append((
            f"chaos/hot/{name}", wall,
            f"hit={int(hit)};"
            f"ttm_hot={ttm_h:.3f};"
            f"ttm_degraded={ttm_d:.3f};"
            f"recovered={int(rec)};"
            f"promotions={plane.promotions};"
            f"fenced={el['fenced']};"
            f"stale_applied={el['stale_applied']}" + ttm_txt))
        if not (hit and rec and plane.promotions >= 1
                and el["stale_applied"] == 0):
            bad.append(f"B:hot:{name}")
        if "deg" in per_mode:
            if not (ttm_h == ttm_h and ttm_h <= ttm_d + TTM_EPS):
                bad.append(f"B:ttm:{name}:{ttm_h:.3f}>{ttm_d:.3f}+eps")
            ttm_deg_all.append(ttm_d)
            ttm_hot_all.append(ttm_h)
    mean_d = sum(ttm_deg_all) / max(len(ttm_deg_all), 1)
    mean_h = sum(ttm_hot_all) / max(len(ttm_hot_all), 1)
    # the hot pair must strictly beat degraded failover in aggregate:
    # its whole price of admission is the shadowed-warm detector state
    if not mean_h < mean_d:
        bad.append(f"B:ttm_mean:{mean_h:.3f}>={mean_d:.3f}")
    phase_means = {
        mode: {k: v / phase_cells[mode]
               for k, v in sorted(phase_sums[mode].items())}
        for mode in ("hot", "deg") if phase_cells[mode]}

    # -- part C: election safety on a healthy cluster ----------------------
    c_schedules = {
        # OOB partition hides the primary from the arbiter while a
        # downlink blip trips bus-dark: the standby may only promote
        # after the primary's delivered lease horizon expires, and every
        # command the deposed-but-alive primary keeps sending is fenced
        "split_brain": dict(oob_partition_start=1.0, oob_partition_s=0.6,
                            downlink_partition_start=1.0,
                            downlink_partition_s=0.18),
        # both sidecars die: no standby to promote — degraded host mode
        # (PR-7 path) with the host taking the term
        "dual_dark": dict(dpu_crash_at=1.0, dpu_restart_after=0.6,
                          standby_crash_at=1.0, standby_restart_after=0.6),
        # control: an idle hot pair must be invisible
        "hot_healthy": {},
    }
    for name, knobs in c_schedules.items():
        fault = dataclasses.replace(base.fault, **knobs)
        params = dataclasses.replace(
            base.params, duration=3.0, control="dpu",
            dpu=DPUParams(ping_every=0.02), standby=DPUParams(),
            watchdog=WatchdogParams())
        t0 = time.perf_counter()
        m, plane, _sim = run_scenario(fault, params, base.workload,
                                      mitigate=True)
        wall = (time.perf_counter() - t0) * 1e6
        el = plane.arbiter.report()
        false_findings = sorted({f.name for f in plane.findings} - mon_rows)
        false_acts = [r.action for r in (plane.fallback.log
                                         if plane.fallback else [])
                      if r.action not in mon_actions]
        rows.append((
            f"chaos/election/{name}", wall,
            f"false_findings={len(false_findings)};"
            f"false_actions={len(false_acts)};"
            f"promotions={plane.promotions};"
            f"failovers={plane.failovers};"
            f"failbacks={plane.failbacks};"
            f"term={el['term']};"
            f"fenced={el['fenced']};"
            f"stale_applied={el['stale_applied']};"
            f"state={plane.state}"))
        ok = (not false_findings and not false_acts
              and el["stale_applied"] == 0 and plane.state == "normal")
        if name == "split_brain":
            ok = ok and (plane.promotions == 1 and el["fenced"] >= 1
                         and plane.failovers == 0)
        elif name == "dual_dark":
            ok = ok and (plane.failovers >= 1 and plane.promotions == 0
                         and plane.failbacks >= 1)
        else:  # hot_healthy
            ok = ok and (plane.promotions == 0 and el["fenced"] == 0
                         and plane.failovers == 0 and el["term"] == 1
                         and not plane.findings)
        if not ok:
            bad.append(f"C:{name}")
    # per-phase attribution of the hot-vs-degraded TTM gap, straight from
    # the traced incident reports (means over recovered cells per mode)
    attr = "".join(
        f";{mode}_{k}_mean={v:.3f}"
        for mode in ("hot", "deg") for k, v in phase_means.get(
            mode, {}).items() if k != "t_recover")
    rows.append(("chaos/summary", 0.0,
                 f"schedules={len(schedules)};"
                 f"midcrash_scenarios={len(faulted)};"
                 f"election_schedules={len(c_schedules)};"
                 f"ttm_hot_mean={mean_h:.3f};"
                 f"ttm_degraded_mean={mean_d:.3f};"
                 f"gate_ok={int(not bad)}" + attr))
    if bad:
        raise AssertionError(f"chaos lane acceptance failed: {bad}")
    return rows


def obs(seed: int = 0) -> list[tuple]:
    """Observability lane: tracing overhead + incident-report round trip.

    Part 1 (overhead gate): the telemetry_perf batched-ingest mix replays
    twice — tracer/flight-recorder detached vs attached — min-of-3 each.
    The gate: attaching observability costs < 5% events/sec AND changes
    no finding (observe-only by construction; this is the perf half of
    the golden-parity guard in ``tests/test_obs.py``).

    Part 2 (incident round trip): one fault scenario runs closed-loop
    (dpu mode) with tracing on; its incident report must be schema-valid,
    its TTM phases must telescope back to the scalar recovery time, and
    the report + the Prometheus metrics exposition are written to
    ``artifacts/incident_report.json`` / ``artifacts/obs_metrics.prom``
    (CI archives both).  The ``obs/incident`` row carries the decomposed
    TTM columns (see :func:`control_loop` for definitions).
    """
    import json
    import os

    from repro.core import TelemetryPlane
    from repro.obs import (
        FlightRecorder,
        Tracer,
        collect_metrics,
        validate_report,
    )
    from repro.sim import SCENARIOS, run_scenario

    traces = _record_3a_traces()
    n_events = sum(len(c) for _, chunks in traces for c in chunks)
    bad = []

    def _ingest(traced):
        best, planes = float("inf"), None
        for _ in range(3):
            planes = [TelemetryPlane(n_nodes=4, mitigate=False,
                                     tables=("3a",)) for _ in traces]
            if traced:
                for p in planes:
                    p.tracer = Tracer(recorder=FlightRecorder())
                    p.trace_source = "plane"
                    p.recorder = p.tracer.recorder
            t0 = time.perf_counter()
            for plane, (_, chunks) in zip(planes, traces):
                for c in chunks:
                    plane.observe_batch(c)
            best = min(best, time.perf_counter() - t0)
        return best, planes

    def _findings(planes):
        return [(f.name, f.node, f.ts, f.severity, f.score)
                for p in planes for f in p.findings]

    dt_off, planes_off = _ingest(False)
    dt_on, planes_on = _ingest(True)
    overhead_pct = (dt_on - dt_off) / dt_off * 100.0
    identical = int(_findings(planes_off) == _findings(planes_on))
    if not identical:
        bad.append("tracing changed findings")
    if overhead_pct >= 5.0:
        bad.append(f"tracing overhead {overhead_pct:.1f}% >= 5%")
    rows = [(
        "obs/tracing_overhead", dt_on / n_events * 1e6,
        f"events={n_events};"
        f"events_per_sec_off={n_events / dt_off:.0f};"
        f"events_per_sec_on={n_events / dt_on:.0f};"
        f"overhead_pct={overhead_pct:.2f};"
        f"identical_findings={identical}")]

    # -- part 2: one closed-loop incident, exported end to end -------------
    sc = SCENARIOS["tp_straggler"].variant(seed=seed)
    params = dataclasses.replace(
        sc.params, duration=sc.params.duration + 1.0, control="dpu",
        trace=True)
    t0 = time.perf_counter()
    m, plane, sim = run_scenario(dataclasses.replace(sc.fault), params,
                                 sc.workload, mitigate=True)
    wall = (time.perf_counter() - t0) * 1e6
    ttm_txt = _ttm_columns(sim, sc, m, validate_report, bad, 0.25,
                           "obs:incident")
    if not sim.fault.mitigated:
        bad.append("incident scenario did not recover")
    incs = sim.tracer.incidents
    rep = incs[0].to_report() if incs else {}
    rows.append((
        "obs/incident", wall,
        f"incidents={len(incs)};"
        f"closed={int(bool(rep.get('closed')))};"
        f"timeline_events={len(rep.get('timeline', []))};"
        f"recorder_frames={sim.recorder.occupancy()}" + ttm_txt))

    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/incident_report.json", "w") as fh:
        json.dump(sim.tracer.reports(), fh, indent=1)
    prom = collect_metrics(tracer=sim.tracer, plane=sim.plane.plane,
                           sidecar=sim.plane,
                           recorder=sim.recorder).render()
    with open("artifacts/obs_metrics.prom", "w") as fh:
        fh.write(prom)
    n_samples = sum(1 for line in prom.splitlines()
                    if line and not line.startswith("#"))
    rows.append(("obs/metrics_exposition", 0.0,
                 f"samples={n_samples};bytes={len(prom)};"
                 f"gate_ok={int(not bad)}"))
    if bad:
        raise AssertionError(f"obs lane acceptance failed: {bad}")
    return rows


def serving_engine() -> list[tuple]:
    """Live-engine throughput: continuous vs static batching (the paper's
    early-completion pathology on the real JAX engine)."""
    import random
    from repro.configs import ARCHS
    from repro.models import build_model
    from repro.serving import EngineConfig, InferenceEngine, ServeRequest
    cfg = ARCHS["qwen3-0.6b"].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    rows = []
    for continuous in (True, False):
        rng = random.Random(1)
        reqs = [ServeRequest(
            req_id=i, arrival=0.0,
            prompt=[rng.randrange(cfg.vocab) for _ in range(8)],
            max_new_tokens=(40 if i % 4 == 0 else 4)) for i in range(12)]
        eng = InferenceEngine(m, params, EngineConfig(
            max_slots=4, max_seq=128, n_pages=256, telemetry=False))
        eng.sched.set_continuous(continuous)
        t0 = time.perf_counter()
        rep = eng.run(reqs, max_steps=600)
        wall = (time.perf_counter() - t0) * 1e6
        label = "continuous" if continuous else "static"
        rows.append((f"serving/{label}_batching", wall / max(rep['steps'], 1),
                     f"steps={rep['steps']};tok_per_step="
                     f"{rep['tokens_per_step']:.2f}"))
    return rows


def kernels_bench() -> list[tuple]:
    """Hot-spot kernels: oracle timing + interpret-mode validation cost."""
    from repro.kernels import ops, ref
    ks = jax.random.split(jax.random.key(0), 4)
    rows = []
    q = jax.random.normal(ks[0], (2, 512, 8, 128), jnp.float32)
    k = jax.random.normal(ks[1], (2, 512, 2, 128), jnp.float32)
    v = jax.random.normal(ks[2], (2, 512, 2, 128), jnp.float32)
    fa = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v,
                                                         causal=True))
    fa(q, k, v).block_until_ready()
    rows.append(("kernels/flash_attention_ref_512", _time(
        lambda: fa(q, k, v).block_until_ready()), "B2_S512_H8_D128"))

    qd = jax.random.normal(ks[0], (8, 8, 128), jnp.float32)
    kp = jax.random.normal(ks[1], (128, 16, 2, 128), jnp.float32)
    vp = jax.random.normal(ks[2], (128, 16, 2, 128), jnp.float32)
    tbl = jnp.arange(64, dtype=jnp.int32).reshape(8, 8)
    lens = jnp.full((8,), 100, jnp.int32)
    pa = jax.jit(ref.paged_attention_ref)
    pa(qd, kp, vp, tbl, lens).block_until_ready()
    rows.append(("kernels/paged_attention_ref", _time(
        lambda: pa(qd, kp, vp, tbl, lens).block_until_ready()),
        "B8_pages128"))

    x = jax.random.normal(ks[0], (2, 512, 4, 64), jnp.float32)
    a = -jnp.abs(jax.random.normal(ks[1], (2, 512, 4))) * 0.1
    B = jax.random.normal(ks[2], (2, 512, 64), jnp.float32)
    C = jax.random.normal(ks[3], (2, 512, 64), jnp.float32)
    from repro.models.ssm import ssd_chunked
    sc = jax.jit(lambda *a_: ssd_chunked(*a_, chunk=128)[0])
    sc(x, a, B, C).block_until_ready()
    rows.append(("kernels/ssd_chunked_512", _time(
        lambda: sc(x, a, B, C).block_until_ready()), "B2_L512_H4_P64"))
    return rows


def roofline_readout() -> list[tuple]:
    """Summarize the dry-run roofline artifacts (if present)."""
    import glob
    import json
    import os
    rows = []
    for f in sorted(glob.glob("artifacts/roofline/*.json")):
        try:
            r = json.load(open(f))
        except Exception:
            continue
        if not r.get("ok"):
            continue
        rl = r["roofline"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            max(rl["compute_s"], rl["memory_s"], rl["collective_s"]) * 1e6,
            f"dominant={rl['dominant']};frac={rl['roofline_fraction']:.3f};"
            f"useful={rl['useful_flops_ratio']:.3f}"))
    if not rows:
        rows.append(("roofline/missing", 0.0,
                     "run repro.launch.roofline first"))
    return rows


ALL_TABLES = [
    table1_archzoo, table2_signals, telemetry_perf, sim_perf, table3a,
    table3b, table3c, table3d, table3e, router_policies, mitigation_loop,
    control_loop, collective, chaos, obs, serving_engine, kernels_bench,
    roofline_readout,
]
