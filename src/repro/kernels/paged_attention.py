"""Pallas TPU paged-attention decode kernel (the serving hot spot).

One query token per sequence attends over a paged KV cache (vLLM-style
block tables — the paper's §3.1 PagedAttention discussion).  TPU-native
structure:

  - PrefetchScalarGridSpec prefetches the block table and sequence lengths
    into SMEM so BlockSpec index_maps can address *physical* pages: the
    page gather happens in the DMA engine, not as kernel compute.
  - grid = (batch, pages_per_seq); the page axis is the online-softmax
    reduction, running stats in VMEM scratch (same pattern as flash
    attention — sequential grid is the TPU's reduction loop).
  - GQA handled in-register: q is viewed (Hkv, G, D) and batched against
    the page's (Hkv, page, D) keys via dot_general over the kv-head dim.
  - pages past a sequence's length are skipped entirely with pl.when —
    short sequences cost proportionally less DMA and MXU time.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  page: int, g: int, sm_scale: float, per_seq: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = len_ref[b]
    live = j * page < seq_len

    @pl.when(live)
    def _compute():
        hq, d = q_ref.shape[1], q_ref.shape[2]
        hkv = hq // g
        q = q_ref[0].astype(jnp.float32)                  # (Hq, D)
        k = k_ref[0].astype(jnp.float32)                  # (page, Hkv, D)
        v = v_ref[0].astype(jnp.float32)
        qg = q.reshape(hkv, g, d)
        kk = k.transpose(1, 0, 2)                         # (Hkv, page, D)
        vv = v.transpose(1, 0, 2)
        # batched over kv heads: (Hkv, G, page)
        s = jax.lax.dot_general(
            qg, kk, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * sm_scale
        pos = j * page + jax.lax.iota(jnp.int32, page)
        mask = (pos < seq_len)[None, None, :]
        s = jnp.where(mask, s, NEG_INF)

        sh = s.reshape(hq, page)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sh, axis=-1))
        safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask.reshape(1, page),
                      jnp.exp(sh - safe_m[:, None]), 0.0)  # (Hq, page)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp(m_prev - safe_m))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.reshape(hkv, g, page), vv,
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)           # (Hkv, G, D)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + pv.reshape(hq, d))
        m_ref[...] = m_new

    @pl.when(j == per_seq - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_attention_kernel(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           lengths: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k/v_pages: (P, page, Hkv, D);
    block_table: (B, per_seq) int32; lengths: (B,) int32 -> (B, Hq, D)."""
    b, hq, d = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    per_seq = block_table.shape[1]
    g = hq // hkv
    sm_scale = 1.0 / math.sqrt(d)

    dp = (-d) % 128
    if dp:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, dp)))
        k_pages = jnp.pad(k_pages, ((0, 0), (0, 0), (0, 0), (0, dp)))
        v_pages = jnp.pad(v_pages, ((0, 0), (0, 0), (0, 0), (0, dp)))
    d_p = d + dp

    kernel = functools.partial(_paged_kernel, page=page, g=g,
                               sm_scale=sm_scale, per_seq=per_seq)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, per_seq),
        in_specs=[
            pl.BlockSpec((1, hq, d_p), lambda b, j, tbl, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, hkv, d_p),
                         lambda b, j, tbl, ln: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, page, hkv, d_p),
                         lambda b, j, tbl, ln: (tbl[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, d_p),
                               lambda b, j, tbl, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hq,), jnp.float32),
            pltpu.VMEM((hq,), jnp.float32),
            pltpu.VMEM((hq, d_p), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, d_p), q.dtype),
        interpret=interpret,
    )(block_table, lengths, q, k_pages, v_pages)
    return out[:, :, :d]
