"""Pallas TPU Mamba2 SSD chunk-scan kernel (long-context hot spot for the
hybrid/ssm architectures).

TPU-native structure: the inter-chunk recurrence is carried in VMEM scratch
across the *sequential* chunk axis of the grid — the TPU grid IS the scan.
Each grid step does three MXU matmuls on one chunk:

  G      = (C B^T) ⊙ exp(segsum(a))          (chunk x chunk, lower-tri)
  y      = G x  +  exp(cumsum a) · (C state^T)
  state' = exp(total) state + x^T (B ⊙ w)    w_j = exp(total - cum_j)

with chunk=128 (MXU-aligned).  No CUDA-style warp tricks are needed: the
parallel-prefix structure maps onto the systolic array as dense per-chunk
matmuls plus an O(1)-state carry.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, *,
                chunk: int, n_chunks: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    x = x_ref[0].astype(jnp.float32)          # (chunk, P)
    a = a_ref[0].astype(jnp.float32)          # (chunk,)
    B = b_ref[0].astype(jnp.float32)          # (chunk, N)
    C = c_ref[0].astype(jnp.float32)          # (chunk, N)

    cs = jnp.cumsum(a)                        # (chunk,)
    total = cs[-1]
    # intra-chunk: G[i,j] = C_i·B_j * exp(cs_i - cs_j) for j <= i
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    seg = cs[:, None] - cs[None, :]
    tri = (jax.lax.iota(jnp.int32, chunk)[:, None]
           >= jax.lax.iota(jnp.int32, chunk)[None, :])
    G = jnp.where(tri, scores * jnp.exp(seg), 0.0)
    y = jax.lax.dot_general(G, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: contribution of the carried state
    st = st_ref[...]                          # (P, N)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        C, st, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update
    w = jnp.exp(total - cs)[:, None] * B       # (chunk, N)
    st_ref[...] = (jnp.exp(total) * st
                   + jax.lax.dot_general(x, w, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32))


def ssd_scan_kernel(x: jax.Array, a: jax.Array, B: jax.Array,
                    C: jax.Array, *, chunk: int = 128,
                    interpret: bool = False
                    ) -> tuple[jax.Array, None]:
    """x: (b, l, h, p); a: (b, l, h); B/C: (b, l, n) -> y: (b, l, h, p).

    The (batch, head) pairs become grid rows; B/C are shared across heads
    via the index_map (no H-fold duplication in HBM).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    n_chunks = lp // chunk

    xr = x.transpose(0, 2, 1, 3).reshape(b * h, lp, p)
    ar = a.transpose(0, 2, 1).reshape(b * h, lp)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    y = pl.pallas_call(
        kernel,
        grid=(b * h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, chunk, n), lambda bh, c, h=h: (bh // h, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, c, h=h: (bh // h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda bh, c: (bh, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lp, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, ar, B, C)
    y = y.reshape(b, h, lp, p).transpose(0, 2, 1, 3)[:, :l]
    return y, None
