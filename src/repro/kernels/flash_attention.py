"""Pallas TPU flash attention (prefill hot spot).

Design (TPU-native, not a CUDA port):
  - grid = (batch * n_q_heads, n_q_blocks, n_kv_blocks); the TPU executes
    the grid sequentially minor-most first, so the kv-block axis acts as
    the online-softmax reduction loop.
  - BlockSpec tiles q/k/v into VMEM: q (1, TQ, D), k/v (1, TK, D); the
    output block (1, TQ, D) is revisited across the kv axis while the
    running max / sum / accumulator live in VMEM scratch.
  - GQA without materializing repeated KV: the k/v index_map divides the
    q-head grid coordinate by the group size, so all G query heads of a
    group stream the SAME kv rows from HBM.
  - causal + sliding-window masking by absolute positions; kv blocks
    entirely beyond the diagonal (or outside the window) are skipped with
    pl.when (no MXU work, no VMEM traffic).

Default 128x128 blocks are MXU-aligned; the working set
(q + k + v + acc at 128x128xf32 = 256 KiB) sits comfortably in a v5e
core's ~16 MiB VMEM, leaving room for double buffering.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, sm_scale: float,
                  block_q: int, block_k: int, kv_len: int,
                  n_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level skip: fully-masked kv blocks do no work
    last_q = iq * block_q + block_q - 1
    first_q = iq * block_q
    first_k = ik * block_k
    last_k = first_k + block_k - 1
    live = first_k < kv_len
    if causal:
        live = jnp.logical_and(live, first_k <= last_q)
    if window > 0:
        live = jnp.logical_and(live, last_k > first_q - window)

    @pl.when(live)
    def _compute():
        q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)
        k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
        q = q_ref[0].astype(jnp.float32)              # (TQ, D)
        k = k_ref[0].astype(jnp.float32)              # (TK, D)
        v = v_ref[0].astype(jnp.float32)              # (TK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (TQ, TK)
        mask = k_pos[None, :] < kv_len
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (TQ,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # guard fully-masked rows: exp(-inf - -inf) would NaN
        safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask, jnp.exp(s - safe_m[:, None]), 0.0)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                          jnp.exp(m_prev - safe_m))
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D).

    Supports GQA (Hq a multiple of Hkv); D and S are padded to block
    multiples internally and un-padded on return.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    sm_scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, max(128, 1 << (sq - 1).bit_length()))
    block_q = min(block_q, 128 if sq >= 128 else _pow2(sq))
    block_k = min(block_k, 128 if skv >= 128 else _pow2(skv))

    dp = (-d) % 128
    qp = (-sq) % block_q
    kp = (-skv) % block_k
    if dp or qp:
        q = jnp.pad(q, ((0, 0), (0, qp), (0, 0), (0, dp)))
    if dp or kp:
        k = jnp.pad(k, ((0, 0), (0, kp), (0, 0), (0, dp)))
        v = jnp.pad(v, ((0, 0), (0, kp), (0, 0), (0, dp)))
    sq_p, skv_p, d_p = sq + qp, skv + kp, d + dp

    # (B, S, H, D) -> (B*H, S, D); kv rows shared across each q group
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, sq_p, d_p)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv_p, d_p)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv_p, d_p)

    n_q_blocks = sq_p // block_q
    n_kv_blocks = skv_p // block_k

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, kv_len=skv,
        n_kv_blocks=n_kv_blocks)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q_blocks, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d_p), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, block_k, d_p),
                         lambda h, iq, ik: (h // g, ik, 0)),
            pl.BlockSpec((1, block_k, d_p),
                         lambda h, iq, ik: (h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d_p),
                               lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, d_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d_p), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    out = out.reshape(b, hq, sq_p, d_p).transpose(0, 2, 1, 3)
    return out[:, :sq, :, :d]


def _pow2(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p
