"""Pure-jnp oracles for every Pallas kernel (the correctness references).

These are the semantics the kernels must match; tests sweep shapes/dtypes
and assert allclose against these.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _repeat_kv(kv: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return kv
    b, s, h, d = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :],
                            (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)   # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block_table: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """Decode attention over a paged KV cache.

    q:           (B, Hq, D)        one query token per sequence
    k/v_pages:   (P, page, Hkv, D) physical page pool
    block_table: (B, pages_per_seq) int32 physical page ids
    lengths:     (B,) int32 current sequence lengths
    returns      (B, Hq, D)
    """
    b, hq, d = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    per_seq = block_table.shape[1]
    g = hq // hkv
    # gather each sequence's logical KV: (B, per_seq*page, Hkv, D)
    k = k_pages[block_table].reshape(b, per_seq * page, hkv, d)
    v = v_pages[block_table].reshape(b, per_seq * page, hkv, d)
    k = _repeat_kv(k, g)
    v = _repeat_kv(v, g)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    pos = jnp.arange(per_seq * page)[None, :]
    mask = pos < lengths[:, None]
    s = jnp.where(mask[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                 init_state: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (the exact semantics).

    x: (b, l, h, p); a: (b, l, h) log-decay; B/C: (b, l, n).
    state: (b, h, p, n).  y_t = C_t · s_t,  s_t = exp(a_t)·s_{t-1} + x_t⊗B_t
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    s0 = (init_state if init_state is not None
          else jnp.zeros((b, h, p, n), jnp.float32))

    def step(s, inp):
        xt, at, Bt, Ct = inp
        s = s * jnp.exp(at)[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt.astype(jnp.float32),
            Bt.astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", s, Ct.astype(jnp.float32))
        return s, y

    s, ys = jax.lax.scan(
        step, s0, (x.transpose(1, 0, 2, 3), a.transpose(1, 0, 2),
                   B.transpose(1, 0, 2), C.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3), s
