"""Jit'd dispatch wrappers: Pallas kernel on TPU, pure-jnp oracle elsewhere.

The rest of the framework calls these entry points; the backend decision is
made once here.  ``interpret=True`` forces the Pallas path with the
interpreter (CPU validation — what the kernel tests use).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.paged_attention import paged_attention_kernel
from repro.kernels.ssd_scan import ssd_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "force_kernel", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    force_kernel: bool = False, interpret: bool = False):
    if force_kernel or interpret or _on_tpu():
        return flash_attention_kernel(q, k, v, causal=causal,
                                      window=window, interpret=interpret
                                      or not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("force_kernel", "interpret"))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    force_kernel: bool = False, interpret: bool = False):
    if force_kernel or interpret or _on_tpu():
        return paged_attention_kernel(q, k_pages, v_pages, block_table,
                                      lengths, interpret=interpret
                                      or not _on_tpu())
    return ref.paged_attention_ref(q, k_pages, v_pages, block_table,
                                   lengths)


@functools.partial(jax.jit, static_argnames=("chunk", "force_kernel",
                                             "interpret"))
def ssd_scan(x, a, B, C, *, chunk: int = 128, force_kernel: bool = False,
             interpret: bool = False):
    if force_kernel or interpret or _on_tpu():
        y, _ = ssd_scan_kernel(x, a, B, C, chunk=chunk,
                               interpret=interpret or not _on_tpu())
        return y
    y, _ = ref.ssd_scan_ref(x, a, B, C)
    return y
