"""Pallas TPU kernels for the compute hot spots + jnp oracles.

flash_attention — prefill attention (GQA/SWA), VMEM-tiled online softmax
paged_attention — decode over paged KV cache (block tables, scalar prefetch)
ssd_scan        — Mamba2 SSD chunk scan (sequential grid carries the state)
"""
