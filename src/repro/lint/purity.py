"""Determinism auditor — the AST half of ``repro.lint``.

Walks the control-loop packages (``sim``, ``dpu``, ``core``, ``obs``,
``serving``) and flags the four classes of nondeterminism that have
historically surfaced as mysterious golden churn PRs later:

``wall-clock``
    Calls to ``time.time`` / ``time.perf_counter`` / ``datetime.now`` and
    friends.  Sim results must replay bit-identically from a seed; a wall
    clock on any simulated path breaks that silently.  The sampled-timing
    sites in ``core/telemetry.py`` (the overhead measurement the
    benchmarks report — deliberately wall-clock, deliberately off the
    result path) are exempted by the ``WALL_CLOCK_ALLOWLIST`` below; each
    entry carries its reason and surfaces in the report as a *suppressed*
    finding, so the exemption inventory is as auditable as a pragma.

``unseeded-rng``
    Module-level RNG draws (``np.random.rand`` etc., bare ``random.*``)
    and unseeded generator constructions (``np.random.default_rng()`` /
    ``random.Random()`` with no arguments).  Every draw must flow through
    a seeded ``np.random.Generator`` threaded from ``SimParams`` — the
    invariant that keeps "zero RNG drawn when knobs are off" checkable at
    all.  ``jax.random`` is exempt by construction (functional, key-based).

``mutable-default``
    Mutable default arguments — shared across calls, the classic
    cross-run state leak.

``unguarded-hook``
    A call through a ``.tracer`` / ``.recorder`` attribute (or a local
    alias of one) that is not dominated by a ``None`` guard within the
    enclosing function — the PR-9 invariant ("every hook site
    None-guarded") checked by a small dominator walk over the function
    body rather than by convention.  Recognized guard shapes::

        if self.tracer is not None: self.tracer.on_x(...)
        if self.tracer is None: return          # early-out dominator
        t = self.tracer
        if t: t.on_x(...)                       # alias + truthiness
        x = a.tracer.reports() if a.tracer is not None else []
        tracer is not None and tracer.on_x(...)

    ``getattr(obj, "tracer", None)`` normalizes to ``obj.tracer`` so
    defensive lookups guard the same key.
"""

from __future__ import annotations

import ast

from repro.lint.findings import LintFinding

#: wall-clock reads; anything else on these modules is fine (time.sleep
#: never appears on a simulated path, and flagging sleeps is out of scope)
WALL_CLOCK_FNS = {
    "time", "time_ns", "perf_counter", "perf_counter_ns",
    "monotonic", "monotonic_ns", "process_time", "process_time_ns",
    "clock", "now", "utcnow", "today",
}

#: np.random constructors that are fine WHEN GIVEN a seed argument
SEEDED_CTORS = {"default_rng", "Generator", "SeedSequence", "PCG64",
                "Philox", "MT19937", "SFC64", "RandomState"}

#: (repo-relative path, function qualname) -> reason.  The only legal home
#: for wall-clock reads on the telemetry path: the sampled overhead-timing
#: windows whose whole job is to measure real elapsed time.  These surface
#: as suppressed findings (with these reasons) in every report.
WALL_CLOCK_ALLOWLIST: dict[tuple[str, str], str] = {
    ("src/repro/core/telemetry.py", "DPUAgent._update_timed"):
        "sampled per-detector overhead timing — measures wall time by "
        "design, off the result path",
    ("src/repro/core/telemetry.py", "DPUAgent.observe"):
        "sampled (every-Nth-event) ingest overhead timing window",
    ("src/repro/core/telemetry.py", "DPUAgent.observe_batch"):
        "sampled (every-Nth-batch) ingest overhead timing window",
    ("src/repro/core/telemetry.py", "DPUAgent.poll"):
        "detector poll overhead accounting (TelemetryStats.poll_seconds)",
}

#: attribute names whose holders are observability hooks: any call routed
#: through one of these must be None-guarded (tracing is always optional)
HOOK_ATTRS = ("tracer", "recorder")


# ---------------------------------------------------------------------------
# expression normalization


def _normalize(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Dotted-path rendering of an expression, resolving local aliases and
    ``getattr(x, "y", ...)`` to ``x.y``.  None for anything non-trivial."""
    if isinstance(node, ast.Name):
        return aliases.get(node.id, node.id)
    if isinstance(node, ast.Attribute):
        base = _normalize(node.value, aliases)
        return None if base is None else f"{base}.{node.attr}"
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id == "getattr" and len(node.args) >= 2
            and isinstance(node.args[1], ast.Constant)
            and isinstance(node.args[1].value, str)):
        base = _normalize(node.args[0], aliases)
        return None if base is None else f"{base}.{node.args[1].value}"
    return None


def _guard_covers(key: str, guarded: frozenset) -> bool:
    """Is ``key`` (a call receiver) dominated by a guard?  A guard on the
    hook holder itself covers deeper attribute access — once ``tracer``
    is known non-None, ``tracer.counters.get(...)`` is safe; the rule
    only polices the holder being None."""
    if key in guarded:
        return True
    parts = key.split(".")
    for i in range(1, len(parts)):
        if parts[i - 1] in HOOK_ATTRS and ".".join(parts[:i]) in guarded:
            return True
    return False


def _is_hook_expr(path: str | None) -> bool:
    """Does this dotted path route through a hook holder attribute?"""
    if path is None:
        return False
    parts = path.split(".")
    # the final segment is the method being called; any earlier segment
    # being a hook attr means the receiver is (reached through) a hook
    return any(p in HOOK_ATTRS for p in parts[:-1]) or (
        len(parts) >= 2 and parts[-2] in HOOK_ATTRS)


# ---------------------------------------------------------------------------
# guard extraction (the dominator walk's transfer functions)


def _guards_from_test(test: ast.expr, aliases: dict[str, str],
                      ) -> tuple[set[str], set[str]]:
    """(non_none_if_true, non_none_if_false) keys established by a test.

    ``x is not None`` / bare truthiness guard the true branch;
    ``x is None`` / ``not x`` guard the false branch; ``and`` chains
    accumulate conjunct guards on the true side.
    """
    true_set: set[str] = set()
    false_set: set[str] = set()
    if isinstance(test, ast.Compare) and len(test.ops) == 1:
        left = _normalize(test.left, aliases)
        right = test.comparators[0]
        is_none = isinstance(right, ast.Constant) and right.value is None
        if left is not None and is_none:
            if isinstance(test.ops[0], ast.IsNot):
                true_set.add(left)
            elif isinstance(test.ops[0], ast.Is):
                false_set.add(left)
        return true_set, false_set
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        t, f = _guards_from_test(test.operand, aliases)
        return f, t
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        for v in test.values:
            t, _ = _guards_from_test(v, aliases)
            true_set |= t
        return true_set, set()
    key = _normalize(test, aliases)
    if key is not None:               # bare truthiness: `if self.tracer:`
        true_set.add(key)
    return true_set, set()


def _terminates(body: list[ast.stmt]) -> bool:
    """Does this block unconditionally leave the enclosing scope/loop?"""
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


class _FunctionAuditor:
    """Per-function unguarded-hook analysis: a linear dominator walk that
    threads the set of known-non-None hook keys through the statement
    list, branching at ifs and re-joining after early-out guards."""

    def __init__(self, checker: "PurityChecker", qualname: str) -> None:
        self.checker = checker
        self.qualname = qualname
        self.aliases: dict[str, str] = {}

    def run(self, fn: ast.AST) -> None:
        self._collect_aliases(fn)
        self._walk_block(fn.body, frozenset())

    def _collect_aliases(self, fn: ast.AST) -> None:
        """``t = self.tracer``-style bindings, function-wide.  A name
        rebound to two different hook paths is dropped (ambiguous)."""
        dropped: set[str] = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                name = node.targets[0].id
                path = _normalize(node.value, {})
                if path is not None and _is_hook_expr(f"{path}._"):
                    if name in self.aliases and self.aliases[name] != path:
                        dropped.add(name)
                    self.aliases[name] = path
        for name in dropped:
            self.aliases.pop(name, None)

    # -- statements ------------------------------------------------------

    def _walk_block(self, body: list[ast.stmt],
                    guarded: frozenset) -> frozenset:
        for stmt in body:
            guarded = self._walk_stmt(stmt, guarded)
        return guarded

    def _walk_stmt(self, stmt: ast.stmt, guarded: frozenset) -> frozenset:
        if isinstance(stmt, ast.If):
            t, f = _guards_from_test(stmt.test, self.aliases)
            self._check_expr(stmt.test, guarded)
            self._walk_block(stmt.body, guarded | t)
            self._walk_block(stmt.orelse, guarded | f)
            # early-out dominator: `if x is None: return` guards the rest
            if f and not stmt.orelse and _terminates(stmt.body):
                guarded = guarded | f
            return guarded
        if isinstance(stmt, ast.While):
            t, _ = _guards_from_test(stmt.test, self.aliases)
            self._check_expr(stmt.test, guarded)
            self._walk_block(stmt.body, guarded | t)
            self._walk_block(stmt.orelse, guarded)
            return guarded
        if isinstance(stmt, ast.For):
            self._check_expr(stmt.iter, guarded)
            self._walk_block(stmt.body, guarded)
            self._walk_block(stmt.orelse, guarded)
            return guarded
        if isinstance(stmt, ast.Try):
            self._walk_block(stmt.body, guarded)
            for h in stmt.handlers:
                self._walk_block(h.body, guarded)
            self._walk_block(stmt.orelse, guarded)
            self._walk_block(stmt.finalbody, guarded)
            return guarded
        if isinstance(stmt, ast.With):
            for item in stmt.items:
                self._check_expr(item.context_expr, guarded)
            self._walk_block(stmt.body, guarded)
            return guarded
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return guarded            # nested defs audited on their own
        if isinstance(stmt, ast.Assert):
            # `assert x is not None` dominates everything after it
            t, _ = _guards_from_test(stmt.test, self.aliases)
            return guarded | t
        if isinstance(stmt, ast.Assign):
            # assigning a hook key kills its guard (it may now be None)
            self._check_expr(stmt.value, guarded)
            killed = {
                _normalize(t, self.aliases)
                for t in stmt.targets
            } - {None}
            return frozenset(k for k in guarded if k not in killed)
        for node in ast.iter_child_nodes(stmt):
            if isinstance(node, ast.expr):
                self._check_expr(node, guarded)
        return guarded

    # -- expressions -----------------------------------------------------

    def _check_expr(self, expr: ast.expr, guarded: frozenset) -> None:
        if isinstance(expr, ast.IfExp):
            t, f = _guards_from_test(expr.test, self.aliases)
            self._check_expr(expr.test, guarded)
            self._check_expr(expr.body, guarded | t)
            self._check_expr(expr.orelse, guarded | f)
            return
        if isinstance(expr, ast.BoolOp):
            acc = frozenset(guarded)
            for v in expr.values:
                self._check_expr(v, acc)
                if isinstance(expr.op, ast.And):
                    t, _ = _guards_from_test(v, self.aliases)
                    acc = acc | t
            return
        if isinstance(expr, ast.Call):
            path = _normalize(expr.func, self.aliases)
            if _is_hook_expr(path):
                key = path.rsplit(".", 1)[0]
                if not _guard_covers(key, guarded):
                    self.checker._hook_finding(expr, path, self.qualname)
            self._check_expr(expr.func, guarded)
            for a in expr.args:
                self._check_expr(a, guarded)
            for kw in expr.keywords:
                self._check_expr(kw.value, guarded)
            return
        if isinstance(expr, (ast.FunctionDef, ast.Lambda)):
            return
        for node in ast.iter_child_nodes(expr):
            if isinstance(node, ast.expr):
                self._check_expr(node, guarded)


# ---------------------------------------------------------------------------
# the file-level pass


class PurityChecker(ast.NodeVisitor):
    """One file's determinism audit; collect with :func:`lint_source`."""

    def __init__(self, path: str) -> None:
        self.path = path
        self.findings: list[LintFinding] = []
        self._qual: list[str] = []      # class/function nesting stack
        # module-alias tracking: local name -> canonical module
        self._modules: dict[str, str] = {}
        # names imported from modules: local name -> "module.attr"
        self._from_imports: dict[str, str] = {}

    # -- imports ---------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self._modules[a.asname or a.name.split(".")[0]] = a.name
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module is not None and not node.level:
            for a in node.names:
                self._from_imports[a.asname or a.name] = \
                    f"{node.module}.{a.name}"
        self.generic_visit(node)

    # -- scoping ---------------------------------------------------------

    @property
    def qualname(self) -> str:
        return ".".join(self._qual) or "<module>"

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._qual.append(node.name)
        self.generic_visit(node)
        self._qual.pop()

    def _visit_function(self, node) -> None:
        self._check_defaults(node)
        self._qual.append(node.name)
        # the hook dominator walk runs per function body
        _FunctionAuditor(self, self.qualname).run(node)
        self.generic_visit(node)
        self._qual.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    # -- mutable defaults ------------------------------------------------

    def _check_defaults(self, node) -> None:
        args = node.args
        for default in (*args.defaults, *args.kw_defaults):
            if default is None:
                continue
            bad = None
            if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                bad = {ast.List: "[]", ast.Dict: "{}",
                       ast.Set: "{...}"}[type(default)]
            elif (isinstance(default, ast.Call)
                    and isinstance(default.func, ast.Name)
                    and default.func.id in ("list", "dict", "set",
                                            "bytearray")):
                bad = f"{default.func.id}()"
            if bad is not None:
                self.findings.append(LintFinding(
                    "mutable-default", self.path, default.lineno,
                    f"mutable default {bad} on {node.name}() — shared "
                    "across calls; use None + in-body construction (or "
                    "dataclasses.field(default_factory=...))"))

    # -- calls: wall clock + rng -----------------------------------------

    def _canonical_call(self, func: ast.expr) -> str | None:
        """Render a call target as 'module.attr[.attr]' in canonical
        module names, resolving import aliases; None if untraceable."""
        if isinstance(func, ast.Name):
            return self._from_imports.get(func.id)
        if isinstance(func, ast.Attribute):
            parts = [func.attr]
            cur = func.value
            while isinstance(cur, ast.Attribute):
                parts.append(cur.attr)
                cur = cur.value
            if not isinstance(cur, ast.Name):
                return None
            root = cur.id
            if root in self._modules:
                parts.append(self._modules[root])
            elif root in self._from_imports:
                parts.append(self._from_imports[root])
            else:
                return None
            return ".".join(reversed(parts))
        return None

    def visit_Call(self, node: ast.Call) -> None:
        target = self._canonical_call(node.func)
        if target is not None:
            self._check_wall_clock(node, target)
            self._check_rng(node, target)
        self.generic_visit(node)

    def _check_wall_clock(self, node: ast.Call, target: str) -> None:
        mod, _, fn = target.rpartition(".")
        is_clock = (
            (mod == "time" and fn in WALL_CLOCK_FNS)
            or (mod in ("datetime", "datetime.datetime", "datetime.date")
                and fn in ("now", "utcnow", "today"))
        )
        if not is_clock:
            return
        allow = WALL_CLOCK_ALLOWLIST.get((self.path, self.qualname))
        self.findings.append(LintFinding(
            "wall-clock", self.path, node.lineno,
            f"{target}() in {self.qualname} — wall-clock reads break "
            "seeded replay; thread sim time in instead",
            suppressed=allow is not None,
            reason=allow or ""))

    def _check_rng(self, node: ast.Call, target: str) -> None:
        parts = target.split(".")
        # numpy module-level RNG: numpy.random.<fn>(...)
        if len(parts) >= 3 and parts[0] == "numpy" and parts[1] == "random":
            fn = parts[2]
            if fn not in SEEDED_CTORS:
                self.findings.append(LintFinding(
                    "unseeded-rng", self.path, node.lineno,
                    f"module-level np.random.{fn}() in {self.qualname} — "
                    "draws from global state; use the seeded "
                    "np.random.Generator threaded from SimParams"))
            elif fn == "default_rng" and not node.args and not node.keywords:
                self.findings.append(LintFinding(
                    "unseeded-rng", self.path, node.lineno,
                    f"np.random.default_rng() without a seed in "
                    f"{self.qualname} — entropy-seeded; thread the seed "
                    "from SimParams"))
            return
        # stdlib random: bare module functions, or Random() without seed
        if parts[0] == "random" and len(parts) >= 2:
            fn = parts[1]
            if fn == "Random":
                if not node.args and not node.keywords:
                    self.findings.append(LintFinding(
                        "unseeded-rng", self.path, node.lineno,
                        f"random.Random() without a seed in "
                        f"{self.qualname}"))
            elif fn[:1].islower():
                self.findings.append(LintFinding(
                    "unseeded-rng", self.path, node.lineno,
                    f"bare random.{fn}() in {self.qualname} — global-state "
                    "draw; use a seeded np.random.Generator"))

    # -- hook findings (reported by the dominator walk) ------------------

    def _hook_finding(self, node: ast.Call, path: str,
                      qualname: str) -> None:
        recv, _, meth = path.rpartition(".")
        self.findings.append(LintFinding(
            "unguarded-hook", self.path, node.lineno,
            f"{recv}.{meth}() in {qualname} not dominated by a None "
            f"guard on '{recv}' — hook holders default to None and every "
            "call site must tolerate that"))


def lint_source(source: str, path: str) -> list[LintFinding]:
    """Audit one file's source text (the unit-test entry point)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:                       # pragma: no cover
        return [LintFinding("wall-clock", path, e.lineno or 0,
                            f"unparseable file: {e.msg}")]
    checker = PurityChecker(path)
    checker.visit(tree)
    return checker.findings
