"""Registry-wiring checker — the cross-module half of ``repro.lint``.

The runbook registry is the reproduction's spine: every row must resolve,
end to end, into a detector class, ≥1 fault scenario, a golden fixture
entry, an attribution rule, a registered mitigation action (with a policy
conflict-group resolution), and — directly or by exclusion pragma — a seat
in the CI smoke sweep.  Before this module those links were held together
by naming convention plus import-time ``assert``s scattered across
``core/mitigation.py``, ``dpu/policy.py``, and hardcoded counts in
``tests/test_runbooks.py``.  They now live here, in one statically
checkable pass — and this contract is deliberately the first step of the
ROADMAP plugin-registry refactor: whatever ``@runbook_row`` decorator
registry replaces the hand-wired tables must keep :func:`check_wiring`
green, which pins the full chain while the wiring underneath it moves.

``EXPECTED_TABLE_COUNTS`` below is the single declared source for registry
size; the previously hardcoded row/table counts in ``tests/test_runbooks``
assert against it through :func:`expected_rows`.

Orphans are errors in both directions: a golden entry whose scenario is
gone, an ``ACTIONS`` key no row emits, a ``DIRECT_LOCUS`` rule for a row
that no longer exists, a detector class no row binds — each is stale
wiring that would otherwise rot silently.
"""

from __future__ import annotations

import json
import re
from pathlib import Path

from repro.lint.findings import LintFinding

#: the single source of truth for registry size.  Adding a runbook row
#: means bumping the one number here — every count assertion elsewhere
#: (tests, docs) derives from this table.
EXPECTED_TABLE_COUNTS: dict[str, int] = {
    "3a": 9,       # the paper's ingress/egress rows
    "3b": 10,      # host <-> PCIe rows
    "3c": 9,       # east-west collective rows
    "3d": 2,       # data-parallel routing extensions
    "3e": 3,       # per-collective / rail / memory-knee tier
    "dpu": 1,      # the telemetry plane's self-diagnosis row
    "mon": 5,      # monitoring-plane robustness rows
}

#: scenarios with no bound runbook row — healthy baselines measure the
#: false-positive budget and are exempt from the row-chain checks
BASELINE_ROW_ID = ""

GOLDEN_REL = Path("tests") / "golden" / "scenario_findings.json"
FAULTS_REL = Path("src") / "repro" / "sim" / "faults.py"


def expected_rows() -> int:
    """Total registry size implied by ``EXPECTED_TABLE_COUNTS``."""
    return sum(EXPECTED_TABLE_COUNTS.values())


def _registry_anchor(root: Path) -> tuple[str, dict[str, int]]:
    """(relpath, row_id -> line) anchors into ``core/runbooks.py`` so
    wiring findings point at the offending row, not the module."""
    rel = Path("src") / "repro" / "core" / "runbooks.py"
    lines: dict[str, int] = {}
    try:
        text = (root / rel).read_text()
    except OSError:
        return rel.as_posix(), lines
    for i, line in enumerate(text.splitlines(), start=1):
        m = re.search(r'^\s*"([a-z0-9_]+)",\s*"(?:3[a-e]|dpu|mon)"', line)
        if m and m.group(1) not in lines:
            lines[m.group(1)] = i
    return rel.as_posix(), lines


def scenario_anchors(root: Path) -> tuple[str, dict[str, int]]:
    """(relpath, scenario name -> line) anchors into ``sim/faults.py`` —
    the line each scenario is registered on, which is also where a
    ``smoke-coverage`` exclusion pragma must sit."""
    lines: dict[str, int] = {}
    try:
        text = (root / FAULTS_REL).read_text()
    except OSError:
        return FAULTS_REL.as_posix(), lines
    pat = re.compile(r'(?:add\(\s*|s\[)"([a-z0-9_]+)"')
    for i, line in enumerate(text.splitlines(), start=1):
        for m in pat.finditer(line):
            lines.setdefault(m.group(1), i)
    return FAULTS_REL.as_posix(), lines


def check_wiring(root: Path | None = None) -> list[LintFinding]:
    """Statically verify the full detector/scenario/golden/attribution/
    action chain for every registry row.  Imports the registries (cheap,
    already import-time safe) but runs nothing."""
    from repro.core.attribution import DIRECT_LOCUS
    from repro.core.detectors import ALL_DETECTORS, Detector
    from repro.core.mitigation import ACTIONS
    from repro.core.runbooks import ALL_RUNBOOKS, BY_ID, BY_TABLE
    from repro.dpu.policy import CONFLICT_GROUPS
    from repro.sim.faults import SCENARIOS
    from repro.sim.sweep import SMOKE_SCENARIOS

    root = root or repo_root()
    out: list[LintFinding] = []
    reg_path, reg_lines = _registry_anchor(root)
    sc_path, sc_lines = scenario_anchors(root)

    def row_finding(rule: str, row_id: str, msg: str) -> None:
        out.append(LintFinding(rule, reg_path, reg_lines.get(row_id, 0),
                               msg))

    # -- table counts (the one declared size) ----------------------------
    tables = {t: len(rows) for t, rows in BY_TABLE.items()}
    if set(tables) != set(EXPECTED_TABLE_COUNTS):
        out.append(LintFinding(
            "wiring-counts", reg_path, 0,
            f"registry tables {sorted(tables)} != declared "
            f"{sorted(EXPECTED_TABLE_COUNTS)}"))
    for t in sorted(set(tables) & set(EXPECTED_TABLE_COUNTS)):
        if tables[t] != EXPECTED_TABLE_COUNTS[t]:
            out.append(LintFinding(
                "wiring-counts", reg_path, 0,
                f"table {t} has {tables[t]} rows, declared "
                f"{EXPECTED_TABLE_COUNTS[t]} — update "
                "repro.lint.wiring.EXPECTED_TABLE_COUNTS with the row"))
    if len(ALL_RUNBOOKS) != len(BY_ID):
        out.append(LintFinding(
            "wiring-counts", reg_path, 0,
            f"{len(ALL_RUNBOOKS) - len(BY_ID)} duplicate row_id(s) in "
            "ALL_RUNBOOKS"))

    # -- per-row chain ---------------------------------------------------
    scen_by_row: dict[str, list[str]] = {}
    for name, sc in SCENARIOS.items():
        if sc.row_id:
            scen_by_row.setdefault(sc.row_id, []).append(name)

    for e in ALL_RUNBOOKS:
        # detector class: exists, subclasses Detector, names itself
        # identically (detectors key their findings by class attrs)
        if not (isinstance(e.detector_cls, type)
                and issubclass(e.detector_cls, Detector)):
            row_finding("wiring-detector", e.row_id,
                        f"{e.row_id}: detector_cls is not a Detector "
                        "subclass")
        else:
            if getattr(e.detector_cls, "name", None) != e.row_id:
                row_finding(
                    "wiring-detector", e.row_id,
                    f"{e.row_id}: detector {e.detector_cls.__name__}.name "
                    f"is {getattr(e.detector_cls, 'name', None)!r}")
            if getattr(e.detector_cls, "table", None) != e.table:
                row_finding(
                    "wiring-detector", e.row_id,
                    f"{e.row_id}: detector {e.detector_cls.__name__}.table "
                    f"is {getattr(e.detector_cls, 'table', None)!r}, row "
                    f"says {e.table!r}")
            if e.detector_cls not in ALL_DETECTORS:
                row_finding(
                    "wiring-detector", e.row_id,
                    f"{e.row_id}: {e.detector_cls.__name__} missing from "
                    "detectors.ALL_DETECTORS")
        # scenario chain: the canonical scenario exists, points back, and
        # the row has >= 1 scenario overall
        if e.scenario not in SCENARIOS:
            row_finding("wiring-scenario", e.row_id,
                        f"{e.row_id}: scenario {e.scenario!r} not in "
                        "sim.faults.SCENARIOS")
        elif SCENARIOS[e.scenario].row_id != e.row_id:
            row_finding(
                "wiring-scenario", e.row_id,
                f"{e.row_id}: scenario {e.scenario!r} validates "
                f"{SCENARIOS[e.scenario].row_id!r}, not this row")
        if not scen_by_row.get(e.row_id):
            row_finding("wiring-scenario", e.row_id,
                        f"{e.row_id}: no scenario validates this row")
        # attribution rule
        if e.row_id not in DIRECT_LOCUS:
            row_finding("wiring-attribution", e.row_id,
                        f"{e.row_id}: no attribution.DIRECT_LOCUS entry")
        # action registered + conflict-group resolvable (an action absent
        # from CONFLICT_GROUPS arbitrates as its own singleton group,
        # which is a valid resolution — membership is only checked for
        # consistency below)
        if e.action not in ACTIONS:
            row_finding("wiring-action", e.row_id,
                        f"{e.row_id}: action {e.action!r} not registered "
                        "in mitigation.ACTIONS")
        # siblings are real, distinct rows
        for sib in e.sibling_rows:
            if sib == e.row_id:
                row_finding("wiring-sibling", e.row_id,
                            f"{e.row_id}: lists itself as a sibling")
            elif sib not in BY_ID:
                row_finding("wiring-sibling", e.row_id,
                            f"{e.row_id}: sibling {sib!r} is not a "
                            "registry row")

    # -- orphans (stale wiring, reverse direction) -----------------------
    bound_detectors = {e.detector_cls for e in ALL_RUNBOOKS}
    for cls in ALL_DETECTORS:
        if cls not in bound_detectors:
            out.append(LintFinding(
                "wiring-detector", reg_path, 0,
                f"detector {cls.__name__} ({getattr(cls, 'name', '?')}) "
                "is bound to no runbook row"))
    for name, sc in SCENARIOS.items():
        if sc.row_id and sc.row_id not in BY_ID:
            out.append(LintFinding(
                "wiring-scenario", sc_path, sc_lines.get(name, 0),
                f"scenario {name!r} validates unknown row "
                f"{sc.row_id!r}"))
    emitted = {e.action for e in ALL_RUNBOOKS}
    for action in sorted(set(ACTIONS) - emitted):
        out.append(LintFinding(
            "wiring-action", "src/repro/core/mitigation.py", 0,
            f"ACTIONS[{action!r}] is emitted by no runbook row — stale "
            "actuation surface"))
    for action in sorted(set(CONFLICT_GROUPS) - set(ACTIONS)):
        out.append(LintFinding(
            "wiring-action", "src/repro/dpu/policy.py", 0,
            f"CONFLICT_GROUPS[{action!r}] references an action missing "
            "from mitigation.ACTIONS"))
    for row_id in sorted(set(DIRECT_LOCUS) - set(BY_ID)):
        out.append(LintFinding(
            "wiring-attribution", "src/repro/core/attribution.py", 0,
            f"DIRECT_LOCUS[{row_id!r}] names a row that is not in the "
            "registry"))

    # -- golden fixtures -------------------------------------------------
    out.extend(_check_goldens(root, SCENARIOS))

    # -- smoke-grid coverage ---------------------------------------------
    for name in SMOKE_SCENARIOS:
        if name not in SCENARIOS:
            out.append(LintFinding(
                "smoke-coverage", "src/repro/sim/sweep.py", 0,
                f"--smoke grid names unknown scenario {name!r}"))
    smoke = set(SMOKE_SCENARIOS)
    for name in SCENARIOS:
        if name in smoke:
            continue
        out.append(LintFinding(
            "smoke-coverage", sc_path, sc_lines.get(name, 0),
            f"scenario {name!r} is not in the sweep --smoke grid; add it "
            "or carry an explicit exclusion pragma naming the gate that "
            "does cover it"))
    return out


def _check_goldens(root: Path, scenarios: dict) -> list[LintFinding]:
    out: list[LintFinding] = []
    gpath = (root / GOLDEN_REL)
    rel = GOLDEN_REL.as_posix()
    try:
        payload = json.loads(gpath.read_text())
    except (OSError, ValueError) as e:
        return [LintFinding("wiring-golden", rel, 0,
                            f"cannot load golden fixtures: {e}")]
    golden = payload.get("scenarios", {})
    sc_path, sc_lines = scenario_anchors(root)
    for name, sc in scenarios.items():
        entry = golden.get(name)
        if entry is None:
            out.append(LintFinding(
                "wiring-golden", sc_path, sc_lines.get(name, 0),
                f"scenario {name!r} has no golden fixture entry — run "
                "tests/regen_golden.py"))
        elif entry.get("row_id", "") != sc.row_id:
            out.append(LintFinding(
                "wiring-golden", rel, 0,
                f"golden entry {name!r} pins row "
                f"{entry.get('row_id')!r}, registry says {sc.row_id!r}"))
    for name in golden:
        if name not in scenarios:
            out.append(LintFinding(
                "wiring-golden", rel, 0,
                f"stale golden entry {name!r}: no such scenario in the "
                "registry"))
    return out


def repo_root() -> Path:
    """The checkout root (…/src/repro/lint/wiring.py -> three up)."""
    return Path(__file__).resolve().parents[3]
