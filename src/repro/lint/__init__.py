"""repro.lint — determinism auditor + registry-wiring static analyzer.

Two halves: :mod:`repro.lint.purity` walks the control-loop package ASTs
for nondeterminism (wall-clock reads, unseeded RNG, mutable defaults,
unguarded tracer/recorder hooks); :mod:`repro.lint.wiring` statically
verifies the runbook registry's full detector/scenario/golden/
attribution/action chain.  Run ``python -m repro.lint``; suppress with
``# repro-lint: allow(<rule>): <reason>``.
"""

from repro.lint.cli import run_lint
from repro.lint.findings import RULES, LintFinding, LintReport
from repro.lint.purity import lint_source
from repro.lint.wiring import (EXPECTED_TABLE_COUNTS, check_wiring,
                               expected_rows)

__all__ = [
    "RULES", "LintFinding", "LintReport", "run_lint", "lint_source",
    "check_wiring", "EXPECTED_TABLE_COUNTS", "expected_rows",
]
