"""Suppression pragmas: ``# repro-lint: allow(<rule>): <reason>``.

A pragma suppresses findings of exactly one rule, anchored to exactly one
statement: the pragma either trails the statement's first line or sits on a
comment line directly above it (consecutive pragma-comment lines stack, so
one statement can carry several rules).  The reason text after the second
colon is MANDATORY — an allow() with an empty reason is itself a
``bad-pragma`` finding, and a pragma that matched nothing is reported as
``unused-pragma`` so stale suppressions cannot linger.

Scope is deliberately narrow: no file-level or block-level suppressions.
Every exemption is one line away from the code it exempts, carrying the
why, which is the whole point.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.lint.findings import RULES, LintFinding

#: the pragma grammar.  Examples::
#:
#:     t0 = time.perf_counter()   # repro-lint: allow(wall-clock): harness
#:     # repro-lint: allow(smoke-coverage): nightly full sweep covers it
#:     add("egress_jitter", ...)
PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*allow\(\s*(?P<rule>[a-z0-9-]+)\s*\)\s*"
    r"(?::\s*(?P<reason>.*\S))?\s*$")

#: a comment that *looks* like a pragma attempt but fails the grammar —
#: flagged rather than silently ignored (a typo must not un-suppress code
#: without anyone noticing)
NEAR_MISS_RE = re.compile(r"#\s*repro-lint\b")


@dataclass
class Pragma:
    rule: str
    reason: str
    path: str
    line: int                    # line the pragma comment lives on
    anchor: int                  # statement line the pragma applies to
    used: bool = False


@dataclass
class PragmaSet:
    """All pragmas of one file, indexed for matching."""

    path: str
    pragmas: list[Pragma] = field(default_factory=list)
    problems: list[LintFinding] = field(default_factory=list)

    def match(self, rule: str, line: int) -> Pragma | None:
        """First unused-or-used pragma of ``rule`` anchored at ``line``."""
        for p in self.pragmas:
            if p.rule == rule and p.anchor == line:
                p.used = True
                return p
        return None

    def unused(self) -> list[LintFinding]:
        return [
            LintFinding("unused-pragma", self.path, p.line,
                        f"allow({p.rule}) matched no finding")
            for p in self.pragmas if not p.used
        ]


def collect_pragmas(source: str, path: str) -> PragmaSet:
    """Parse every pragma in ``source``; anchor own-line pragmas to the
    next non-comment, non-blank line (stacked pragma lines share it)."""
    ps = PragmaSet(path=path)
    lines = source.splitlines()
    pending: list[Pragma] = []         # own-line pragmas awaiting an anchor
    for i, raw in enumerate(lines, start=1):
        stripped = raw.strip()
        hash_pos = raw.find("#")
        comment = raw[hash_pos:] if hash_pos >= 0 else ""
        m = PRAGMA_RE.search(comment) if comment else None
        if m:
            rule, reason = m.group("rule"), m.group("reason") or ""
            if rule not in RULES:
                ps.problems.append(LintFinding(
                    "bad-pragma", path, i,
                    f"allow({rule}): unknown rule id"))
                continue
            if not reason:
                ps.problems.append(LintFinding(
                    "bad-pragma", path, i,
                    f"allow({rule}): missing reason text — every "
                    "suppression must say why"))
                continue
            p = Pragma(rule=rule, reason=reason, path=path, line=i, anchor=i)
            if stripped.startswith("#"):
                pending.append(p)      # own-line: anchors the next stmt
            else:
                ps.pragmas.append(p)   # trailing: anchors its own line
            continue
        if comment and NEAR_MISS_RE.search(comment):
            ps.problems.append(LintFinding(
                "bad-pragma", path, i,
                "malformed repro-lint pragma (expected "
                "'# repro-lint: allow(<rule>): <reason>')"))
            continue
        if stripped.startswith("#") or not stripped:
            continue                   # blank/comment: pragmas keep waiting
        for p in pending:              # first code line anchors the stack
            p.anchor = i
            ps.pragmas.append(p)
        pending.clear()
    # pragmas at EOF with no following statement anchor nothing
    for p in pending:
        ps.problems.append(LintFinding(
            "bad-pragma", path, p.line,
            f"allow({p.rule}) anchors no statement (end of file)"))
    return ps


def apply_pragmas(findings: list[LintFinding],
                  sets: dict[str, PragmaSet]) -> list[LintFinding]:
    """Mark findings suppressed in place where a pragma anchors them;
    return the combined list plus pragma-hygiene findings."""
    for f in findings:
        ps = sets.get(f.path)
        if ps is None or f.suppressed:
            continue
        p = ps.match(f.rule, f.line)
        if p is not None:
            f.suppressed = True
            f.reason = p.reason
    out = list(findings)
    for ps in sets.values():
        out.extend(ps.problems)
        out.extend(ps.unused())
    return out
