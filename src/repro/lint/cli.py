"""``python -m repro.lint`` — run both linter halves over the tree.

Exit codes: 0 = clean (no unsuppressed findings), 1 = findings,
2 = usage / environment error.  ``--json PATH`` additionally writes the
machine-readable report (the CI gate uploads it next to the bench
artifacts).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.lint.findings import RULES, LintReport
from repro.lint.pragmas import PragmaSet, apply_pragmas, collect_pragmas
from repro.lint.purity import lint_source
from repro.lint.wiring import check_wiring, repo_root

#: the packages the determinism auditor walks
SCAN_PACKAGES = ("sim", "dpu", "core", "obs", "serving")


def iter_sources(root: Path):
    """Yield (repo-relative posix path, source text) for every scanned
    file, sorted for stable output."""
    base = root / "src" / "repro"
    for pkg in SCAN_PACKAGES:
        for py in sorted((base / pkg).rglob("*.py")):
            yield py.relative_to(root).as_posix(), py.read_text()


def run_lint(root: Path | None = None, wiring: bool = True) -> LintReport:
    """Whole-tree run: purity pass per file, wiring pass once, pragma
    matching over both."""
    root = root or repo_root()
    findings = []
    sets: dict[str, PragmaSet] = {}
    files = 0
    for rel, source in iter_sources(root):
        files += 1
        findings.extend(lint_source(source, rel))
        sets[rel] = collect_pragmas(source, rel)
    if wiring:
        findings.extend(check_wiring(root))
    findings = apply_pragmas(findings, sets)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return LintReport(findings=findings, files_scanned=files)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.lint",
        description="determinism auditor + registry-wiring checker")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the machine-readable report here")
    ap.add_argument("--root", metavar="DIR",
                    help="repo checkout root (default: inferred)")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--no-wiring", action="store_true",
                    help="skip the registry-wiring pass (AST-only)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="list suppressed findings and their reasons")
    args = ap.parse_args(argv)

    if args.rules:
        for rule, desc in RULES.items():
            print(f"{rule:20s} {desc}")
        return 0

    root = Path(args.root).resolve() if args.root else repo_root()
    if not (root / "src" / "repro").is_dir():
        print(f"repro.lint: {root} does not look like a checkout root "
              "(no src/repro)", file=sys.stderr)
        return 2

    report = run_lint(root, wiring=not args.no_wiring)

    for f in report.unsuppressed:
        print(f.format())
    if args.show_suppressed:
        for f in report.suppressed:
            print(f.format())

    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report.to_json(), indent=2) + "\n")

    n_bad = len(report.unsuppressed)
    print(f"repro.lint: {report.files_scanned} files scanned, "
          f"{n_bad} unsuppressed finding(s), "
          f"{len(report.suppressed)} suppressed", file=sys.stderr)
    return 1 if n_bad else 0
