"""Finding records and the rule catalog for ``repro.lint``.

Every rule the linter can emit is registered here with a one-line
description; the CLI's ``--rules`` flag and the README's rule catalog both
render from this table, so a rule cannot exist without documentation.

A ``LintFinding`` is plain data: rule id, repo-relative ``path:line``
anchor, message, and — once pragma matching has run — whether it is
suppressed and by which reason.  ``python -m repro.lint`` exits nonzero on
any finding with ``suppressed is False``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

#: rule id -> one-line description (the catalog; keep in sync with README)
RULES: dict[str, str] = {
    # -- determinism auditor (purity.py) ---------------------------------
    "wall-clock":
        "wall-clock read (time.time/perf_counter/datetime.now) outside the "
        "sampled-timing allowlist — sim results must replay bit-identically",
    "unseeded-rng":
        "module-level RNG draw (np.random.*, bare random.*) or unseeded "
        "generator construction — all draws must flow through a seeded "
        "np.random.Generator threaded from SimParams",
    "mutable-default":
        "mutable default argument ([]/{}/set()) — shared across calls, a "
        "classic cross-run state leak",
    "unguarded-hook":
        "tracer/recorder hook call not dominated by a None guard in the "
        "enclosing function — tracing must be optional at every site",
    # -- registry wiring checker (wiring.py) -----------------------------
    "wiring-counts":
        "runbook registry table counts diverge from the declared expected "
        "counts (repro.lint.wiring.EXPECTED_TABLE_COUNTS)",
    "wiring-detector":
        "runbook row without a matching detector class (name/table "
        "mismatch), or a detector class no row binds",
    "wiring-scenario":
        "runbook row without a fault scenario, or a scenario naming an "
        "unknown row",
    "wiring-golden":
        "scenario without a golden fixture entry, or a stale golden entry "
        "with no scenario (tests/golden/scenario_findings.json)",
    "wiring-attribution":
        "runbook row without an attribution rule (core.attribution."
        "DIRECT_LOCUS), or a stale attribution entry",
    "wiring-action":
        "runbook row actuating through an action missing from "
        "core.mitigation.ACTIONS, an ACTIONS entry no row emits, or a "
        "policy conflict-group member unknown to ACTIONS",
    "wiring-sibling":
        "sibling_rows referencing a nonexistent row (or the row itself)",
    "smoke-coverage":
        "scenario not covered by the sweep --smoke grid and carrying no "
        "exclusion pragma, or a smoke-grid name missing from the registry",
    # -- the linter's own hygiene ----------------------------------------
    "bad-pragma":
        "malformed suppression pragma: unknown rule id or missing reason "
        "text (every suppression must say why)",
    "unused-pragma":
        "suppression pragma that matched no finding — stale suppressions "
        "hide future regressions",
}


@dataclass
class LintFinding:
    """One linter verdict, anchored to a source location."""

    rule: str
    path: str                 # repo-relative, posix separators
    line: int                 # 1-based; 0 = whole-file / registry-level
    message: str
    suppressed: bool = False
    reason: str = ""          # pragma/allowlist reason when suppressed

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        tag = f"[{self.rule}]"
        if self.suppressed:
            return f"{loc}: {tag} suppressed ({self.reason}): {self.message}"
        return f"{loc}: {tag} {self.message}"

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "suppressed": self.suppressed,
            "reason": self.reason,
        }


@dataclass
class LintReport:
    """Aggregate of one whole-tree run."""

    findings: list[LintFinding] = field(default_factory=list)
    files_scanned: int = 0

    @property
    def unsuppressed(self) -> list[LintFinding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> list[LintFinding]:
        return [f for f in self.findings if f.suppressed]

    def by_rule(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.unsuppressed:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def to_json(self) -> dict:
        return {
            "files_scanned": self.files_scanned,
            "unsuppressed": len(self.unsuppressed),
            "suppressed": len(self.suppressed),
            "by_rule": self.by_rule(),
            "findings": [f.to_json() for f in self.findings],
        }
