"""State-space and recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

Mamba2 follows the minimal-SSD formulation (Dao & Gu 2024): chunked
intra-block quadratic attention-like computation + inter-chunk linear
recurrence.  Decode is an O(1) state update — this is what makes the
``long_500k`` shape tractable for the hybrid/ssm architectures.

xLSTM (Beck et al. 2024): mLSTM has a matrix memory with exponential gating
(recurrent scan over time; O(1) decode state), sLSTM a scalar memory with
hidden-state recurrence.  Blocks alternate per ``cfg.slstm_every``.

Simplifications vs the reference CUDA implementations (documented in
DESIGN.md): no short conv1d in front of Mamba2's x/B/C (a 4-tap depthwise
conv; negligible FLOPs, removed to keep decode state = SSM state only), and
sLSTM uses per-head dense recurrent gates rather than block-diagonal ones.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, rmsnorm, rmsnorm_init


# ======================================================================
# Mamba2 / SSD
# ======================================================================

def mamba2_init(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    d, di = cfg.d_model, cfg.d_inner
    n, h = cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 4)
    return {
        # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * n + h, dt),
        "out_proj": dense_init(ks[1], di, d, dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(di, dt),
    }


def _split_mamba_proj(cfg: ModelConfig, proj: jax.Array):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    x = proj[..., di:2 * di]
    B = proj[..., 2 * di:2 * di + n]
    C = proj[..., 2 * di + n:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return z, x, B, C, dt


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., T) -> (..., T, T) lower-triangular segment sums."""
    T = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    s = csum[..., :, None] - csum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                chunk: int, init_state: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Minimal SSD.

    x: (b, l, h, p)  — per-head inputs (dt already folded in)
    a: (b, l, h)     — log-decay per step (dt * A, negative)
    B: (b, l, n)     — input projection (single group, shared across heads)
    C: (b, l, n)     — output projection
    Returns y: (b, l, h, p) and final state (b, h, p, n).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    nc = l // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)     # (b,h,c,L)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    a_cum = jnp.cumsum(ac, axis=-1)                           # (b,h,c,L)
    # 1) intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(ac))                               # (b,h,c,L,L)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        Cc, Bc, Lmat, xc,
                        preferred_element_type=jnp.float32)
    # 2) per-chunk final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)           # (b,h,c,L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        Bc, decay_states, xc,
                        preferred_element_type=jnp.float32)
    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1])                     # (b,h,c)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        dec, st = inp                                         # (b,h), (b,h,p,n)
        new = carry * dec[..., None, None] + st
        return new, carry

    final, prev = jax.lax.scan(
        step, init_state,
        (chunk_decay.transpose(2, 0, 1), states.transpose(1, 0, 2, 3, 4)))
    prev = prev.transpose(1, 0, 2, 3, 4)                      # (b,c,h,p,n)
    # 4) inter-chunk contribution to outputs
    out_decay = jnp.exp(a_cum)                                # (b,h,c,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       Cc, prev, out_decay,
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final


def mamba2_fwd(p: dict, cfg: ModelConfig, u: jax.Array,
               state: jax.Array | None = None, chunk: int = 128
               ) -> tuple[jax.Array, jax.Array]:
    """Full-sequence Mamba2 block. u: (b, l, d) -> (y, final_state)."""
    b, l, d = u.shape
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim
    proj = u @ p["in_proj"]
    z, x, B, C, dt = _split_mamba_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (b,l,h)
    A = -jnp.exp(p["A_log"])                                      # (h,)
    a = dt * A                                                    # (b,l,h)
    xh = x.reshape(b, l, h, pdim).astype(jnp.float32)
    xh = xh * dt[..., None]                                       # fold dt
    pad = (-l) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, final = ssd_chunked(xh, a, B.astype(jnp.float32),
                           C.astype(jnp.float32), chunk, state)
    y = y[:, :l]
    y = y + xh[:, :l] * p["D"][None, None, :, None]
    y = y.reshape(b, l, cfg.d_inner).astype(u.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    return y @ p["out_proj"], final


def mamba2_step(p: dict, cfg: ModelConfig, u: jax.Array,
                state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """O(1) decode step. u: (b, 1, d); state: (b, h, p, n)."""
    b = u.shape[0]
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim
    proj = u[:, 0] @ p["in_proj"]                                 # (b, ·)
    z, x, B, C, dt = _split_mamba_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (b,h)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A)                                          # (b,h)
    xh = x.reshape(b, h, pdim).astype(jnp.float32) * dt[..., None]
    # state: s = s * da + x ⊗ B
    new_state = (state * da[..., None, None]
                 + jnp.einsum("bhp,bn->bhpn", xh, B.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, cfg.d_inner).astype(u.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    return (y @ p["out_proj"])[:, None], new_state


# ======================================================================
# xLSTM: mLSTM + sLSTM
# ======================================================================

def mlstm_init(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], d, d, dt),
        "wk": dense_init(ks[1], d, d, dt),
        "wv": dense_init(ks[2], d, d, dt),
        "w_i": dense_init(ks[3], d, h, jnp.float32),
        "w_f": dense_init(ks[4], d, h, jnp.float32),
        "w_o": dense_init(ks[5], d, d, dt),
        "w_up": dense_init(ks[6], d, d, dt),   # output gate path
        "f_bias": jnp.full((h,), 3.0, jnp.float32),  # forget-by-default
    }


def _mlstm_scan(q, k, v, i_pre, f_pre, state):
    """Recurrent stabilized mLSTM over time.
    q,k,v: (b, l, h, dh); i_pre/f_pre: (b, l, h) pre-activations.
    state: (C (b,h,dh,dh), n (b,h,dh), m (b,h)).
    """
    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp                       # (b,h,dh)...
        log_f = -jax.nn.softplus(-ft)                  # log sigmoid(f)
        m_new = jnp.maximum(log_f + m, it)
        i_s = jnp.exp(it - m_new)                      # (b,h)
        f_s = jnp.exp(log_f + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])       # (b,h,dv,dk)
        n = f_s[..., None] * n + i_s[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))[..., None]
        h_t = num / den
        return (C, n, m_new), h_t

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
          f_pre.transpose(1, 0, 2))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3), state            # (b,l,h,dh)


def mlstm_fwd(p: dict, cfg: ModelConfig, x: jax.Array,
              state: tuple | None = None) -> tuple[jax.Array, tuple]:
    b, l, d = x.shape
    h = cfg.n_heads
    dh = d // h
    q = (x @ p["wq"]).reshape(b, l, h, dh).astype(jnp.float32) / math.sqrt(dh)
    k = (x @ p["wk"]).reshape(b, l, h, dh).astype(jnp.float32) / math.sqrt(dh)
    v = (x @ p["wv"]).reshape(b, l, h, dh).astype(jnp.float32)
    i_pre = x.astype(jnp.float32) @ p["w_i"]
    f_pre = x.astype(jnp.float32) @ p["w_f"] + p["f_bias"]
    if state is None:
        state = (jnp.zeros((b, h, dh, dh), jnp.float32),
                 jnp.zeros((b, h, dh), jnp.float32),
                 jnp.full((b, h), -1e30, jnp.float32))
    hs, state = _mlstm_scan(q, k, v, i_pre, f_pre, state)
    gate = jax.nn.silu((x @ p["w_up"]).astype(jnp.float32))
    out = (hs.reshape(b, l, d) * gate).astype(x.dtype) @ p["w_o"]
    return out, state


def slstm_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 6)
    scale = 1.0 / math.sqrt(d)
    rscale = 1.0 / math.sqrt(dh)
    return {
        # input weights for gates z,i,f,o stacked: (d, 4d)
        "w_x": (jax.random.normal(ks[0], (d, 4 * d), jnp.float32)
                * scale),
        # per-head recurrent weights: (h, dh, 4*dh)
        "r_h": (jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32)
                * rscale),
        "bias": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                                 jnp.full((d,), 3.0, jnp.float32),
                                 jnp.zeros((d,), jnp.float32)]),
        "w_o": dense_init(ks[2], d, d, dtype_of(cfg)),
    }


def slstm_fwd(p: dict, cfg: ModelConfig, x: jax.Array,
              state: tuple | None = None) -> tuple[jax.Array, tuple]:
    """Scalar-memory LSTM with hidden-state recurrence (per head)."""
    b, l, d = x.shape
    h = cfg.n_heads
    dh = d // h
    pre_x = x.astype(jnp.float32) @ p["w_x"] + p["bias"]    # (b,l,4d)
    if state is None:
        state = (jnp.zeros((b, d), jnp.float32),    # c
                 jnp.zeros((b, d), jnp.float32),    # n
                 jnp.zeros((b, d), jnp.float32),    # h
                 jnp.full((b, d), -1e30, jnp.float32))  # m

    def step(carry, xt):
        c, n, hprev, m = carry
        hh = hprev.reshape(b, h, dh)
        rec = jnp.einsum("bhd,hde->bhe", hh, p["r_h"]).reshape(b, 4 * d)
        pre = xt + rec
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zt)
        log_f = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(log_f + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    state, hs = jax.lax.scan(step, state, pre_x.transpose(1, 0, 2))
    out = hs.transpose(1, 0, 2).astype(x.dtype) @ p["w_o"]
    return out, state
