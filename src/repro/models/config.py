"""Model configuration — one dataclass covers all ten assigned families.

Families: dense (llama/mistral/qwen), moe (shared+routed experts), encdec
(seamless audio), vlm (llava backbone + patch stub), hybrid (zamba2 =
Mamba2 backbone + shared attention block), ssm (xLSTM).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | encdec | vlm | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qk_norm: bool = False
    swa_window: int = 0          # 0 = full attention; >0 = sliding window
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # --- encoder-decoder ---
    enc_layers: int = 0
    # --- hybrid / ssm ---
    ssm_state: int = 0           # Mamba2 state dim N
    ssm_head_dim: int = 64       # Mamba2 P
    ssm_expand: int = 2
    attn_every: int = 0          # zamba2: shared attn block every k layers
    xlstm: bool = False
    slstm_every: int = 2         # xLSTM: sLSTM block every k layers (rest mLSTM)
    # --- modality frontend stubs ---
    frontend: str = "none"       # none | vision | audio
    frontend_tokens: int = 0     # patch/frame embeddings per example
    # --- numerics / misc ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    # --- shape support ---
    supports_decode: bool = True
    subquadratic: bool = False   # may run long_500k
    remat: bool = True           # activation checkpointing in train_step
    # Unroll layer loops instead of lax.scan.  Used by the roofline
    # calibration: XLA cost_analysis counts while-loop bodies ONCE, so we
    # lower small unrolled variants and extrapolate exact per-layer terms.
    unroll_layers: bool = False
    # Dispatch full-sequence attention through kernels/ops.py (Pallas flash
    # kernel on TPU; pure-jnp oracle elsewhere).
    use_kernels: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        attn = d * n_q + 2 * d * n_kv + n_q * d
        if self.family == "ssm" and self.xlstm:
            per_layer = 4 * d * d + 2 * d  # qkv+out proj + gates (approx)
            layers = self.n_layers * per_layer
        elif self.family == "hybrid":
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            mamba = (d * (2 * di + 2 * N + H)   # in_proj
                     + di * d                    # out_proj
                     + 2 * H)                    # A_log, D
            shared_blocks = attn + 3 * d * self.d_ff
            layers = self.n_layers * mamba + shared_blocks
        elif self.is_moe:
            router = d * self.n_experts
            experts = self.n_experts * 3 * d * self.expert_d_ff
            shared = 3 * d * (self.n_shared_experts * self.expert_d_ff)
            layers = self.n_layers * (attn + router + experts + shared)
        else:
            mlp = 3 * d * self.d_ff
            layers = self.n_layers * (attn + mlp)
            if self.enc_layers:
                # encoder layers + decoder cross-attention
                layers += self.enc_layers * (attn + mlp)
                layers += self.n_layers * attn
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(layers + embed)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        hd = self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        router = d * self.n_experts
        routed = self.top_k * 3 * d * self.expert_d_ff
        shared = 3 * d * (self.n_shared_experts * self.expert_d_ff)
        layers = self.n_layers * (attn + router + routed + shared)
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(layers + embed)

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 if not self.attn_every else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            expert_d_ff=64 if self.n_experts else 0,
            # effectively dropless at smoke scale so prefill/decode match
            # the full forward exactly (capacity drops are T-dependent)
            capacity_factor=8.0,
            enc_layers=min(self.enc_layers, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32,
            attn_every=2 if self.attn_every else 0,
            frontend_tokens=min(self.frontend_tokens, 16),
            swa_window=min(self.swa_window, 64) if self.swa_window else 0,
            name=self.name + "-smoke",
            dtype="float32",
            remat=False,
        )
        small.update(overrides)
        return replace(self, **small)
