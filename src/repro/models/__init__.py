"""Model zoo: one composable stack covering all ten assigned architectures."""
from repro.models.config import ModelConfig
from repro.models.model import Model, ShapeSpec, build_model
__all__ = ["Model", "ModelConfig", "ShapeSpec", "build_model"]
