"""Unified model facade: build_model(cfg) -> Model with init / loss /
prefill / decode_step / init_cache / input_specs.

The same entry points serve four consumers:
  - CPU smoke tests (reduced configs, no sharding),
  - the serving engine (prefill + decode with KV/state caches),
  - the trainer (loss -> grad),
  - the multi-pod dry-run (input_specs -> ShapeDtypeStruct lowering).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of
from repro.models.ssm import mamba2_fwd, mamba2_step


@dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------

    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        if cfg.family == "encdec":
            return T.encdec_init(key, cfg)
        if cfg.family == "hybrid":
            return T.hybrid_init(key, cfg)
        if cfg.family == "ssm" and cfg.xlstm:
            return T.xlstm_init(key, cfg)
        return T.decoder_init(key, cfg)

    # ------------------------------------------------------------------
    # forward / loss
    # ------------------------------------------------------------------

    def forward(self, params: dict, batch: dict, shard=T.NOSHARD
                ) -> tuple[jax.Array, jax.Array]:
        """Full-sequence logits for training. Returns (logits, aux_loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.family == "encdec":
            enc_out = T.encode(params, cfg, batch["frontend"], shard)
            positions = jnp.arange(tokens.shape[1])
            logits, aux, _ = T.encdec_fwd(params, cfg, tokens, enc_out,
                                          positions, shard)
        elif cfg.family == "hybrid":
            positions = jnp.arange(tokens.shape[1])
            logits, aux, _ = T.hybrid_fwd(params, cfg, tokens, positions,
                                          shard)
        elif cfg.family == "ssm" and cfg.xlstm:
            logits, aux, _ = T.xlstm_fwd(params, cfg, tokens, shard)
        else:
            positions = jnp.arange(tokens.shape[1])
            prefix = batch.get("frontend")
            logits, aux, _ = T.decoder_fwd(params, cfg, tokens, positions,
                                           shard, prefix_embeds=prefix)
            if prefix is not None:
                logits = logits[:, prefix.shape[1]:]
        return logits, aux

    def loss(self, params: dict, batch: dict, shard=T.NOSHARD) -> jax.Array:
        logits, aux = self.forward(params, batch, shard)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        valid = (labels >= 0)
        safe = jnp.where(valid, labels, 0)
        tok_lp = jnp.take_along_axis(logp, safe[..., None],
                                     axis=-1)[..., 0]
        n = jnp.maximum(jnp.sum(valid), 1)
        ce = -jnp.sum(jnp.where(valid, tok_lp, 0.0)) / n
        return ce + aux

    # ------------------------------------------------------------------
    # serving: cache + prefill + decode
    # ------------------------------------------------------------------

    def init_cache(self, batch: int, max_seq: int,
                   src_len: int = 0) -> dict:
        cfg = self.cfg
        dt = dtype_of(cfg)
        hd = cfg.hd
        if cfg.family == "hybrid":
            n_super = cfg.n_layers // cfg.attn_every
            n_tail = cfg.n_layers % cfg.attn_every
            kv_len = max_seq if cfg.swa_window == 0 else min(
                max_seq, cfg.swa_window)
            cache = {
                "ssm": jnp.zeros((n_super, cfg.attn_every, batch,
                                  cfg.ssm_heads, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32),
                "k": jnp.zeros((n_super, batch, max_seq, cfg.n_kv_heads,
                                hd), dt),
                "v": jnp.zeros((n_super, batch, max_seq, cfg.n_kv_heads,
                                hd), dt),
                "kpos": jnp.full((max_seq,), -1, jnp.int32),
                "pos": jnp.zeros((), jnp.int32),
            }
            if n_tail:
                cache["ssm_tail"] = jnp.zeros(
                    (n_tail, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                     cfg.ssm_state), jnp.float32)
            return cache
        if cfg.family == "ssm" and cfg.xlstm:
            n_pairs = cfg.n_layers // 2
            h = cfg.n_heads
            dh = cfg.d_model // h
            d = cfg.d_model
            return {
                "mlstm": (jnp.zeros((n_pairs, batch, h, dh, dh),
                                    jnp.float32),
                          jnp.zeros((n_pairs, batch, h, dh), jnp.float32),
                          jnp.full((n_pairs, batch, h), -1e30,
                                   jnp.float32)),
                "slstm": (jnp.zeros((n_pairs, batch, d), jnp.float32),
                          jnp.zeros((n_pairs, batch, d), jnp.float32),
                          jnp.zeros((n_pairs, batch, d), jnp.float32),
                          jnp.full((n_pairs, batch, d), -1e30,
                                   jnp.float32)),
                "pos": jnp.zeros((), jnp.int32),
            }
        # dense / moe / vlm / encdec: per-layer KV cache
        kv_len = max_seq if cfg.swa_window == 0 else min(max_seq,
                                                         cfg.swa_window)
        cache = {
            "k": jnp.zeros((cfg.n_layers, batch, kv_len, cfg.n_kv_heads,
                            hd), dt),
            "v": jnp.zeros((cfg.n_layers, batch, kv_len, cfg.n_kv_heads,
                            hd), dt),
            "kpos": jnp.full((kv_len,), -1, jnp.int32),
            "pos": jnp.zeros((), jnp.int32),
        }
        if cfg.family == "encdec":
            cache["enc_out"] = jnp.zeros((batch, src_len, cfg.d_model), dt)
        return cache

    def prefill(self, params: dict, tokens: jax.Array, cache: dict,
                shard=T.NOSHARD, frontend: jax.Array | None = None
                ) -> tuple[jax.Array, dict]:
        """Process the prompt, fill the cache, return last-position logits."""
        cfg = self.cfg
        s = tokens.shape[1]
        positions = jnp.arange(s) + cache["pos"]
        if cfg.family == "encdec":
            enc_out = T.encode(params, cfg, frontend, shard)
            cache = dict(cache, enc_out=enc_out)
            logits, _, new_cache = T.encdec_fwd(
                params, cfg, tokens, enc_out, positions, shard,
                cache={k: cache[k] for k in ("k", "v", "kpos", "pos")},
                last_only=True)
            new_cache["enc_out"] = enc_out
        elif cfg.family == "hybrid":
            logits, _, new_cache = T.hybrid_fwd(params, cfg, tokens,
                                                positions, shard,
                                                cache=cache, last_only=True)
        elif cfg.family == "ssm" and cfg.xlstm:
            logits, _, new_cache = T.xlstm_fwd(params, cfg, tokens, shard,
                                               cache=cache, last_only=True)
        else:
            logits, _, new_cache = T.decoder_fwd(params, cfg, tokens,
                                                 positions, shard,
                                                 prefix_embeds=frontend,
                                                 cache=cache,
                                                 last_only=True)
        return logits[:, -1:], new_cache

    def decode_step(self, params: dict, tokens: jax.Array, cache: dict,
                    shard=T.NOSHARD) -> tuple[jax.Array, dict]:
        """One decode step: tokens (B, 1) -> logits (B, 1, V), new cache."""
        return self.prefill(params, tokens, cache, shard) \
            if self.cfg.family == "encdec" and "enc_out" not in cache \
            else self._step(params, tokens, cache, shard)

    def _step(self, params, tokens, cache, shard):
        cfg = self.cfg
        s = tokens.shape[1]
        positions = jnp.arange(s) + cache["pos"]
        if cfg.family == "encdec":
            logits, _, new_cache = T.encdec_fwd(
                params, cfg, tokens, cache["enc_out"], positions, shard,
                cache={k: cache[k] for k in ("k", "v", "kpos", "pos")})
            new_cache["enc_out"] = cache["enc_out"]
        elif cfg.family == "hybrid":
            logits, _, new_cache = T.hybrid_fwd(params, cfg, tokens,
                                                positions, shard,
                                                cache=cache)
        elif cfg.family == "ssm" and cfg.xlstm:
            logits, _, new_cache = T.xlstm_fwd(params, cfg, tokens, shard,
                                               cache=cache)
        else:
            logits, _, new_cache = T.decoder_fwd(params, cfg, tokens,
                                                 positions, shard,
                                                 cache=cache)
        return logits, new_cache

    # ------------------------------------------------------------------
    # dry-run input specs
    # ------------------------------------------------------------------

    def input_specs(self, shape: "ShapeSpec") -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
        cfg = self.cfg
        i32 = jnp.int32
        B, S = shape.global_batch, shape.seq_len
        f = jax.ShapeDtypeStruct
        dt = dtype_of(cfg)
        if shape.kind == "train":
            batch = {"tokens": f((B, S), i32), "labels": f((B, S), i32)}
            if cfg.family == "vlm":
                ftok = cfg.frontend_tokens
                batch = {"tokens": f((B, S - ftok), i32),
                         "labels": f((B, S - ftok), i32),
                         "frontend": f((B, ftok, cfg.d_model), dt)}
            elif cfg.family == "encdec":
                batch["frontend"] = f((B, S, cfg.d_model), dt)
            return {"batch": batch}
        if shape.kind == "prefill":
            cache = jax.eval_shape(
                lambda: self.init_cache(B, S, src_len=S))
            spec = {"tokens": f((B, S), i32), "cache": cache}
            if cfg.family == "encdec":
                spec["frontend"] = f((B, S, cfg.d_model), dt)
            if cfg.family == "vlm":
                ftok = cfg.frontend_tokens
                spec["tokens"] = f((B, S - ftok), i32)
                spec["frontend"] = f((B, ftok, cfg.d_model), dt)
            return spec
        # decode: one new token against a seq_len-deep cache
        cache = jax.eval_shape(
            lambda: self.init_cache(B, S, src_len=min(S, 4096)))
        return {"tokens": f((B, 1), i32), "cache": cache}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int
