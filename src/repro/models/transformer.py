"""Model stacks for all assigned families, built scan-over-layers so the
compiled HLO is O(1) in depth (512-device SPMD compiles stay tractable).

Families:
  dense   — pre-norm GQA attention + SwiGLU (llama/mistral/qwen/danube)
  moe     — attention + (shared + routed top-k experts)
  encdec  — bidirectional encoder + causal decoder w/ cross-attention
  vlm     — dense backbone consuming [patch-embeds ; token-embeds]
  hybrid  — zamba2: Mamba2 backbone, ONE shared attn+MLP block applied every
            k layers (super-block structure: scan over (k mamba + shared))
  ssm     — xLSTM: alternating mLSTM / sLSTM pairs

Every stack exposes: init / fwd (full sequence, optional caches for decode).
``Sharder`` is an optional activation-constraint hook (see parallel.sharding)
so the same code runs unsharded on CPU tests and fully sharded in the
dry-run.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import ssm as S
from repro.models.layers import (
    attention_fwd,
    attention_init,
    dense_init,
    dtype_of,
    mlp_fwd,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.moe import moe_fwd, moe_init


class NoSharder:
    """Default no-op activation sharder."""

    def act(self, x, kind: str):
        return x


NOSHARD = NoSharder()


# ----------------------------------------------------------------------
# layer-stacking helpers
# ----------------------------------------------------------------------

def stack_init(key, n: int, init_fn: Callable[[Any], dict]) -> dict:
    """Initialize n layers and stack leaves along a leading axis."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def scan_layers(body, carry, xs, cfg: ModelConfig):
    """lax.scan over stacked layer params — or an unrolled Python loop when
    ``cfg.unroll_layers`` (roofline calibration: cost_analysis counts scan
    bodies once, unrolled copies are counted exactly)."""
    if not cfg.unroll_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked


# ----------------------------------------------------------------------
# dense / moe / vlm decoder-only stack
# ----------------------------------------------------------------------

def decoder_init(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    k_embed, k_layers, k_head, k_shared = jax.random.split(key, 4)

    def layer_init(k):
        ka, kb = jax.random.split(k)
        p = {
            "ln1": rmsnorm_init(cfg.d_model, dt),
            "attn": attention_init(ka, cfg),
            "ln2": rmsnorm_init(cfg.d_model, dt),
        }
        if cfg.is_moe:
            p["moe"] = moe_init(kb, cfg)
        else:
            p["mlp"] = mlp_init(kb, cfg.d_model, cfg.d_ff, dt)
        return p

    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "layers": stack_init(k_layers, cfg.n_layers, layer_init),
        "ln_f": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dt)
    return params


def ring_info(cache_pos, s_total: int, max_seq: int, old_kpos,
              shard=None):
    """Ring-buffer bookkeeping shared by every attention layer of a step."""
    q_pos = cache_pos + jnp.arange(s_total)
    if s_total >= max_seq:
        return {"q_pos": q_pos, "shard": shard}, q_pos[-max_seq:]
    slots = q_pos % max_seq
    new_kpos = old_kpos.at[slots].set(q_pos)
    return {"slots": slots, "kpos": new_kpos, "q_pos": q_pos,
            "shard": shard}, new_kpos


def _dense_layer_fwd(lp: dict, cfg: ModelConfig, x, positions, shard,
                     cache_k=None, cache_v=None, ring=None):
    """One decoder layer; returns (x, aux, new_k, new_v)."""
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    kv_cache = None
    if cache_k is not None:
        kv_cache = {"k": cache_k, "v": cache_v, **ring}
    attn_out, new_cache = attention_fwd(lp["attn"], cfg, h, positions,
                                        kv_cache=kv_cache)
    x = x + shard.act(attn_out, "act")
    h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        out, aux = moe_fwd(lp["moe"], cfg, h, shard=shard)
    else:
        out = mlp_fwd(lp["mlp"], shard.act(h, "ffn_in"))
    x = x + shard.act(out, "act")
    nk = new_cache["k"] if new_cache else None
    nv = new_cache["v"] if new_cache else None
    return x, aux, nk, nv


def decoder_fwd(params: dict, cfg: ModelConfig, tokens: jax.Array,
                positions: jax.Array, shard=NOSHARD,
                prefix_embeds: jax.Array | None = None,
                cache: dict | None = None, last_only: bool = False
                ) -> tuple[jax.Array, jax.Array, dict | None]:
    """Returns (logits, aux_loss, new_cache).

    tokens: (B, S) int32.  prefix_embeds: (B, F, d) prepended (VLM/audio).
    cache: {"k": (L,B,max,Hkv,hd), "v": ..., "pos": scalar} for decode.
    """
    x = params["embed"].astype(dtype_of(cfg))[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        offset = cache["pos"] if cache is not None else 0
        positions = jnp.arange(x.shape[1]) + offset
    x = shard.act(x, "act")

    if cache is None:
        def body(carry, lp):
            x, aux = carry
            x, a, _, _ = _dense_layer_fwd(lp, cfg, x, positions, shard)
            return (x, aux + a), None

        body = _maybe_remat(body, cfg)
        (x, aux), _ = scan_layers(body, (x, jnp.zeros((), jnp.float32)),
                                  params["layers"], cfg)
        new_cache = None
    else:
        pos = cache["pos"]
        ring, new_kpos = ring_info(pos, x.shape[1], cache["k"].shape[2],
                                   cache["kpos"], shard)
        positions = ring["q_pos"]

        def body(carry, inp):
            x, aux = carry
            lp, ck, cv = inp
            x, a, nk, nv = _dense_layer_fwd(lp, cfg, x, positions, shard,
                                            ck, cv, ring)
            return (x, aux + a), (nk, nv)

        (x, aux), (nk, nv) = scan_layers(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], cache["k"], cache["v"]), cfg)
        # advance by the full written slab (prefix embeds + tokens)
        new_cache = {"k": nk, "v": nv, "pos": pos + x.shape[1],
                     "kpos": new_kpos}

    if last_only:
        x = x[:, -1:]      # serving prefill: head for last token only
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = shard.act(x @ head.astype(x.dtype), "logits")
    return logits, aux, new_cache


# ----------------------------------------------------------------------
# encoder-decoder (seamless-m4t style)
# ----------------------------------------------------------------------

def encdec_init(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)

    def enc_layer(k):
        ka, kb = jax.random.split(k)
        return {
            "ln1": rmsnorm_init(cfg.d_model, dt),
            "attn": attention_init(ka, cfg),
            "ln2": rmsnorm_init(cfg.d_model, dt),
            "mlp": mlp_init(kb, cfg.d_model, cfg.d_ff, dt),
        }

    def dec_layer(k):
        ka, kb, kc = jax.random.split(k, 3)
        return {
            "ln1": rmsnorm_init(cfg.d_model, dt),
            "attn": attention_init(ka, cfg),
            "ln_x": rmsnorm_init(cfg.d_model, dt),
            "xattn": attention_init(kb, cfg, cross=True),
            "ln2": rmsnorm_init(cfg.d_model, dt),
            "mlp": mlp_init(kc, cfg.d_model, cfg.d_ff, dt),
        }

    return {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "encoder": stack_init(k_enc, cfg.enc_layers, enc_layer),
        "decoder": stack_init(k_dec, cfg.n_layers, dec_layer),
        "ln_enc": rmsnorm_init(cfg.d_model, dt),
        "ln_f": rmsnorm_init(cfg.d_model, dt),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab, dt),
    }


def encode(params: dict, cfg: ModelConfig, src_embeds: jax.Array,
           shard=NOSHARD) -> jax.Array:
    """Bidirectional encoder over frontend frame embeddings."""
    x = shard.act(src_embeds.astype(dtype_of(cfg)), "act")
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        # bidirectional: no mask, no cache
        a, _ = attention_fwd(lp["attn"], cfg, h, positions,
                             kv_source=h)
        x = x + shard.act(a, "act")
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + shard.act(mlp_fwd(lp["mlp"], h), "act")
        return x, None

    body = _maybe_remat(body, cfg)
    x, _ = scan_layers(body, x, params["encoder"], cfg)
    return rmsnorm(params["ln_enc"], x, cfg.norm_eps)


def encdec_fwd(params: dict, cfg: ModelConfig, tokens: jax.Array,
               enc_out: jax.Array, positions: jax.Array, shard=NOSHARD,
               cache: dict | None = None, last_only: bool = False
               ) -> tuple[jax.Array, jax.Array, dict | None]:
    x = params["embed"].astype(dtype_of(cfg))[tokens]
    x = shard.act(x, "act")

    def layer(lp, x, ck=None, cv=None, ring=None):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        kv = None if ck is None else {"k": ck, "v": cv, **ring}
        a, nc = attention_fwd(lp["attn"], cfg, h, positions, kv_cache=kv)
        x = x + shard.act(a, "act")
        h = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        a, _ = attention_fwd(lp["xattn"], cfg, h, positions,
                             kv_source=enc_out)
        x = x + shard.act(a, "act")
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + shard.act(mlp_fwd(lp["mlp"], h), "act")
        return x, nc

    if cache is None:
        def body(x, lp):
            x, _ = layer(lp, x)
            return x, None
        body = _maybe_remat(body, cfg)
        x, _ = scan_layers(body, x, params["decoder"], cfg)
        new_cache = None
    else:
        pos = cache["pos"]
        ring, new_kpos = ring_info(pos, tokens.shape[1],
                                   cache["k"].shape[2], cache["kpos"],
                                   shard)
        positions = ring["q_pos"]

        def body(x, inp):
            lp, ck, cv = inp
            x, nc = layer(lp, x, ck, cv, ring)
            return x, (nc["k"], nc["v"])

        x, (nk, nv) = scan_layers(
            body, x, (params["decoder"], cache["k"], cache["v"]), cfg)
        new_cache = {"k": nk, "v": nv, "pos": pos + tokens.shape[1],
                     "kpos": new_kpos}

    if last_only:
        x = x[:, -1:]      # serving prefill: head for last token only
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = shard.act(x @ params["lm_head"].astype(x.dtype), "logits")
    return logits, jnp.zeros((), jnp.float32), new_cache


# ----------------------------------------------------------------------
# zamba2-style hybrid: Mamba2 backbone + ONE shared attention block
# ----------------------------------------------------------------------

def hybrid_init(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    k_emb, k_blocks, k_tail, k_shared, k_head = jax.random.split(key, 5)
    n_super = cfg.n_layers // cfg.attn_every
    n_tail = cfg.n_layers % cfg.attn_every

    def mamba_layer(k):
        return {"ln": rmsnorm_init(cfg.d_model, dt),
                "mamba": S.mamba2_init(k, cfg)}

    def super_block(k):
        return stack_init(k, cfg.attn_every, mamba_layer)

    ka, kb = jax.random.split(k_shared)
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "blocks": stack_init(k_blocks, n_super, super_block),
        # zamba2's signature: a single parameter-shared attn+MLP block
        "shared": {
            "ln1": rmsnorm_init(cfg.d_model, dt),
            "attn": attention_init(ka, cfg),
            "ln2": rmsnorm_init(cfg.d_model, dt),
            "mlp": mlp_init(kb, cfg.d_model, cfg.d_ff, dt),
        },
        "ln_f": rmsnorm_init(cfg.d_model, dt),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab, dt),
    }
    if n_tail:
        params["tail"] = stack_init(k_tail, n_tail, mamba_layer)
    return params


def hybrid_fwd(params: dict, cfg: ModelConfig, tokens: jax.Array,
               positions: jax.Array, shard=NOSHARD,
               cache: dict | None = None, last_only: bool = False
               ) -> tuple[jax.Array, jax.Array, dict | None]:
    """cache (decode): {"ssm": (n_super, k, B,H,P,N), "ssm_tail": (tail,...),
    "k"/"v": (n_apps, B, max, Hkv, hd), "pos"}."""
    x = params["embed"].astype(dtype_of(cfg))[tokens]
    x = shard.act(x, "act")
    shared = params["shared"]

    def shared_block(x, ck=None, cv=None, ring=None):
        h = rmsnorm(shared["ln1"], x, cfg.norm_eps)
        kv = None if ck is None else {"k": ck, "v": cv, **ring}
        a, nc = attention_fwd(shared["attn"], cfg, h, positions,
                              kv_cache=kv)
        x = x + shard.act(a, "act")
        h = rmsnorm(shared["ln2"], x, cfg.norm_eps)
        x = x + shard.act(mlp_fwd(shared["mlp"], h), "act")
        return x, nc

    if cache is None:
        def mamba_body(x, lp):
            h = rmsnorm(lp["ln"], x, cfg.norm_eps)
            y, _ = S.mamba2_fwd(lp["mamba"], cfg, h)
            return x + shard.act(y, "act"), None

        mamba_body = _maybe_remat(mamba_body, cfg)

        def super_body(x, block):
            x, _ = scan_layers(mamba_body, x, block, cfg)
            x, _ = shared_block(x)
            return x, None

        x, _ = scan_layers(super_body, x, params["blocks"], cfg)
        if "tail" in params:
            x, _ = scan_layers(mamba_body, x, params["tail"], cfg)
        new_cache = None
    else:
        pos = cache["pos"]
        single = tokens.shape[1] == 1   # static: decode vs prefill-with-state
        ring, new_kpos = ring_info(pos, tokens.shape[1],
                                   cache["k"].shape[2], cache["kpos"],
                                   shard)
        positions = ring["q_pos"]

        def mamba_step_body(x, inp):
            lp, st = inp
            h = rmsnorm(lp["ln"], x, cfg.norm_eps)
            if single:
                y, new_st = S.mamba2_step(lp["mamba"], cfg, h, st)
            else:
                y, new_st = S.mamba2_fwd(lp["mamba"], cfg, h, state=st)
            return x + shard.act(y, "act"), new_st

        def super_body(x, inp):
            block, st, ck, cv = inp
            x, new_st = scan_layers(mamba_step_body, x, (block, st), cfg)
            x, nc = shared_block(x, ck, cv, ring)
            return x, (new_st, nc["k"], nc["v"])

        x, (new_ssm, nk, nv) = scan_layers(
            super_body, x,
            (params["blocks"], cache["ssm"], cache["k"], cache["v"]), cfg)
        new_tail = None
        if "tail" in params:
            x, new_tail = scan_layers(mamba_step_body, x,
                                      (params["tail"], cache["ssm_tail"]),
                                      cfg)
        new_cache = {"ssm": new_ssm, "k": nk, "v": nv,
                     "pos": pos + tokens.shape[1], "kpos": new_kpos}
        if new_tail is not None:
            new_cache["ssm_tail"] = new_tail

    if last_only:
        x = x[:, -1:]      # serving prefill: head for last token only
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = shard.act(x @ params["lm_head"].astype(x.dtype), "logits")
    return logits, jnp.zeros((), jnp.float32), new_cache


# ----------------------------------------------------------------------
# xLSTM stack: alternating (mLSTM, sLSTM) pairs
# ----------------------------------------------------------------------

def xlstm_init(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    k_emb, k_pairs, k_head = jax.random.split(key, 3)
    n_pairs = cfg.n_layers // 2

    def pair_init(k):
        ka, kb = jax.random.split(k)
        return {
            "ln_m": rmsnorm_init(cfg.d_model, dt),
            "mlstm": S.mlstm_init(ka, cfg),
            "ln_s": rmsnorm_init(cfg.d_model, dt),
            "slstm": S.slstm_init(kb, cfg),
        }

    return {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "pairs": stack_init(k_pairs, n_pairs, pair_init),
        "ln_f": rmsnorm_init(cfg.d_model, dt),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab, dt),
    }


def xlstm_fwd(params: dict, cfg: ModelConfig, tokens: jax.Array,
              shard=NOSHARD, cache: dict | None = None,
              last_only: bool = False
              ) -> tuple[jax.Array, jax.Array, dict | None]:
    """cache (decode): per-pair recurrent states, stacked on axis 0."""
    x = params["embed"].astype(dtype_of(cfg))[tokens]
    x = shard.act(x, "act")

    def pair_body(x, inp):
        if cache is None:
            lp = inp
            m_state = s_state = None
        else:
            lp, m_state, s_state = inp
        h = rmsnorm(lp["ln_m"], x, cfg.norm_eps)
        y, new_m = S.mlstm_fwd(lp["mlstm"], cfg, h, m_state)
        x = x + shard.act(y, "act")
        h = rmsnorm(lp["ln_s"], x, cfg.norm_eps)
        y, new_s = S.slstm_fwd(lp["slstm"], cfg, h, s_state)
        x = x + shard.act(y, "act")
        return x, (new_m, new_s)

    if cache is None:
        body = _maybe_remat(lambda x, lp: (pair_body(x, lp)[0], None), cfg)
        x, _ = scan_layers(body, x, params["pairs"], cfg)
        new_cache = None
    else:
        x, (new_m, new_s) = scan_layers(
            pair_body, x, (params["pairs"], cache["mlstm"], cache["slstm"]),
            cfg)
        new_cache = {"mlstm": new_m, "slstm": new_s,
                     "pos": cache["pos"] + tokens.shape[1]}

    if last_only:
        x = x[:, -1:]      # serving prefill: head for last token only
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = shard.act(x @ params["lm_head"].astype(x.dtype), "logits")
    return logits, jnp.zeros((), jnp.float32), new_cache
