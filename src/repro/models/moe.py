"""Mixture-of-Experts layer: shared experts + routed top-k, GShard-style
capacity dispatch via one-hot einsums (MXU-friendly; SPMD emits all-to-all
when experts are sharded over the 'model'/'expert' mesh axis).

Covers qwen2-moe (60 routed top-4 + 4 shared) and granite-moe (40 routed
top-8, no shared).  Router aux losses: load-balancing (Switch) + z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, mlp_fwd, mlp_init


def moe_init(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    d, e_ff = cfg.d_model, cfg.expert_d_ff
    p = {
        "router": dense_init(ks[0], d, cfg.n_experts, jnp.float32),
        "w_gate": jax.random.normal(
            ks[1], (cfg.n_experts, d, e_ff), jnp.float32
        ).astype(dt) / (d ** 0.5),
        "w_up": jax.random.normal(
            ks[2], (cfg.n_experts, d, e_ff), jnp.float32
        ).astype(dt) / (d ** 0.5),
        "w_down": jax.random.normal(
            ks[3], (cfg.n_experts, e_ff, d), jnp.float32
        ).astype(dt) / (e_ff ** 0.5),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = mlp_init(ks[4], d, cfg.n_shared_experts * e_ff, dt)
    return p


MOE_GROUP = 1024   # tokens per dispatch group (GShard/GLaM-style)


def moe_fwd(p: dict, cfg: ModelConfig, x: jax.Array,
            group_size: int = MOE_GROUP,
            shard=None) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    GROUPED capacity dispatch (GShard/Switch/GLaM): tokens are processed in
    groups of ``group_size``; each expert takes up to
    C = group * cf * k / E tokens *per group*.  The one-hot dispatch tensor
    is (G, group, E, C) — linear in T — instead of the naive (T, E, C)
    which is O(T^2/E) and explodes at training shapes (T = 1M tokens =>
    5e18 elements).  Group-local capacity is the canonical TPU idiom
    precisely because the MXU-friendly one-hot dispatch requires a bounded
    per-group C.
    """
    b, s, d = x.shape
    t = b * s
    e = cfg.n_experts
    k = cfg.top_k
    group = min(group_size, t)
    n_g = t // group
    # ragged tail folds into the last group's capacity headroom
    if n_g * group != t:
        n_g += 1
        pad = n_g * group - t
        xt = jnp.pad(x.reshape(t, d), ((0, pad), (0, 0)))
    else:
        pad = 0
        xt = x.reshape(t, d)
    xg = xt.reshape(n_g, group, d)

    logits = (xg.astype(jnp.float32) @ p["router"])        # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- aux losses (over real tokens only) ---
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=(0, 1))
    pbar = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_coef * e * jnp.sum(f * pbar)
    z = cfg.router_z_coef * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux_loss = aux + z

    # --- top-k routing with per-group capacity ---
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (G, g, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1,
                                     keepdims=True) + 1e-9)
    cap = int(max(k, round(group * cfg.capacity_factor * k / e)))

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (G, g, k, E)
    flat = onehot.reshape(n_g, group * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat        # (G, g*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(n_g, group, k)
    keep = pos < cap                                       # capacity drop
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch: (G, g, E, C) one-hot
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                            dtype=xt.dtype)[..., :cap]     # (G, g, k, C)
    disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(xt.dtype), pos_oh)
    expert_in = jnp.einsum("gtd,gtec->gecd", xg, disp)     # (G, E, C, d)
    if shard is not None:
        # EP: groups stay on their DP shard, experts live on the TP axis
        expert_in = shard.act(expert_in, "moe_inner")

    # expert MLPs (batched over G x E)
    gate = jax.nn.silu(jnp.einsum(
        "gecd,edf->gecf", expert_in, p["w_gate"],
        preferred_element_type=jnp.float32))
    up = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"],
                    preferred_element_type=jnp.float32)
    hidden = (gate * up).astype(xt.dtype)
    expert_out = jnp.einsum("gecf,efd->gecd", hidden, p["w_down"],
                            preferred_element_type=jnp.float32)
    if shard is not None:
        expert_out = shard.act(expert_out.astype(xt.dtype), "moe_inner")

    # combine: weight each kept (token, choice) by its gate value
    comb = jnp.einsum("gtec,gtk,gtke->gtec", disp,
                      gate_vals.astype(xt.dtype),
                      onehot.astype(xt.dtype))
    out = jnp.einsum("gecd,gtec->gtd", expert_out.astype(xt.dtype), comb)
    out = out.reshape(n_g * group, d)
    if pad:
        out = out[:t]

    if "shared" in p:
        out = out + mlp_fwd(p["shared"], xt[:t] if pad else xt)
    return out.reshape(b, s, d), aux_loss
