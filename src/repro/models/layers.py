"""Core transformer layers: RMSNorm, RoPE, GQA attention (SWA / qk-norm),
SwiGLU MLP.  Pure function style: params are dict pytrees, shapes explicit.

Conventions:
  activations x : (batch, seq, d_model)
  attention     : q (B,S,Hq,D), k/v (B,S,Hkv,D); GQA repeats kv heads
  KV cache      : dict(k=(B,max_seq,Hkv,D), v=..., pos=int32 scalar)
All matmuls accumulate in float32 (preferred_element_type) for MXU accuracy.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


# ----------------------------------------------------------------------
# RMSNorm
# ----------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    if angles.ndim == 2:  # (S, D/2) -> broadcast batch
        angles = angles[None]
    cos = jnp.cos(angles)[..., None, :]                    # (B,S,1,D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Attention (GQA + optional SWA + optional qk-norm)
# ----------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, cross: bool = False) -> dict:
    dt = dtype_of(cfg)
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dt)
        p["k_norm"] = rmsnorm_init(hd, dt)
    return p


def _causal_mask(q_len: int, kv_len: int, swa: int,
                 q_offset) -> jax.Array:
    """Boolean mask (q_len, kv_len): True = attend."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = k_pos <= q_pos
    if swa > 0:
        mask &= k_pos > q_pos - swa
    return mask


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
         mask: jax.Array | None, shard=None) -> jax.Array:
    """Grouped-query scaled-dot-product attention (no KV materialization).

    q: (B,Sq,Hq,D), k/v: (B,Skv,Hkv,D) with Hq a multiple of Hkv;
    mask: (Sq,Skv) or (B,1,1,Sq,Skv) broadcastable boolean.
    shard: optional Sharder — constrains the logits' kv dim onto the TP
    axis so a sequence-sharded KV cache is reduced in place (distributed
    softmax) instead of being all-gathered."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    if shard is not None:
        logits = shard.act(logits, "attn_logits")
    logits = logits / math.sqrt(d)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        logits = jnp.where(mask, logits, jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def attention_fwd(p: dict, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array,
                  kv_cache: dict | None = None,
                  kv_source: jax.Array | None = None,
                  use_kernel: bool = False) -> tuple[jax.Array, dict | None]:
    """Self- or cross-attention with optional ring-buffer KV cache.

    kv_cache (decode/prefill-with-state):
        {"k"/"v": (B, max, Hkv, D),
         "slots": (s,) ring slots to write (precomputed by the caller),
         "kpos": (max,) absolute position per slot AFTER this write
                 (-1 = empty),
         "q_pos": (s,) absolute positions of the incoming tokens}
    When s >= max (prefill longer than a sliding-window cache), the slab is
    attended in-slab (window <= s makes that exact for pos==0) and only the
    last ``max`` tokens are stored.
    kv_source: encoder output for cross-attention (no cache).
    """
    b, s, _ = x.shape
    hd = cfg.hd
    q = x @ p["wq"]
    src = kv_source if kv_source is not None else x
    k = src @ p["wk"]
    v = src @ p["wv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    v = v.reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if kv_source is None:  # RoPE only for self-attention
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None and kv_source is None:
        max_seq = kv_cache["k"].shape[1]
        cdt = kv_cache["k"].dtype
        if s >= max_seq:
            # prefill slab covers the whole (window-bounded) cache
            mask = _causal_mask(s, s, cfg.swa_window, 0)
            out = sdpa(q, k, v, mask)
            ck = k[:, s - max_seq:].astype(cdt)
            cv = v[:, s - max_seq:].astype(cdt)
            new_cache = {"k": ck, "v": cv}
        else:
            slots = kv_cache["slots"]
            ck = kv_cache["k"].at[:, slots].set(k.astype(cdt))
            cv = kv_cache["v"].at[:, slots].set(v.astype(cdt))
            new_cache = {"k": ck, "v": cv}
            kpos = kv_cache["kpos"]          # (max,), post-write
            q_pos = kv_cache["q_pos"]        # (s,)
            mask = (kpos[None, :] >= 0) & (kpos[None, :] <= q_pos[:, None])
            if cfg.swa_window > 0:
                mask &= kpos[None, :] > q_pos[:, None] - cfg.swa_window
            out = sdpa(q, ck, cv, mask, shard=kv_cache.get("shard"))
    elif kv_source is not None:
        out = sdpa(q, k, v, None)            # full cross-attention
    else:
        if use_kernel or cfg.use_kernels:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True,
                                       window=cfg.swa_window)
        else:
            mask = _causal_mask(s, s, cfg.swa_window, 0)
            out = sdpa(q, k, v, mask)
    out = out.reshape(b, s, cfg.n_heads * hd)
    return out @ p["wo"], new_cache


# ----------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def mlp_fwd(p: dict, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu((x @ p["w_gate"]).astype(jnp.float32))
    up = (x @ p["w_up"]).astype(jnp.float32)
    return ((gate * up).astype(x.dtype)) @ p["w_down"]
