"""Serving substrate: paged KV accounting, continuous batching, telemetry-
integrated inference engine, and the cross-replica (data-parallel) router."""
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.kvcache import PagedKVPool
from repro.serving.router import (
    POLICIES,
    HierarchicalView,
    NodeSnapshot,
    ReplicaSet,
    ReplicaSnapshot,
    RequestInfo,
    Router,
    RouterPolicy,
    RouterView,
    RoutingDecision,
    make_policy,
)
from repro.serving.scheduler import Scheduler, SchedulerConfig, ServeRequest
__all__ = ["EngineConfig", "HierarchicalView", "InferenceEngine",
           "NodeSnapshot", "PagedKVPool", "POLICIES",
           "ReplicaSet", "ReplicaSnapshot", "RequestInfo", "Router",
           "RouterPolicy", "RouterView", "RoutingDecision", "Scheduler",
           "SchedulerConfig", "ServeRequest", "make_policy"]
