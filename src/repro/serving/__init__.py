"""Serving substrate: paged KV accounting, continuous batching, telemetry-
integrated inference engine."""
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.kvcache import PagedKVPool
from repro.serving.scheduler import Scheduler, SchedulerConfig, ServeRequest
__all__ = ["EngineConfig", "InferenceEngine", "PagedKVPool", "Scheduler",
           "SchedulerConfig", "ServeRequest"]
