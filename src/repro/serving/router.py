"""Hierarchical cross-replica request router — the data-parallel dispatch
layer, at replica -> node -> device granularity.

The paper's decode-phase load imbalance is hierarchical: skew appears
across DP replicas, across nodes inside a replica, and across devices
inside a node.  A front-end router that only sees the replica tier fixes
the first and is blind to the other two; a router fed a *stale* view — or
one whose session affinity defeats its balancing — manufactures exactly
the pathologies Table 3(d) catalogs.

Pieces:

  NodeSnapshot     — router-visible state of one cluster node inside a
                     replica (queue depth, active slots, KV occupancy,
                     per-device live-sequence counts).
  ReplicaSnapshot  — the replica-tier aggregate at time ts, carrying its
                     ``nodes`` tree.  Deliberately the same information a
                     DPU-side collector exports: queue samples and
                     KV-occupancy telemetry, no model internals.
  HierarchicalView — per-replica snapshot history with an explicit
                     staleness model and node/device-tier access.
                     Snapshots are inserted in timestamp order (the view
                     transport jitters, so arrivals may be out of order).
  RouterPolicy     — pluggable two-stage decision rule: ``choose`` picks a
                     replica; hierarchical policies also implement
                     ``choose_node`` to pick a node slot within it.
                       round_robin          (static, load-blind)
                       join_shortest_queue  (queued + active work units)
                       least_kv             (lowest KV-cache occupancy)
                       prediction_aware     (lowest expected remaining
                                             decode tokens)
                       prefix_affinity      (consistent-hash on the request
                                             session/prefix key, load-
                                             ceiling spill to JSQ)
                       hierarchical_jsq     (replica whose least-loaded
                                             node is least loaded, then
                                             that node; device counts
                                             break ties)
  Router           — routes RequestInfo -> replica (and node, for
                     hierarchical policies), with optimistic local
                     accounting between view refreshes.  Staleness is a
                     *measured* property of the view transport
                     (``view_lag``); optimistic bumps switch off by
                     themselves once the view lags beyond
                     ``bump_lag_tol`` — the stale-router-view pathology no
                     longer needs a knob (the legacy ``staleness`` knob is
                     retained for explicit experiments).
  ReplicaSet       — N live engines behind one Router.  The view refresh
                     is periodic (``refresh_period``) and telemetry-borne:
                     snapshots travel through a ``ModeledLink``
                     (``repro.dpu.transport``), so the router's view lags,
                     jitters, and drops exactly like the DPU's uplink
                     does.  The same message carries the columnar
                     QUEUE_SAMPLE rows the detection plane consumes.

Every routing decision is recorded; tests assert conservation (no request
dropped, each routed exactly once) and the JSQ invariant (never route to a
strictly longer queue than the minimum in view).
"""

from __future__ import annotations

import dataclasses
import random
from bisect import bisect_right, insort
from dataclasses import dataclass, field
from zlib import crc32

import numpy as np

from repro.core.events import EventBatchBuilder, EventKind
from repro.dpu.transport import LinkParams, ModeledLink


@dataclass(frozen=True)
class NodeSnapshot:
    """Router-visible state of one cluster node within a replica."""

    node: int                   # cluster node id
    queue_depth: int = 0        # requests queued on this node
    active: int = 0             # requests currently decoding on this node
    slots: int = 1              # decode slot capacity
    kv_occupancy: float = 0.0   # 0..1 fraction of this node's KV pool
    expected_work: float = 0.0  # predicted remaining decode tokens
    dev_active: tuple[int, ...] = ()   # live sequences per device slot

    @property
    def backlog(self) -> int:
        return self.queue_depth + self.active


@dataclass(frozen=True)
class ReplicaSnapshot:
    """Router-visible state of one replica at a point in time."""

    replica: int
    ts: float
    queue_depth: int = 0        # requests waiting, not yet in a decode slot
    active: int = 0             # requests currently decoding
    slots: int = 1              # decode slot capacity (for normalization)
    kv_occupancy: float = 0.0   # 0..1 fraction of KV pool in use
    expected_work: float = 0.0  # predicted remaining decode tokens (queued+active)
    nodes: tuple[NodeSnapshot, ...] = ()   # per-node tier (may be empty)

    @property
    def backlog(self) -> int:
        """Total requests the replica is responsible for right now."""
        return self.queue_depth + self.active


@dataclass(frozen=True)
class RequestInfo:
    """What the router may know about a request at dispatch time."""

    flow: int
    prompt_len: int = 0
    predicted_decode: float = 0.0   # expected decode length (workload model)
    session: int = -1               # prefix/session affinity key (-1: none)

    @property
    def affinity_key(self) -> int:
        """The key prefix-affinity policies hash: the session when the
        front-end knows it, else the flow id."""
        return self.session if self.session >= 0 else self.flow


@dataclass(frozen=True)
class RoutingDecision:
    ts: float
    flow: int
    replica: int
    policy: str
    view_ts: float              # timestamp of the snapshot the choice used
    node: int = -1              # node slot (hierarchical policies only)


class RouterView:
    """Per-replica snapshot history with an explicit staleness model.

    ``get(replica, now, staleness)`` returns the newest snapshot no younger
    than ``now - staleness`` — i.e. what an eventually-consistent router
    actually knows.  History is kept **sorted by snapshot timestamp**:
    the view transport jitters, so snapshots can arrive out of order, and
    an append-only history would corrupt both the age-pruning cutoff and
    the newest-first scan in ``get``.  Pruning is by AGE relative to the
    newest snapshot *held* (``max_age``, which callers must keep >= the
    deepest staleness they will ask for), with a generous entry-count
    backstop so a pathological snapshot flood stays bounded.
    """

    MAX_HISTORY = 4096      # backstop only; age-based pruning is primary

    def __init__(self, n_replicas: int, max_age: float = 2.0) -> None:
        self.n_replicas = n_replicas
        self.max_age = max_age
        self._hist: list[list[ReplicaSnapshot]] = [
            [] for _ in range(n_replicas)]

    def update(self, snap: ReplicaSnapshot) -> None:
        h = self._hist[snap.replica]
        # insert in ts order (equal timestamps keep arrival order); a late
        # out-of-order snapshot lands in sorted position instead of
        # masquerading as the newest state
        if h and snap.ts < h[-1].ts:
            insort(h, snap, key=lambda s: s.ts)
        else:
            h.append(snap)
        # prune by age of the newest snapshot HELD (h[-1] after insertion,
        # never the just-arrived one — a stale arrival must not drag the
        # cutoff backward)
        cutoff = h[-1].ts - self.max_age
        drop = 0
        while drop < len(h) - 1 and h[drop + 1].ts <= cutoff:
            drop += 1
        if len(h) - drop > self.MAX_HISTORY:
            drop = len(h) - self.MAX_HISTORY
        if drop:
            del h[:drop]

    def get(self, replica: int, now: float,
            staleness: float = 0.0) -> ReplicaSnapshot:
        h = self._hist[replica]
        if not h:
            return ReplicaSnapshot(replica=replica, ts=float("-inf"))
        if staleness <= 0.0:
            return h[-1]
        cutoff = now - staleness
        for snap in reversed(h):
            if snap.ts <= cutoff:
                return snap
        return h[0]     # nothing old enough: the oldest we have

    def latest_ts(self, replica: int) -> float:
        h = self._hist[replica]
        return h[-1].ts if h else float("-inf")


class HierarchicalView(RouterView):
    """RouterView plus node/device-tier access over the snapshot tree."""

    def nodes(self, replica: int, now: float,
              staleness: float = 0.0) -> tuple[NodeSnapshot, ...]:
        """Node snapshots of one replica as of ``now - staleness``."""
        return self.get(replica, now, staleness).nodes

    def tree(self, now: float,
             staleness: float = 0.0) -> dict[int, dict[int, NodeSnapshot]]:
        """The full replica -> node -> snapshot tree the policies see."""
        out: dict[int, dict[int, NodeSnapshot]] = {}
        for r in range(self.n_replicas):
            out[r] = {ns.node: ns for ns in self.nodes(r, now, staleness)}
        return out


class RouterPolicy:
    """Two-stage decision rule over the (possibly stale) view.

    ``choose`` picks a replica.  Policies that understand the node tier set
    ``hierarchical = True`` and implement ``choose_node``; for the rest the
    caller falls back to its own spread (the sim round-robins over the
    replica's TP group, exactly the flat-router behavior).
    """

    name: str = "abstract"
    hierarchical: bool = False

    def choose(self, snaps: list[ReplicaSnapshot], req: RequestInfo,
               rng: random.Random) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    def choose_node(self, snap: ReplicaSnapshot, req: RequestInfo,
                    rng: random.Random) -> int:
        """Pick a node slot within the chosen replica; -1 defers to the
        caller's flat spread."""
        return -1

    @staticmethod
    def _argmin(snaps: list[ReplicaSnapshot], key,
                rng: random.Random) -> int:
        best = min(key(s) for s in snaps)
        ties = [s.replica for s in snaps if key(s) == best]
        return ties[0] if len(ties) == 1 else rng.choice(ties)

    @staticmethod
    def _argmin_node(nodes: tuple[NodeSnapshot, ...], key,
                     rng: random.Random) -> int:
        best = min(key(ns) for ns in nodes)
        ties = [ns.node for ns in nodes if key(ns) == best]
        return ties[0] if len(ties) == 1 else rng.choice(ties)


class RoundRobinPolicy(RouterPolicy):
    """Static rotation — load-blind; the baseline every DP router starts as."""

    name = "round_robin"

    def __init__(self) -> None:
        self._i = -1

    def choose(self, snaps, req, rng):
        self._i = (self._i + 1) % len(snaps)
        return snaps[self._i].replica


class JoinShortestQueuePolicy(RouterPolicy):
    """Route to the replica with the fewest queued + active requests."""

    name = "join_shortest_queue"

    def choose(self, snaps, req, rng):
        return self._argmin(snaps, lambda s: s.backlog, rng)


class LeastKVPolicy(RouterPolicy):
    """Route to the replica with the lowest KV-cache occupancy.

    KV occupancy integrates sequence *length*, not just request count, so it
    sees heavy hitters that JSQ's unit counting misses — but it reacts more
    slowly, because occupancy only moves once a request is admitted.
    Queue depth breaks ties so an un-admitted backlog still repels traffic.
    """

    name = "least_kv"

    def choose(self, snaps, req, rng):
        return self._argmin(
            snaps, lambda s: (round(s.kv_occupancy, 3), s.backlog), rng)


class PredictionAwarePolicy(RouterPolicy):
    """Route to the replica with the least expected remaining decode work.

    ``expected_work`` sums the workload model's expected decode length over
    the replica's queued + active requests minus tokens already produced —
    the universal-load-balancing-principle estimate of time-to-drain.
    """

    name = "prediction_aware"

    def choose(self, snaps, req, rng):
        return self._argmin(snaps, lambda s: s.expected_work, rng)


class PrefixAffinityPolicy(RouterPolicy):
    """Consistent-hash session affinity with a load-ceiling spill to JSQ.

    Requests sharing a prefix/session key land on the same *home* replica
    (and the same home node within it), so the home's prefix cache keeps
    serving the shared prompt prefix — the affinity half of the
    affinity-vs-balance tension online DP routers live in.  The balance
    half is the spill rule: when the home's backlog exceeds
    ``spill_factor`` x the mean (with an absolute ``spill_floor`` so a
    near-idle cluster never spills), the request joins the shortest queue
    instead — a hot session degrades into routable load rather than a hot
    replica.  The hash ring is seeded and static, so placement is
    deterministic and survives view churn.
    """

    name = "prefix_affinity"
    hierarchical = True
    VNODES = 64                 # virtual points per replica on the ring

    def __init__(self, spill_factor: float = 1.25,
                 spill_floor: int = 4) -> None:
        self.spill_factor = spill_factor
        self.spill_floor = spill_floor
        self._ring_n = -1
        self._ring_keys: list[int] = []
        self._ring_owner: list[int] = []
        self.spills = 0

    def _build_ring(self, n: int) -> None:
        pts = sorted(
            (crc32(f"replica:{r}:{v}".encode()), r)
            for r in range(n) for v in range(self.VNODES))
        self._ring_keys = [p[0] for p in pts]
        self._ring_owner = [p[1] for p in pts]
        self._ring_n = n

    def home_replica(self, key: int, n: int) -> int:
        """Consistent-hash home for an affinity key among n replicas."""
        if self._ring_n != n:
            self._build_ring(n)
        h = crc32(str(key).encode())
        i = bisect_right(self._ring_keys, h) % len(self._ring_keys)
        return self._ring_owner[i]

    def _ceiling(self, backlogs: list[int]) -> float:
        mean = sum(backlogs) / len(backlogs)
        return max(self.spill_floor, self.spill_factor * mean)

    def choose(self, snaps, req, rng):
        home = self.home_replica(req.affinity_key, len(snaps))
        if snaps[home].backlog <= self._ceiling(
                [s.backlog for s in snaps]):
            return home
        self.spills += 1
        return self._argmin(snaps, lambda s: s.backlog, rng)

    def choose_node(self, snap, req, rng):
        nodes = snap.nodes
        if not nodes:
            return -1
        if len(nodes) == 1:
            return nodes[0].node
        home = nodes[crc32(b"node:%d" % req.affinity_key) % len(nodes)]
        if home.backlog <= self._ceiling([ns.backlog for ns in nodes]):
            return home.node
        return self._argmin_node(nodes, lambda ns: ns.backlog, rng)


class HierarchicalJSQPolicy(RouterPolicy):
    """Two-stage JSQ over the snapshot tree.

    Stage 1 picks the replica whose *least-loaded node* has the most free
    room (replica backlog breaks ties) — which differs from flat JSQ
    exactly when replica totals are balanced but intra-replica node skew
    hides a free node.  Stage 2 joins that node; per-device live-sequence
    counts break node ties so the freest device slot wins.
    """

    name = "hierarchical_jsq"
    hierarchical = True

    @staticmethod
    def _node_key(ns: NodeSnapshot) -> tuple:
        return (ns.backlog, min(ns.dev_active) if ns.dev_active else 0)

    def choose(self, snaps, req, rng):
        def key(s: ReplicaSnapshot):
            if s.nodes:
                return (min(ns.backlog for ns in s.nodes), s.backlog)
            return (s.backlog, s.backlog)
        return self._argmin(snaps, key, rng)

    def choose_node(self, snap, req, rng):
        if not snap.nodes:
            return -1
        return self._argmin_node(snap.nodes, self._node_key, rng)


POLICIES: dict[str, type[RouterPolicy]] = {
    p.name: p for p in (RoundRobinPolicy, JoinShortestQueuePolicy,
                        LeastKVPolicy, PredictionAwarePolicy,
                        PrefixAffinityPolicy, HierarchicalJSQPolicy)
}


def make_policy(policy: str | RouterPolicy) -> RouterPolicy:
    if isinstance(policy, RouterPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown router policy {policy!r}; have {sorted(POLICIES)}")


class Router:
    """Dispatches requests across N replicas under a pluggable policy.

    Between view refreshes the router does optimistic local accounting:
    each dispatch bumps the cached snapshot's backlog/expected_work (and
    the chosen node's, for hierarchical policies) so that a burst arriving
    inside one refresh interval still spreads out.  The bumps assume the
    view is *fresh*; once the newest snapshot for a replica is older than
    ``bump_lag_tol`` — the view transport is lagging — the router can no
    longer trust that a refresh reflects its recent dispatches, so the
    bumps switch off and the stale-router-view pathology emerges from the
    link itself.  The legacy ``staleness`` knob (> 0 widens reads to
    ``now - staleness`` and disables bumps outright) is retained for
    explicit experiments.
    """

    #: view age beyond which optimistic bumps are distrusted (s); must
    #: exceed any healthy refresh period + transport delay
    BUMP_LAG_TOL = 0.05

    def __init__(self, n_replicas: int,
                 policy: str | RouterPolicy = "round_robin",
                 staleness: float = 0.0, seed: int = 0,
                 bump_lag_tol: float | None = None) -> None:
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.n_replicas = n_replicas
        self.policy = make_policy(policy)
        self.rng = random.Random(seed ^ 0x7077E7)
        self.view = HierarchicalView(n_replicas)
        self.bump_lag_tol = (self.BUMP_LAG_TOL if bump_lag_tol is None
                             else bump_lag_tol)
        self.staleness = staleness      # property: widens view retention
        self.decisions: list[RoutingDecision] = []
        self.routed_per_replica: list[int] = [0] * n_replicas
        # optimistic deltas since each replica's last snapshot
        self._bump_backlog: list[int] = [0] * n_replicas
        self._bump_work: list[float] = [0.0] * n_replicas
        self._bump_node: dict[int, int] = {}    # node id -> dispatches

    @property
    def staleness(self) -> float:
        return self._staleness

    @staleness.setter
    def staleness(self, value: float) -> None:
        # the view must retain history at least as deep as the staleness we
        # will read at, or get() would silently serve fresher state
        self._staleness = value
        if value > 0:
            self.view.max_age = max(self.view.max_age, 2.0 * value)

    # -- view ingestion --------------------------------------------------

    def observe(self, snap: ReplicaSnapshot) -> None:
        """Ingest one snapshot (however late the transport delivered it —
        the measured view lag is always ``now - latest_ts`` at read time,
        so delivery time needs no separate bookkeeping here).

        Optimistic bumps are deltas since the snapshot the view *serves*;
        a late out-of-order arrival (older than the newest held) does not
        replace that snapshot, so it must not clear the deltas either —
        resetting on it would make the replica look emptier than its
        retained state and dogpile the next burst."""
        newest = snap.ts >= self.view.latest_ts(snap.replica)
        self.view.update(snap)
        if newest:
            self._bump_backlog[snap.replica] = 0
            self._bump_work[snap.replica] = 0.0
            for ns in snap.nodes:
                self._bump_node.pop(ns.node, None)

    def view_lag(self, now: float) -> float:
        """Measured staleness of the view (s): age of the newest snapshot,
        worst case across replicas.  This is a property of the transport
        feeding the router, not a knob — inf until every replica has
        reported at least once."""
        return max(now - self.view.latest_ts(r)
                   for r in range(self.n_replicas))

    # -- routing ---------------------------------------------------------

    def _bumps_fresh(self, snap: ReplicaSnapshot, now: float) -> bool:
        """Optimistic accounting applies only to a fresh view: the legacy
        staleness knob disables it, and so does a measured view lag beyond
        tolerance.  An empty view (ts == -inf) counts as fresh — the
        router has dispatched nothing the view could be missing."""
        if self._staleness > 0.0:
            return False
        return snap.ts == float("-inf") or now - snap.ts <= self.bump_lag_tol

    def _effective(self, replica: int, now: float) -> ReplicaSnapshot:
        snap = self.view.get(replica, now, self.staleness)
        if not self._bumps_fresh(snap, now):
            return snap
        b, w = self._bump_backlog[replica], self._bump_work[replica]
        if b == 0 and w == 0.0:
            return snap
        return dataclasses.replace(
            snap, queue_depth=snap.queue_depth + b,
            expected_work=snap.expected_work + w)

    def _node_effective(self, snap: ReplicaSnapshot) -> ReplicaSnapshot:
        """Fold node-level optimistic bumps into the node tier."""
        nb = self._bump_node
        if not nb or not snap.nodes:
            return snap
        nodes = tuple(
            dataclasses.replace(ns, queue_depth=ns.queue_depth + nb[ns.node])
            if ns.node in nb else ns
            for ns in snap.nodes)
        return dataclasses.replace(snap, nodes=nodes)

    def route_ex(self, req: RequestInfo, now: float = 0.0) -> RoutingDecision:
        """Two-stage routing: policy picks a replica, then (for
        hierarchical policies) a node slot within it.  ``decision.node``
        is -1 when the policy left node placement to the caller."""
        snaps = [self._effective(r, now) for r in range(self.n_replicas)]
        if self.policy.hierarchical:
            # node-tier optimistic bumps must be visible to BOTH stages:
            # stage 1 ranks replicas by their node interiors
            snaps = [self._node_effective(s) if self._bumps_fresh(s, now)
                     else s for s in snaps]
        replica = self.policy.choose(snaps, req, self.rng)
        if not 0 <= replica < self.n_replicas:
            raise RuntimeError(
                f"policy {self.policy.name} chose invalid replica {replica}")
        node = -1
        if self.policy.hierarchical and snaps[replica].nodes:
            node = self.policy.choose_node(snaps[replica], req, self.rng)
            if node >= 0:
                self._bump_node[node] = self._bump_node.get(node, 0) + 1
        self.routed_per_replica[replica] += 1
        self._bump_backlog[replica] += 1
        self._bump_work[replica] += max(req.predicted_decode, 1.0)
        decision = RoutingDecision(
            ts=now, flow=req.flow, replica=replica,
            policy=self.policy.name,
            view_ts=snaps[replica].ts, node=node)
        self.decisions.append(decision)
        return decision

    def route(self, req: RequestInfo, now: float = 0.0) -> int:
        return self.route_ex(req, now).replica

    # -- introspection ---------------------------------------------------

    def imbalance(self) -> float:
        """max/mean routed-count ratio (1.0 = perfectly even)."""
        total = sum(self.routed_per_replica)
        if total == 0:
            return 1.0
        mean = total / self.n_replicas
        return max(self.routed_per_replica) / mean


# ----------------------------------------------------------------------
# live-engine replica set
# ----------------------------------------------------------------------

def engine_snapshot(engine, replica: int, now: float,
                    default_decode: float = 32.0,
                    node_base: int | None = None) -> ReplicaSnapshot:
    """Build a ReplicaSnapshot from an InferenceEngine-shaped object.

    Duck-typed: needs ``sched`` (queue, running, cfg.max_slots) and ``pool``
    (occupancy()).  Works on the real engine and on test stubs alike.  The
    snapshot carries a one-node tier (an engine is one serving node;
    ``node_base`` places it in the cluster's node coordinate space) with
    per-device live counts derived from the engine's slot ids — the same
    device axis its DISPATCH/D2H telemetry uses.
    """
    sched = engine.sched
    queued = list(sched.queue)
    running = list(sched.running.values())
    work = 0.0
    for r in queued:
        work += max(getattr(r, "max_new_tokens", default_decode), 1.0)
    for r in running:
        rem = (getattr(r, "max_new_tokens", default_decode)
               - getattr(r, "tokens_out", 0))
        work += max(rem, 1.0)
    occ = float(engine.pool.occupancy())
    slot_ids = [k for k in getattr(sched, "running", {})
                if isinstance(k, int)]
    if slot_ids:
        dev = [0, 0, 0, 0]
        for k in slot_ids:          # engine telemetry maps slot -> slot % 4
            dev[k % 4] += 1
        dev_active = tuple(dev)
    else:
        dev_active = ()
    node_id = replica if node_base is None else node_base
    node = NodeSnapshot(
        node=node_id, queue_depth=len(queued), active=len(running),
        slots=sched.cfg.max_slots, kv_occupancy=occ, expected_work=work,
        dev_active=dev_active)
    return ReplicaSnapshot(
        replica=replica, ts=now,
        queue_depth=len(queued), active=len(running),
        slots=sched.cfg.max_slots,
        kv_occupancy=occ,
        expected_work=work, nodes=(node,))


class ReplicaSet:
    """N serving-engine replicas behind one Router.

    The router's view is **telemetry-borne**: ``refresh`` snapshots every
    engine on a configurable period (not per request — re-snapshotting
    every engine on every submit is O(n_replicas) per request and defeats
    the staleness model entirely) and publishes the snapshots through a
    :class:`ModeledLink`, the same transport abstraction the DPU uplink
    uses.  The router only learns a snapshot when the link delivers it, so
    ``Router.view_lag`` is a measured property of the link (delay, jitter,
    loss) rather than a configuration knob.  The default link is
    zero-latency/lossless (a front-end colocated with its replicas);
    experiments pass real ``LinkParams``.

    When a ``plane`` is attached, the front-end renders its own activity as
    DPU-visible telemetry through the same columnar path the simulator and
    engines use: one INGRESS_PKT per routed request (tagged with the chosen
    replica) and one ingress QUEUE_SAMPLE per replica per *delivered* view
    refresh — the queue columns ride the same modeled link as the router's
    view, so the detection plane and the router see the identical lagged
    picture.
    """

    def __init__(self, engines: list,
                 policy: str | RouterPolicy = "join_shortest_queue",
                 staleness: float = 0.0, seed: int = 0,
                 plane=None,
                 view_link: LinkParams | None = None,
                 refresh_period: float = 2e-3,
                 nodes_per_replica: int = 1) -> None:
        if not engines:
            raise ValueError("need at least one engine replica")
        if nodes_per_replica < 1:
            raise ValueError("nodes_per_replica must be >= 1")
        self.engines = engines
        self.router = Router(len(engines), policy=policy,
                             staleness=staleness, seed=seed)
        self.plane = plane
        self.nodes_per_replica = nodes_per_replica
        self.refresh_period = refresh_period
        self._last_refresh = float("-inf")
        # zero-knob links draw no randomness, so the default front-end
        # stays deterministic; a jittery/lossy link consumes only its own
        # seeded stream
        self._view_rng = np.random.default_rng(seed ^ 0x51EF)
        # view snapshots are idempotent last-writer-wins datagrams, not a
        # sequenced stream — out-of-order arrival (view flapping) is part
        # of the channel, so ordered-stream clamping stays off
        self.view_link = ModeledLink(
            dataclasses.replace(view_link or LinkParams(delay=0.0),
                                ordered=False),
            self._view_rng)
        self._pending = EventBatchBuilder() if plane is not None else None

    # -- view pipeline ---------------------------------------------------

    def node_replica(self, node: int) -> int | None:
        """Map a cluster/detector node id to the replica (engine index)
        that owns it; None when the id is cluster-wide (-1) or out of
        range.  Detector findings carry *node* coordinates — indexing
        ``engines`` with one directly conflates the two spaces."""
        if node < 0:
            return None
        rep = node // self.nodes_per_replica
        return rep if rep < len(self.engines) else None

    def refresh(self, now: float = 0.0, force: bool = False) -> None:
        """Periodic view publication + delivery of matured snapshots."""
        if force or now - self._last_refresh >= self.refresh_period:
            self._last_refresh = now
            snaps = [
                engine_snapshot(eng, i, now,
                                node_base=i * self.nodes_per_replica)
                for i, eng in enumerate(self.engines)]
            self.view_link.send(now, snaps)
        for snaps in self.view_link.deliver(now):
            for snap in snaps:
                self.router.observe(snap)
            if self._pending is not None:
                # meta 0 == META_DIR_INGRESS: the front-end's per-replica
                # ingress queue depths, one columnar append per delivered
                # refresh (stamped with the snapshot time, as a DPU-side
                # collector would see it)
                ids = np.arange(len(snaps), dtype=np.int64)
                self._pending.add_columns(
                    np.full(len(snaps), snaps[0].ts),
                    EventKind.QUEUE_SAMPLE,
                    node=np.asarray([s.nodes[0].node if s.nodes
                                     else s.replica for s in snaps],
                                    np.int64),
                    depth=np.asarray([s.queue_depth for s in snaps],
                                     np.int64),
                    meta=0, replica=ids)

    def view_lag(self, now: float) -> float:
        """The measured router-view staleness (see Router.view_lag)."""
        return self.router.view_lag(now)

    def flush_telemetry(self) -> None:
        """Hand buffered front-end telemetry to the plane as one batch."""
        if self._pending is None or len(self._pending) == 0:
            return
        batch = self._pending.build(sort=True)
        self._pending.clear()
        self.plane.observe_batch(batch)

    def submit(self, req, now: float = 0.0) -> int:
        """Route one ServeRequest to a replica; returns the replica id."""
        self.refresh(now)
        replica = self.router.route(RequestInfo(
            flow=getattr(req, "req_id", -1),
            prompt_len=getattr(req, "prompt_len", 0),
            predicted_decode=float(getattr(req, "max_new_tokens", 0)),
            session=int(getattr(req, "session", -1))), now)
        if self._pending is not None:
            # node carries CLUSTER-node coordinates (the replica's first
            # node), matching the queue-sample rows — node-keyed detectors
            # must never see the two coordinate spaces mixed
            self._pending.add(
                ts=now, kind=EventKind.INGRESS_PKT,
                node=replica * self.nodes_per_replica,
                flow=getattr(req, "req_id", -1),
                size=2 * getattr(req, "prompt_len", 0),
                replica=replica)
        self.engines[replica].submit(req)
        self.flush_telemetry()
        return replica

    def submit_all(self, reqs, now: float = 0.0) -> list[int]:
        return [self.submit(r, now) for r in reqs]

    # ------------------------------------------------------------------
    # EngineControls — the router is a mitigation actuator too: the DPU
    # command bus (or the instant controller) can rebalance queued work
    # across replicas without touching any engine internals
    # ------------------------------------------------------------------

    def apply_action(self, action: str, node: int, detail: dict) -> bool:
        if action in ("rebalance_replicas", "rebalance_nodes"):
            # both routing actuators level the queued backlog; at the
            # live front-end the replica IS the node group
            self.rebalance(now=detail.get("now", 0.0))
            return True
        # per-engine knobs route through the explicit node -> replica map;
        # an id outside the cluster is refused, never silently mis-targeted
        rep = self.node_replica(node)
        if rep is None:
            return False
        eng = self.engines[rep]
        if hasattr(eng, "apply_action"):
            return bool(eng.apply_action(action, node, detail))
        return False

    def rebalance(self, now: float = 0.0) -> int:
        """Drain every replica's scheduler queue and re-deal the backlog
        round-robin starting from the shallowest replica; refreshes the
        router view so the next routed request sees the new state.
        Returns the number of requests moved."""
        backlog = []
        for eng in self.engines:
            q = eng.sched.queue
            backlog.extend(q)
            q.clear()
        backlog.sort(key=lambda r: getattr(r, "arrival", 0.0))
        order = sorted(range(len(self.engines)),
                       key=lambda i: len(self.engines[i].sched.running))
        for i, req in enumerate(backlog):
            self.engines[order[i % len(order)]].sched.submit(req)
        self.refresh(now, force=True)
        self.flush_telemetry()
        return len(backlog)
