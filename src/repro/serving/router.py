"""Cross-replica request router — the data-parallel dispatch layer.

The paper's runbooks cover skew *within* one tensor-parallel serving group;
the largest real-world imbalances arise one level up, where a front-end
router spreads requests across N data-parallel replicas (each replica being
an ``InferenceEngine`` / sim node group).  A bad policy — or a good policy
fed a stale view — manufactures exactly the pathologies Table 3(d) catalogs:
one replica's queue grows while its peers idle, and the DPU sees per-replica
EGRESS-rate divergence long before client p99 explodes.

Pieces:

  ReplicaSnapshot  — the router-visible state of one replica at time ts
                     (queue depth, active slots, KV occupancy, expected
                     remaining decode work).  This is deliberately the same
                     information a DPU-side collector could export: queue
                     samples and KV-occupancy telemetry, no model internals.
  RouterView       — per-replica snapshot store with an explicit staleness
                     model: policies read the view as of ``now - staleness``,
                     which is how the stale-router-view pathology is injected
                     and how real eventually-consistent routers behave.
  RouterPolicy     — pluggable decision rule; four implementations:
                       round_robin          (static, load-blind)
                       join_shortest_queue  (queued + active work units)
                       least_kv             (lowest KV-cache occupancy)
                       prediction_aware     (lowest expected remaining decode
                                             tokens, using the workload
                                             model's expected decode length)
  Router           — routes RequestInfo -> replica id, with optimistic local
                     accounting between view refreshes (a fresh router bumps
                     its own view after each dispatch so a microburst does
                     not dogpile one replica; a stale router cannot).
  ReplicaSet       — N live engines behind one Router; ``submit`` snapshots
                     each engine, routes, and forwards.

Every routing decision is recorded; tests assert conservation (no request
dropped, each routed exactly once) and the JSQ invariant (never route to a
strictly longer queue than the minimum in view).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

import numpy as np

from repro.core.events import EventBatchBuilder, EventKind


@dataclass(frozen=True)
class ReplicaSnapshot:
    """Router-visible state of one replica at a point in time."""

    replica: int
    ts: float
    queue_depth: int = 0        # requests waiting, not yet in a decode slot
    active: int = 0             # requests currently decoding
    slots: int = 1              # decode slot capacity (for normalization)
    kv_occupancy: float = 0.0   # 0..1 fraction of KV pool in use
    expected_work: float = 0.0  # predicted remaining decode tokens (queued+active)

    @property
    def backlog(self) -> int:
        """Total requests the replica is responsible for right now."""
        return self.queue_depth + self.active


@dataclass(frozen=True)
class RequestInfo:
    """What the router may know about a request at dispatch time."""

    flow: int
    prompt_len: int = 0
    predicted_decode: float = 0.0   # expected decode length (workload model)


@dataclass(frozen=True)
class RoutingDecision:
    ts: float
    flow: int
    replica: int
    policy: str
    view_ts: float              # timestamp of the snapshot the choice used


class RouterView:
    """Per-replica snapshot history with an explicit staleness model.

    ``get(replica, now, staleness)`` returns the newest snapshot no younger
    than ``now - staleness`` — i.e. what an eventually-consistent router
    actually knows.  History is pruned by AGE (``max_age``, which callers
    must keep >= the deepest staleness they will ask for), with a generous
    entry-count backstop so a pathological snapshot flood stays bounded.
    """

    MAX_HISTORY = 4096      # backstop only; age-based pruning is primary

    def __init__(self, n_replicas: int, max_age: float = 2.0) -> None:
        self.n_replicas = n_replicas
        self.max_age = max_age
        self._hist: list[list[ReplicaSnapshot]] = [
            [] for _ in range(n_replicas)]

    def update(self, snap: ReplicaSnapshot) -> None:
        h = self._hist[snap.replica]
        h.append(snap)
        cutoff = snap.ts - self.max_age
        drop = 0
        while drop < len(h) - 1 and h[drop + 1].ts <= cutoff:
            drop += 1
        if len(h) - drop > self.MAX_HISTORY:
            drop = len(h) - self.MAX_HISTORY
        if drop:
            del h[:drop]

    def get(self, replica: int, now: float,
            staleness: float = 0.0) -> ReplicaSnapshot:
        h = self._hist[replica]
        if not h:
            return ReplicaSnapshot(replica=replica, ts=float("-inf"))
        if staleness <= 0.0:
            return h[-1]
        cutoff = now - staleness
        for snap in reversed(h):
            if snap.ts <= cutoff:
                return snap
        return h[0]     # nothing old enough: the oldest we have

    def latest_ts(self, replica: int) -> float:
        h = self._hist[replica]
        return h[-1].ts if h else float("-inf")


class RouterPolicy:
    """Decision rule: pick a replica given the (possibly stale) view."""

    name: str = "abstract"

    def choose(self, snaps: list[ReplicaSnapshot], req: RequestInfo,
               rng: random.Random) -> int:  # pragma: no cover - interface
        raise NotImplementedError

    @staticmethod
    def _argmin(snaps: list[ReplicaSnapshot], key,
                rng: random.Random) -> int:
        best = min(key(s) for s in snaps)
        ties = [s.replica for s in snaps if key(s) == best]
        return ties[0] if len(ties) == 1 else rng.choice(ties)


class RoundRobinPolicy(RouterPolicy):
    """Static rotation — load-blind; the baseline every DP router starts as."""

    name = "round_robin"

    def __init__(self) -> None:
        self._i = -1

    def choose(self, snaps, req, rng):
        self._i = (self._i + 1) % len(snaps)
        return snaps[self._i].replica


class JoinShortestQueuePolicy(RouterPolicy):
    """Route to the replica with the fewest queued + active requests."""

    name = "join_shortest_queue"

    def choose(self, snaps, req, rng):
        return self._argmin(snaps, lambda s: s.backlog, rng)


class LeastKVPolicy(RouterPolicy):
    """Route to the replica with the lowest KV-cache occupancy.

    KV occupancy integrates sequence *length*, not just request count, so it
    sees heavy hitters that JSQ's unit counting misses — but it reacts more
    slowly, because occupancy only moves once a request is admitted.
    Queue depth breaks ties so an un-admitted backlog still repels traffic.
    """

    name = "least_kv"

    def choose(self, snaps, req, rng):
        return self._argmin(
            snaps, lambda s: (round(s.kv_occupancy, 3), s.backlog), rng)


class PredictionAwarePolicy(RouterPolicy):
    """Route to the replica with the least expected remaining decode work.

    ``expected_work`` sums the workload model's expected decode length over
    the replica's queued + active requests minus tokens already produced —
    the universal-load-balancing-principle estimate of time-to-drain.
    """

    name = "prediction_aware"

    def choose(self, snaps, req, rng):
        return self._argmin(snaps, lambda s: s.expected_work, rng)


POLICIES: dict[str, type[RouterPolicy]] = {
    p.name: p for p in (RoundRobinPolicy, JoinShortestQueuePolicy,
                        LeastKVPolicy, PredictionAwarePolicy)
}


def make_policy(policy: str | RouterPolicy) -> RouterPolicy:
    if isinstance(policy, RouterPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown router policy {policy!r}; have {sorted(POLICIES)}")


class Router:
    """Dispatches requests across N replicas under a pluggable policy.

    Between view refreshes a *fresh* router does optimistic local accounting:
    each dispatch bumps the cached snapshot's backlog/expected_work so that a
    burst arriving inside one refresh interval still spreads out.  When
    ``staleness > 0`` the router is modeling a lagging view pipeline, so the
    bumps are disabled too — the stale-router-view pathology in one knob.
    """

    def __init__(self, n_replicas: int,
                 policy: str | RouterPolicy = "round_robin",
                 staleness: float = 0.0, seed: int = 0) -> None:
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.n_replicas = n_replicas
        self.policy = make_policy(policy)
        self.rng = random.Random(seed ^ 0x7077E7)
        self.view = RouterView(n_replicas)
        self.staleness = staleness      # property: widens view retention
        self.decisions: list[RoutingDecision] = []
        self.routed_per_replica: list[int] = [0] * n_replicas
        # optimistic deltas since each replica's last snapshot
        self._bump_backlog: list[int] = [0] * n_replicas
        self._bump_work: list[float] = [0.0] * n_replicas

    @property
    def staleness(self) -> float:
        return self._staleness

    @staleness.setter
    def staleness(self, value: float) -> None:
        # the view must retain history at least as deep as the staleness we
        # will read at, or get() would silently serve fresher state
        self._staleness = value
        if value > 0:
            self.view.max_age = max(self.view.max_age, 2.0 * value)

    # -- view ingestion --------------------------------------------------

    def observe(self, snap: ReplicaSnapshot) -> None:
        self.view.update(snap)
        self._bump_backlog[snap.replica] = 0
        self._bump_work[snap.replica] = 0.0

    # -- routing ---------------------------------------------------------

    def _effective(self, replica: int, now: float) -> ReplicaSnapshot:
        snap = self.view.get(replica, now, self.staleness)
        if self.staleness > 0.0:
            return snap
        b, w = self._bump_backlog[replica], self._bump_work[replica]
        if b == 0 and w == 0.0:
            return snap
        return ReplicaSnapshot(
            replica=replica, ts=snap.ts,
            queue_depth=snap.queue_depth + b, active=snap.active,
            slots=snap.slots, kv_occupancy=snap.kv_occupancy,
            expected_work=snap.expected_work + w)

    def route(self, req: RequestInfo, now: float = 0.0) -> int:
        snaps = [self._effective(r, now) for r in range(self.n_replicas)]
        replica = self.policy.choose(snaps, req, self.rng)
        if not 0 <= replica < self.n_replicas:
            raise RuntimeError(
                f"policy {self.policy.name} chose invalid replica {replica}")
        self.routed_per_replica[replica] += 1
        self._bump_backlog[replica] += 1
        self._bump_work[replica] += max(req.predicted_decode, 1.0)
        self.decisions.append(RoutingDecision(
            ts=now, flow=req.flow, replica=replica,
            policy=self.policy.name,
            view_ts=snaps[replica].ts))
        return replica

    # -- introspection ---------------------------------------------------

    def imbalance(self) -> float:
        """max/mean routed-count ratio (1.0 = perfectly even)."""
        total = sum(self.routed_per_replica)
        if total == 0:
            return 1.0
        mean = total / self.n_replicas
        return max(self.routed_per_replica) / mean


# ----------------------------------------------------------------------
# live-engine replica set
# ----------------------------------------------------------------------

def engine_snapshot(engine, replica: int, now: float,
                    default_decode: float = 32.0) -> ReplicaSnapshot:
    """Build a ReplicaSnapshot from an InferenceEngine-shaped object.

    Duck-typed: needs ``sched`` (queue, running, cfg.max_slots) and ``pool``
    (occupancy()).  Works on the real engine and on test stubs alike.
    """
    sched = engine.sched
    queued = list(sched.queue)
    running = list(sched.running.values())
    work = 0.0
    for r in queued:
        work += max(getattr(r, "max_new_tokens", default_decode), 1.0)
    for r in running:
        rem = (getattr(r, "max_new_tokens", default_decode)
               - getattr(r, "tokens_out", 0))
        work += max(rem, 1.0)
    return ReplicaSnapshot(
        replica=replica, ts=now,
        queue_depth=len(queued), active=len(running),
        slots=sched.cfg.max_slots,
        kv_occupancy=float(engine.pool.occupancy()),
        expected_work=work)


class ReplicaSet:
    """N serving-engine replicas behind one Router.

    The router's view refreshes from live engine state on every submit (a
    front-end colocated with its replicas); ``staleness`` > 0 degrades that
    to the eventually-consistent case for experiments.

    When a ``plane`` is attached, the front-end renders its own activity as
    DPU-visible telemetry through the same columnar path the simulator and
    engines use: one INGRESS_PKT per routed request (tagged with the chosen
    replica) and one ingress QUEUE_SAMPLE per replica per view refresh —
    exactly the signals the Table 3(d) cross-replica detector consumes, so
    a routing imbalance is observable without reading router internals.
    """

    def __init__(self, engines: list,
                 policy: str | RouterPolicy = "join_shortest_queue",
                 staleness: float = 0.0, seed: int = 0,
                 plane=None) -> None:
        if not engines:
            raise ValueError("need at least one engine replica")
        self.engines = engines
        self.router = Router(len(engines), policy=policy,
                             staleness=staleness, seed=seed)
        self.plane = plane
        self._pending = EventBatchBuilder() if plane is not None else None

    def refresh(self, now: float = 0.0) -> None:
        depths: list[int] = []
        for i, eng in enumerate(self.engines):
            snap = engine_snapshot(eng, i, now)
            self.router.observe(snap)
            depths.append(snap.queue_depth)
        if self._pending is not None:
            # meta 0 == META_DIR_INGRESS: the front-end's per-replica
            # ingress queue depths, one columnar append per refresh
            ids = np.arange(len(self.engines), dtype=np.int64)
            self._pending.add_columns(
                np.full(len(depths), now), EventKind.QUEUE_SAMPLE,
                node=ids, depth=np.asarray(depths, np.int64), meta=0,
                replica=ids)

    def flush_telemetry(self) -> None:
        """Hand buffered front-end telemetry to the plane as one batch."""
        if self._pending is None or len(self._pending) == 0:
            return
        batch = self._pending.build(sort=True)
        self._pending.clear()
        self.plane.observe_batch(batch)

    def submit(self, req, now: float = 0.0) -> int:
        """Route one ServeRequest to a replica; returns the replica id."""
        self.refresh(now)
        replica = self.router.route(RequestInfo(
            flow=getattr(req, "req_id", -1),
            prompt_len=getattr(req, "prompt_len", 0),
            predicted_decode=float(getattr(req, "max_new_tokens", 0))), now)
        if self._pending is not None:
            self._pending.add(
                ts=now, kind=EventKind.INGRESS_PKT, node=replica,
                flow=getattr(req, "req_id", -1),
                size=2 * getattr(req, "prompt_len", 0),
                replica=replica)
        self.engines[replica].submit(req)
        self.flush_telemetry()
        return replica

    def submit_all(self, reqs, now: float = 0.0) -> list[int]:
        return [self.submit(r, now) for r in reqs]

    # ------------------------------------------------------------------
    # EngineControls — the router is a mitigation actuator too: the DPU
    # command bus (or the instant controller) can rebalance queued work
    # across replicas without touching any engine internals
    # ------------------------------------------------------------------

    def apply_action(self, action: str, node: int, detail: dict) -> bool:
        if action == "rebalance_replicas":
            self.rebalance(now=detail.get("now", 0.0))
            return True
        # per-engine knobs fall through to the replica named by ``node``
        if 0 <= node < len(self.engines):
            eng = self.engines[node]
            if hasattr(eng, "apply_action"):
                return bool(eng.apply_action(action, node, detail))
        return False

    def rebalance(self, now: float = 0.0) -> int:
        """Drain every replica's scheduler queue and re-deal the backlog
        round-robin starting from the shallowest replica; refreshes the
        router view so the next routed request sees the new state.
        Returns the number of requests moved."""
        backlog = []
        for eng in self.engines:
            q = eng.sched.queue
            backlog.extend(q)
            q.clear()
        backlog.sort(key=lambda r: getattr(r, "arrival", 0.0))
        order = sorted(range(len(self.engines)),
                       key=lambda i: len(self.engines[i].sched.running))
        for i, req in enumerate(backlog):
            self.engines[order[i % len(order)]].sched.submit(req)
        self.refresh(now)
        self.flush_telemetry()
        return len(backlog)
