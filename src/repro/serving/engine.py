"""InferenceEngine: continuous-batching serving loop with the DPU-analog
telemetry plane wired through it (the paper's architecture, live).

Per-slot KV caches are a stacked pytree; the decode step is the Model's
single-sequence step vmapped over slots, so every slot carries its own
position/ring state (true continuous batching).  Telemetry taps emit the
exact event schema the detectors consume: INGRESS on request arrival, H2D
around prefill feeds, DISPATCH per step, D2H per step, EGRESS per token,
QUEUE_SAMPLE per scheduler tick — and the engine implements EngineControls
so the mitigation controller can close the loop (§5).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detectors import (
    META_DIR_INGRESS,
    META_FIN,
    META_KV_OCC,
)
from repro.core.events import EventBatchBuilder, EventKind
from repro.core.mitigation import MitigationController
from repro.core.telemetry import TelemetryPlane
from repro.models import Model
from repro.serving.kvcache import PagedKVPool
from repro.serving.scheduler import Scheduler, SchedulerConfig, ServeRequest


@dataclass
class EngineConfig:
    max_slots: int = 8
    max_seq: int = 256
    page_size: int = 16
    n_pages: int = 512
    node: int = 0
    telemetry: bool = True
    mitigate: bool = True
    greedy: bool = True
    # "instant" — in-process MitigationController (legacy topology);
    # "dpu"     — telemetry crosses a modeled transport into a DPUSidecar
    #             and mitigation commands ride the command bus back
    control: str = "instant"
    dpu: "object | None" = None      # repro.dpu.DPUParams override
    dpu_seed: int = 0                # sidecar wire RNG (XORed with node)
    # observe-only causal tracing (repro.obs): spans for every finding /
    # policy decision / bus exchange / actuation on this engine's loop
    trace: bool = False


class InferenceEngine:
    """Single-host serving engine (smoke scale on CPU, shardable on TPU)."""

    def __init__(self, model: Model, params, cfg: EngineConfig | None = None,
                 plane: TelemetryPlane | None = None) -> None:
        self.model = model
        self.params = params
        self.cfg = cfg or EngineConfig()
        self.sched = Scheduler(SchedulerConfig(max_slots=self.cfg.max_slots))
        self.pool = PagedKVPool(self.cfg.n_pages, self.cfg.page_size)
        self.plane = plane
        if self.plane is None and self.cfg.telemetry:
            self.plane = TelemetryPlane(n_nodes=1, mitigate=self.cfg.mitigate)
        # telemetry sink: the plane directly (instant) or a DPU sidecar
        # whose command bus actuates this engine (dpu)
        if self.cfg.control not in ("instant", "dpu"):
            raise ValueError(
                f"unknown EngineConfig.control {self.cfg.control!r} "
                "(expected 'instant' or 'dpu')")
        self.dpu = None
        self._sink = self.plane
        if self.plane is not None and self.cfg.control == "dpu":
            from repro.dpu import DPUSidecar
            # per-replica wire seed: correlated loss across a ReplicaSet's
            # engines would be an accidental common-mode failure
            self.dpu = DPUSidecar(self.plane, self.cfg.dpu, engine=self,
                                  seed=self.cfg.dpu_seed ^ self.cfg.node,
                                  mitigate=self.cfg.mitigate)
            self._sink = self.dpu
        elif self.plane is not None and self.plane.controller is not None:
            self.plane.controller.engine = self
        # observability (observe-only; engine runs have no FaultSpec, so
        # incidents open on the first finding and never auto-close)
        self.tracer = None
        self.recorder = None
        if self.cfg.trace and self.plane is not None:
            from repro.obs import FlightRecorder, Tracer
            self.recorder = FlightRecorder()
            self.tracer = Tracer(recorder=self.recorder)
            if self.dpu is not None:
                self.dpu.attach_tracer(self.tracer, "primary",
                                       recorder=self.recorder)
            else:
                self.plane.tracer = self.tracer
                self.plane.trace_source = "engine"
                self.plane.recorder = self.recorder
        # stacked per-slot caches: leaf shape (slots, ...)
        single = model.init_cache(1, self.cfg.max_seq)
        self.slot_cache = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.cfg.max_slots,) + a.shape)
            .copy(), single)
        self._decode_vmapped = jax.jit(jax.vmap(
            lambda tok, cache: model.decode_step(self.params, tok, cache),
            in_axes=(0, 0)))
        self._prefill_jit: dict[int, callable] = {}
        self.clock = 0.0
        self.completed: list[ServeRequest] = []
        self.kv_compress = False
        # telemetry back-pressure knob: emit low-priority samples (KV
        # occupancy) every Nth step; throttle_telemetry doubles the stride
        self.telemetry_stride = 1
        self.stats = {"steps": 0, "tokens": 0, "prefills": 0}
        # telemetry taps accumulate columnar rows; one batch per step goes
        # to the plane (the engine feeds the same line-rate path as the sim)
        self._pending = EventBatchBuilder()

    # ------------------------------------------------------------------
    # EngineControls (mitigation actuation surface)
    # ------------------------------------------------------------------

    def apply_action(self, action: str, node: int, detail: dict) -> bool:
        if self.tracer is not None:
            # the live engine has no fault oracle, so no recovery flip —
            # the apply is recorded on the open incident's span tree
            self.tracer.on_apply(action, node, self.clock, False, False,
                                 "engine")
        if action == "inflight_remap":
            self.sched.set_continuous(True)
            return True
        if action == "widen_batch_window":
            self.sched.set_batch_window(
                max(self.sched.cfg.batch_window * 2, 2e-3))
            return True
        if action == "admission_control":
            self.sched.pause_admission(self.clock + 0.05)
            return True
        if action == "smooth_admission":
            self.sched.set_batch_window(
                max(self.sched.cfg.batch_window, 1e-3))
            return True
        if action == "compress_kv":
            self.kv_compress = True
            return True
        if action == "throttle_telemetry":
            self.telemetry_stride = min(self.telemetry_stride * 2, 64)
            return True
        if action in ("rebalance_microbatches", "rebalance_shards",
                      "rebalance_frontend", "pin_and_coalesce",
                      "batch_launches"):
            return True     # accepted; no-op at single-host smoke scale
        return False

    # ------------------------------------------------------------------
    # request path
    # ------------------------------------------------------------------

    def submit(self, req: ServeRequest) -> None:
        self.sched.submit(req)
        self._emit(EventKind.INGRESS_PKT, flow=req.req_id,
                   size=2 * req.prompt_len, meta=META_DIR_INGRESS)

    def _emit(self, kind: EventKind, **kw) -> None:
        if self.plane is not None:
            self._pending.add(ts=self.clock, kind=kind,
                              node=self.cfg.node, **kw)

    def _flush_telemetry(self) -> None:
        if self.plane is None:
            return
        if len(self._pending):
            batch = self._pending.build(sort=True)
            self._pending.clear()
            self._sink.observe_batch(batch)
        if self.dpu is not None:
            self.dpu.advance(self.clock)

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_jit:
            model = self.model

            def prefill_one(params, tokens, cache):
                return model.prefill(params, tokens, cache)

            self._prefill_jit[bucket] = jax.jit(prefill_one)
        return self._prefill_jit[bucket]

    def _admit_loop(self) -> None:
        while True:
            if not self.sched.queue:
                break
            head = self.sched.queue[0]
            need = head.prompt_len + head.max_new_tokens
            if not self.pool.can_admit(need):
                # paper §5: early KV eviction under pressure
                if self.pool.evict_lru() is None:
                    break
                continue
            got = self.sched.admit(self.clock)
            if got is None:
                break
            slot, req = got
            self.pool.allocate(req.req_id, need)
            self._prefill(slot, req)

    def _prefill(self, slot: int, req: ServeRequest) -> None:
        bucket = self.sched.bucket_len(req.prompt_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, -req.prompt_len:] = req.prompt    # left-pad into bucket
        toks = jnp.asarray(toks)
        self._emit(EventKind.H2D_XFER, device=slot % 4,
                   size=int(toks.size * 4), flow=req.req_id)
        fresh = self.model.init_cache(1, self.cfg.max_seq)
        self._emit(EventKind.DISPATCH, device=slot % 4)
        logits, cache = self._prefill_fn(bucket)(self.params, toks, fresh)
        # first-token logits return to the host (pairs with the dispatch)
        self._emit(EventKind.D2H_XFER, device=slot % 4,
                   size=int(logits.size * 4), flow=req.req_id)
        # write the per-slot cache
        self.slot_cache = jax.tree.map(
            lambda full, one: full.at[slot].set(one[...]),
            self.slot_cache, cache)
        nxt = int(jnp.argmax(logits[0, -1]))
        req.tokens_out = 0
        req.first_token = -1.0
        self._slot_next_token[slot] = nxt
        self.stats["prefills"] += 1

    # ------------------------------------------------------------------
    # decode loop
    # ------------------------------------------------------------------

    _slot_next_token: dict

    def run(self, requests: list[ServeRequest], max_steps: int = 2000,
            step_time: float = 2e-3) -> dict:
        """Drive the engine until all requests finish (or step budget)."""
        self._slot_next_token = {}
        pending = sorted(requests, key=lambda r: r.arrival)
        i = 0
        for step in range(max_steps):
            self.clock += step_time
            while i < len(pending) and pending[i].arrival <= self.clock:
                self.submit(pending[i])
                i += 1
            self._emit(EventKind.QUEUE_SAMPLE,
                       depth=self.sched.queue_depth(),
                       meta=META_DIR_INGRESS)
            self._admit_loop()
            if self.sched.running:
                self._step()
            self._flush_telemetry()
            if i >= len(pending) and not self.sched.running \
                    and not self.sched.queue:
                break
        return self.report()

    def _step(self) -> None:
        slots = sorted(self.sched.running)
        toks = np.zeros((self.cfg.max_slots, 1, 1), np.int32)
        for s in slots:
            toks[s, 0, 0] = self._slot_next_token.get(s, 0)
        self._emit(EventKind.DISPATCH, device=0)
        logits, new_cache = self._decode_vmapped(jnp.asarray(toks),
                                                 self.slot_cache)
        self.slot_cache = new_cache
        self._emit(EventKind.D2H_XFER, device=0,
                   size=len(slots) * 4)
        self.stats["steps"] += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0, -1], axis=-1))
        eg_flow: list[int] = []
        eg_meta: list[int] = []
        for s in slots:
            req = self.sched.running[s]
            if req.first_token < 0:
                req.first_token = self.clock
            req.tokens_out += 1
            self.stats["tokens"] += 1
            self.pool.extend(req.req_id)
            self._slot_next_token[s] = int(nxt[s])
            fin = req.tokens_out >= req.max_new_tokens
            eg_flow.append(req.req_id)
            eg_meta.append(META_FIN if fin else 0)
            if fin:
                self.sched.release(s, self.clock)
                self.pool.free(req.req_id)
                self.completed.append(req)
        # token egress leaves as one columnar append per step (the same
        # bulk path the simulator's producer plane uses)
        if self.plane is not None and eg_flow:
            self._pending.add_columns(
                np.full(len(eg_flow), self.clock), EventKind.EGRESS_PKT,
                node=self.cfg.node,
                flow=np.asarray(eg_flow, np.int64),
                size=8 if not self.kv_compress else 4,
                group=self.cfg.node,
                meta=np.asarray(eg_meta, np.int64))
        # KV occupancy sample (Table 2b) — the low-priority event class the
        # throttle_telemetry actuation strides down
        if self.stats["steps"] % self.telemetry_stride == 0:
            self._emit(EventKind.QUEUE_SAMPLE,
                       depth=int(self.pool.occupancy() * 100),
                       meta=META_KV_OCC)

    # ------------------------------------------------------------------

    def report(self) -> dict:
        self._flush_telemetry()
        lats = sorted(r.latency for r in self.completed)
        ttfts = sorted(r.ttft for r in self.completed)

        def pct(xs, q):
            return xs[min(int(q * len(xs)), len(xs) - 1)] if xs else None
        rep = {
            "completed": len(self.completed),
            "steps": self.stats["steps"],
            "tokens": self.stats["tokens"],
            "tokens_per_step": self.stats["tokens"]
            / max(self.stats["steps"], 1),
            "p50_latency": pct(lats, 0.5),
            "p99_latency": pct(lats, 0.99),
            "p50_ttft": pct(ttfts, 0.5),
            "kv_occupancy": self.pool.occupancy(),
            "evictions": self.pool.stats.evictions,
        }
        if self.plane is not None:
            rep["telemetry"] = self.plane.report()
        return rep
