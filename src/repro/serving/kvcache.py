"""Paged KV-cache accounting (vLLM-style block tables, paper §3.1).

On TPU the device-side decode cache is slot-dense (JetStream-style) — HBM
has no fragmentation problem to page over — so the *pool accounting* is the
part of PagedAttention that transfers (DESIGN.md §2): pages gate admission,
drive eviction, and export the "KV-cache occupancy" signal of Table 2(b).
The Pallas ``paged_attention`` kernel consumes the same block tables when a
physically paged pool is wanted (see kernels/).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class PageStats:
    total_pages: int
    free_pages: int
    seqs: int
    allocated: int = 0
    failed: int = 0
    evictions: int = 0

    @property
    def occupancy(self) -> float:
        return 1.0 - self.free_pages / max(self.total_pages, 1)


class PagedKVPool:
    """Page allocator with per-sequence block tables."""

    def __init__(self, n_pages: int, page_size: int) -> None:
        self.page_size = page_size
        self.n_pages = n_pages
        self._free: list[int] = list(range(n_pages))
        self._tables: dict[int, list[int]] = {}
        self._len: dict[int, int] = {}
        self.stats = PageStats(total_pages=n_pages, free_pages=n_pages,
                               seqs=0)

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= len(self._free)

    def allocate(self, seq_id: int, n_tokens: int) -> list[int] | None:
        need = self.pages_needed(n_tokens)
        if need > len(self._free):
            self.stats.failed += 1
            return None
        pages = [self._free.pop() for _ in range(need)]
        self._tables[seq_id] = pages
        self._len[seq_id] = n_tokens
        self.stats.seqs += 1
        self.stats.allocated += need
        self.stats.free_pages = len(self._free)
        return pages

    def extend(self, seq_id: int, n_tokens: int = 1) -> bool:
        """Grow a sequence; allocates a new page on boundary crossing."""
        cur = self._len[seq_id]
        new = cur + n_tokens
        while self.pages_needed(new) > len(self._tables[seq_id]):
            if not self._free:
                self.stats.failed += 1
                return False
            self._tables[seq_id].append(self._free.pop())
            self.stats.allocated += 1
        self._len[seq_id] = new
        self.stats.free_pages = len(self._free)
        return True

    def free(self, seq_id: int) -> None:
        pages = self._tables.pop(seq_id, [])
        self._len.pop(seq_id, None)
        self._free.extend(pages)
        self.stats.seqs -= 1
        self.stats.free_pages = len(self._free)

    def evict_lru(self) -> int | None:
        """Evict the shortest sequence (stand-in policy) to relieve
        pressure — the paper's 'early KV-cache eviction' mitigation."""
        if not self._tables:
            return None
        victim = min(self._len, key=self._len.__getitem__)
        self.free(victim)
        self.stats.evictions += 1
        return victim

    def table(self, seq_id: int) -> list[int]:
        return self._tables[seq_id]

    def occupancy(self) -> float:
        return self.stats.occupancy
