"""Continuous-batching scheduler with the paper's Table 2(b) signal surface.

Implements the software-side sensing the paper catalogs: request arrival
times, sequence lengths (length bucketing), decode progress, queue depth /
wait time, KV-cache occupancy — and exposes the knobs the mitigation
controller actuates (admission control, batching window, bucketing).
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field


@dataclass
class ServeRequest:
    req_id: int
    arrival: float
    prompt: list[int]
    max_new_tokens: int
    # lifecycle timestamps (Table 2b software record-keeping)
    admitted: float = -1.0
    first_token: float = -1.0
    finished: float = -1.0
    tokens_out: int = 0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival if self.first_token >= 0 \
            else float("inf")

    @property
    def latency(self) -> float:
        return self.finished - self.arrival if self.finished >= 0 \
            else float("inf")


@dataclass
class SchedulerConfig:
    max_slots: int = 8
    prefill_buckets: tuple[int, ...] = (64, 128, 256, 512, 1024)
    batch_window: float = 0.0       # admission smoothing window (seconds)
    admission_paused: bool = False
    continuous: bool = True          # False = static batching (pathological)


class Scheduler:
    """Queue + slot assignment + length bucketing + admission control."""

    def __init__(self, cfg: SchedulerConfig) -> None:
        self.cfg = cfg
        self.queue: list[ServeRequest] = []
        self.running: dict[int, ServeRequest] = {}   # slot -> request
        self.free_slots: list[int] = list(range(cfg.max_slots))
        self.wait_times: list[float] = []
        self._admit_after = 0.0

    # -- signals (Table 2b) -------------------------------------------

    def queue_depth(self) -> int:
        return len(self.queue)

    def decode_progress(self) -> dict[int, int]:
        return {slot: r.tokens_out for slot, r in self.running.items()}

    # -- knobs (mitigation actuation) ----------------------------------

    def pause_admission(self, until: float) -> None:
        self._admit_after = max(self._admit_after, until)

    def set_batch_window(self, window: float) -> None:
        self.cfg.batch_window = window

    def set_continuous(self, on: bool) -> None:
        self.cfg.continuous = on

    # -- scheduling -----------------------------------------------------

    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)

    def bucket_len(self, n: int) -> int:
        bs = self.cfg.prefill_buckets
        i = bisect.bisect_left(bs, n)
        return bs[min(i, len(bs) - 1)]

    def admissible(self, now: float) -> bool:
        if self.cfg.admission_paused or now < self._admit_after:
            return False
        if not self.cfg.continuous and self.running:
            # static batching: a batch may only be FORMED while empty or
            # within the same scheduling tick; once decoding, full drain
            if any(r.admitted < now for r in self.running.values()):
                return False
        return bool(self.queue) and bool(self.free_slots)

    def admit(self, now: float) -> tuple[int, ServeRequest] | None:
        """Assign the longest-waiting request to a slot."""
        if not self.admissible(now):
            return None
        req = self.queue.pop(0)
        slot = self.free_slots.pop(0)
        req.admitted = now
        self.wait_times.append(now - req.arrival)
        self.running[slot] = req
        if self.cfg.batch_window > 0:
            self._admit_after = now + self.cfg.batch_window
        return slot, req

    def release(self, slot: int, now: float) -> ServeRequest:
        req = self.running.pop(slot)
        req.finished = now
        self.free_slots.append(slot)
        self.free_slots.sort()
        return req
