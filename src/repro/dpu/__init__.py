"""DPU-resident control plane — the paper's sidecar, modeled honestly.

Everything the repo previously did in-process (detectors polled inline,
mitigation applied the same instant an attribution appeared) moves behind a
modeled transport and a bounded compute budget here:

  transport  — one-way links with delay, jitter, and loss
  budget     — events/sec ceiling + bounded ingest ring (load shedding)
  policy     — arbitration of concurrent attributions (priority, cooldown,
               flap damping, conflict resolution)
  command    — command bus with RTT, acks, retries, backoff, stale
               invalidation, and liveness pings
  sidecar    — DPUSidecar tying tap -> budget -> detectors -> policy ->
               command bus -> host actuator (plus crash/restart chaos and
               an ingest guard over the batch sequence stream)
  watchdog   — host-side liveness supervision and degraded-mode failover
               when the sidecar itself goes dark; with a hot standby
               attached, promoted to lease arbiter (election) over a
               shadowed tap fan-out (transport.TapFanout)
  election   — leader leases with term numbers over the modeled OOB port,
               plus the fencing registry that rejects stale-term commands
               at the host actuator (split-brain guard)

``sim.cluster.run_scenario(control="dpu")`` runs the full asynchronous
loop; ``control="instant"`` preserves the legacy zero-latency topology for
golden parity.
"""

from repro.dpu.budget import DPUBudget
from repro.dpu.command import PING_ACTION, BusStats, CommandBus
from repro.dpu.election import (
    ElectionArbiter,
    FencedCommand,
    FencingRegistry,
    LeaderLease,
    LeaseParams,
)
from repro.dpu.policy import CONFLICT_GROUPS, Command, PolicyEngine
from repro.dpu.sidecar import DPUParams, DPUSidecar, IngestGuard
from repro.dpu.transport import LinkParams, ModeledLink, TapFanout
from repro.dpu.watchdog import Watchdog, WatchdogParams

__all__ = [
    "BusStats", "CONFLICT_GROUPS", "Command", "CommandBus", "DPUBudget",
    "DPUParams", "DPUSidecar", "ElectionArbiter", "FencedCommand",
    "FencingRegistry", "IngestGuard", "LeaderLease", "LeaseParams",
    "LinkParams", "ModeledLink", "PING_ACTION", "PolicyEngine", "TapFanout",
    "Watchdog", "WatchdogParams",
]
