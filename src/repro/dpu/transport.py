"""Modeled message transport between host and DPU.

The paper places the DPU *on the network path* — telemetry reaches it over
a real link and mitigation commands travel back over the same fabric the
inference traffic shares.  ``ModeledLink`` is that wire: a one-way channel
with configurable base delay, bounded uniform jitter, Bernoulli loss, a
scheduled hard-partition window, and (for chaos experiments) Bernoulli
payload corruption and duplication.

Determinism contract: the link draws from the RNG handed to it *only* when
the corresponding knob is nonzero (jitter -> one uniform per send, drop ->
one uniform per send, corrupt/duplicate -> one uniform each per delivered
send).  A zero-knob link therefore consumes no randomness at all, which
keeps the golden scenario fixtures reproducible and keeps the simulator's
own generator stream untouched.  The partition window is a pure clock
comparison — it never touches the RNG either way.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass


@dataclass(frozen=True)
class LinkParams:
    """One-way channel model."""

    delay: float = 1e-3       # base one-way latency (s)
    jitter: float = 0.0       # extra uniform [0, jitter) latency per message
    drop_p: float = 0.0       # Bernoulli loss probability per message
    # scheduled hard partition: 100% loss for [start, start + duration).
    # start < 0 disables the window entirely (the default).
    partition_start: float = -1.0
    partition_duration: float = 0.0
    corrupt_p: float = 0.0    # Bernoulli payload bit-rot per message
    duplicate_p: float = 0.0  # Bernoulli replay (second copy) per message
    # ordered-stream vs datagram semantics.  True (the default) models a
    # TCP / ordered-RDMA flow: a message never overtakes its predecessor,
    # so a receiver-side sequence anomaly is always real loss or replay.
    # False models idempotent last-writer-wins datagrams (e.g. router-view
    # snapshots), where out-of-order arrival is part of the channel.
    ordered: bool = True


class ModeledLink:
    """Delay/jitter/loss channel with in-order-by-arrival delivery.

    ``send`` timestamps the message with its arrival time (or drops it);
    ``deliver`` pops every message whose arrival time has passed.  A
    monotone sequence number breaks arrival-time ties so delivery order is
    deterministic and messages never compare against each other.  Arrival
    times are clamped monotone per link (ordered-stream semantics): jitter
    spreads deliveries out but never reorders them.

    ``corruptor`` is an optional callable applied to a payload when the
    corruption coin lands — it returns the mangled payload that arrives
    instead (the original is what the sender *thinks* it sent).  Without a
    corruptor the corrupt draw still burns its coin but the payload passes
    through intact, keeping the RNG stream independent of whether the
    receiver models corruption.
    """

    def __init__(self, params: LinkParams, rng, corruptor=None) -> None:
        self.params = params
        self.rng = rng
        self.corruptor = corruptor
        self._seq = itertools.count()
        self._last_arrival = 0.0
        self._inflight: list[tuple[float, int, object]] = []
        self.sent = 0
        self.dropped = 0
        self.delivered = 0
        self.partition_dropped = 0
        self.corrupted = 0
        self.duplicated = 0

    def __len__(self) -> int:
        return len(self._inflight)

    def partitioned(self, now: float) -> bool:
        """True inside the scheduled partition window.  Pure comparison —
        zero RNG draws whether or not a window is configured."""
        p = self.params
        return (p.partition_start >= 0.0
                and p.partition_start <= now
                < p.partition_start + p.partition_duration)

    def send(self, now: float, payload) -> bool:
        """Enqueue one message; returns False if the wire ate it."""
        p = self.params
        self.sent += 1
        if self.partitioned(now):
            self.partition_dropped += 1
            self.dropped += 1
            return False
        if p.drop_p > 0.0 and self.rng.random() < p.drop_p:
            self.dropped += 1
            return False
        arrival = now + p.delay
        if p.jitter > 0.0:
            arrival += self.rng.random() * p.jitter
        # ordered-stream semantics: the channel is one logical flow (TCP /
        # ordered RDMA QP), so a frame never overtakes its predecessor —
        # neither from a jitter coin nor from a sender whose "send clock"
        # regresses (the telemetry tap stamps sends with each batch's
        # newest event timestamp, and producer flushes are not globally
        # time-monotone under load).  Without the clamp the receiver sees
        # frames re-sorted by payload time while sequence numbers follow
        # tap order, and the ingest guard reads every swap as a sequence
        # gap + replay — continuous detector-reset churn instead of the
        # loss signal it is meant to catch.  Pure arithmetic: the RNG
        # stream is untouched either way.
        if p.ordered:
            arrival = max(arrival, self._last_arrival)
            self._last_arrival = arrival
        if p.corrupt_p > 0.0 and self.rng.random() < p.corrupt_p:
            self.corrupted += 1
            if self.corruptor is not None:
                payload = self.corruptor(payload)
        heapq.heappush(self._inflight, (arrival, next(self._seq), payload))
        if p.duplicate_p > 0.0 and self.rng.random() < p.duplicate_p:
            # a replayed copy arrives strictly later than the original
            self.duplicated += 1
            heapq.heappush(self._inflight,
                           (arrival + p.delay, next(self._seq), payload))
        return True

    def deliver(self, now: float) -> list:
        """Pop every message whose arrival time is <= now."""
        out = []
        q = self._inflight
        while q and q[0][0] <= now:
            out.append(heapq.heappop(q)[2])
        self.delivered += len(out)
        return out


class TapFanout:
    """One producer flush delivered to N independent tap consumers.

    Models the redundant management path of a hot-standby DPU pair: the
    host telemetry tap is mirrored, and each sidecar's uplink is its own
    ``ModeledLink`` with an independent delay/jitter/drop/partition
    schedule.  Fan-out happens *before* frame stamping — every consumer
    after the first receives a fresh frame wrapper (``fork``) around the
    same immutable column arrays, so each leg stamps its own monotone
    ``batch_seq`` and checksum (per-link ingest guards) and one leg's
    in-place frame mutation can never corrupt another leg's view.
    """

    def __init__(self, *consumers) -> None:
        if not consumers:
            raise ValueError("TapFanout needs at least one consumer")
        self.consumers = list(consumers)
        self.forked = 0

    @staticmethod
    def fork(batch):
        """New frame wrapper sharing ``batch``'s column arrays.

        The copy starts unstamped (``batch_seq=-1``, no checksum): frame
        identity is a per-link property, payload columns are shared.
        """
        from ..core.events import EventBatch
        return EventBatch(batch.ts, batch.kind, batch.node, batch.device,
                          batch.flow, batch.size, batch.depth, batch.op,
                          batch.group, batch.meta, batch.replica)

    def observe_batch(self, batch) -> None:
        # secondaries get forks first: the primary's observe_batch stamps
        # seq/checksum on the original frame in place
        for consumer in self.consumers[1:]:
            self.forked += 1
            consumer.observe_batch(self.fork(batch))
        self.consumers[0].observe_batch(batch)
