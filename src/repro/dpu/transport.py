"""Modeled message transport between host and DPU.

The paper places the DPU *on the network path* — telemetry reaches it over
a real link and mitigation commands travel back over the same fabric the
inference traffic shares.  ``ModeledLink`` is that wire: a one-way channel
with configurable base delay, bounded uniform jitter, and Bernoulli loss.
Payloads are opaque (EventBatches on the uplink, Commands/acks on the
control channel), so one implementation serves both directions.

Determinism contract: the link draws from the RNG handed to it *only* when
the corresponding knob is nonzero (jitter -> one uniform per send, drop ->
one uniform per send).  A zero-jitter zero-loss link therefore consumes no
randomness at all, which keeps the golden scenario fixtures reproducible
and keeps the simulator's own generator stream untouched.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass


@dataclass(frozen=True)
class LinkParams:
    """One-way channel model."""

    delay: float = 1e-3       # base one-way latency (s)
    jitter: float = 0.0       # extra uniform [0, jitter) latency per message
    drop_p: float = 0.0       # Bernoulli loss probability per message


class ModeledLink:
    """Delay/jitter/loss channel with in-order-by-arrival delivery.

    ``send`` timestamps the message with its arrival time (or drops it);
    ``deliver`` pops every message whose arrival time has passed.  A
    monotone sequence number breaks arrival-time ties so delivery order is
    deterministic and messages never compare against each other.
    """

    def __init__(self, params: LinkParams, rng) -> None:
        self.params = params
        self.rng = rng
        self._seq = itertools.count()
        self._inflight: list[tuple[float, int, object]] = []
        self.sent = 0
        self.dropped = 0
        self.delivered = 0

    def __len__(self) -> int:
        return len(self._inflight)

    def send(self, now: float, payload) -> bool:
        """Enqueue one message; returns False if the wire ate it."""
        p = self.params
        self.sent += 1
        if p.drop_p > 0.0 and self.rng.random() < p.drop_p:
            self.dropped += 1
            return False
        arrival = now + p.delay
        if p.jitter > 0.0:
            arrival += self.rng.random() * p.jitter
        heapq.heappush(self._inflight, (arrival, next(self._seq), payload))
        return True

    def deliver(self, now: float) -> list:
        """Pop every message whose arrival time is <= now."""
        out = []
        q = self._inflight
        while q and q[0][0] <= now:
            out.append(heapq.heappop(q)[2])
        self.delivered += len(out)
        return out
