"""DPUSidecar — the DPU as a first-class asynchronous node.

Composes the whole on-DPU control plane and exposes the same producer-facing
protocol a ``TelemetryPlane`` does, so any event producer (the cluster
simulator, the live serving engine, a ReplicaSet front-end) can be pointed
at a *modeled* DPU instead of an in-process plane:

    host tap --(uplink: delay/jitter/drop/partition)--> ingest guard
      (seq/checksum) --> ingest ring (bounded) --> budget-paced drain
      --> detectors + attribution (TelemetryPlane)
      --> PolicyEngine (arbitration, quarantine) --> CommandBus
      (RTT/acks/backoff retries) --(downlink)--> host actuator

The host drives the loop by calling ``advance(now)`` once per scheduling
round; everything in between is event-time deterministic, so golden
fixtures can pin dpu-mode findings the same way they pin instant-mode ones.

Clock discipline: the detector plane runs on *event time* (batch
timestamps), exactly as in the direct-attach topology — transport delay
shifts *when* the DPU learns about an event, never the event's own
timestamp, so detector math (gap trackers, rate meters) is unchanged.  The
DPU's self-telemetry (ingest-ring occupancy / shed counters, ingest-gap and
command-exhaustion health rows) is stamped with the tap clock — the newest
event timestamp that has arrived — keeping the plane's poll cadence
monotone.

Monitoring-plane chaos (this module's robustness layer):

  crash/restart   — ``crash_at``/``restart_after`` power-cycle the DPU:
                    the ingest ring, detector state, half-confirmed policy
                    decisions, and in-flight commands are lost; the plane's
                    findings/attributions logs (the experiment's record)
                    survive.  A restarted DPU comes back *quarantined*.
  ingest guard    — every tapped batch is stamped with a monotone
                    ``batch_seq`` (and a content checksum when the uplink
                    models corruption); the guard drops replayed/corrupt
                    batches and latches a ``dirty`` flag on sequence gaps
                    that is surfaced as self-telemetry until a host-side
                    ``resync_telemetry`` actuation clears it.
  quarantine      — any fresh ingest gap (blackout end, restart) opens an
                    actuation quarantine on the policy engine: detectors
                    re-warm and re-confirm before any command can fire, so
                    stale pre-gap state never actuates.
  liveness pings  — with ``ping_every > 0`` the bus carries periodic
                    no-op probes; a partitioned command channel exhausts
                    their retries and the exhaustion rate is surfaced as
                    self-telemetry (the ``command_partition`` row's
                    signal), independent of whether the policy engine has
                    anything to say.
  heartbeat       — ``heartbeat_ts`` advances only while the DPU is alive;
                    the host-side ``Watchdog`` reads it out-of-band (the
                    BlueField's dedicated 1GbE management port shares no
                    failure domain with the data-path links).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.detectors import (
    META_DPU_RING,
    META_MON_BUS,
    META_MON_INGEST,
)
from repro.core.events import EventBatch, EventBatchBuilder, EventKind
from repro.core.mitigation import EngineControls
from repro.core.telemetry import TelemetryPlane
from repro.dpu.budget import DPUBudget
from repro.dpu.command import PING_ACTION, CommandBus
from repro.dpu.policy import Command, PolicyEngine
from repro.dpu.transport import LinkParams, ModeledLink


@dataclass(frozen=True)
class DPUParams:
    """Everything that distinguishes a modeled DPU from an in-process tap."""

    uplink: LinkParams = field(default_factory=LinkParams)     # host -> DPU
    downlink: LinkParams = field(default_factory=LinkParams)   # DPU -> host
    events_per_s: float = 2e6        # on-DPU detector compute ceiling
    ring_events: int = 65536         # bounded ingest ring (rows)
    ack_timeout: float = 20e-3
    max_retries: int = 3
    stale_after: float = 0.5         # command older than this is invalid
    ack_backoff: float = 2.0         # retry backoff base (exponential)
    ack_timeout_cap: float = 0.25    # backoff ceiling (s)
    # policy-engine knobs (see repro.dpu.policy for the 0.5 floor rationale)
    min_confidence: float = 0.5
    confirmations: int = 2
    cooldown: float = 5.0
    flap_window: float = 2.0
    flap_limit: int = 2
    flap_backoff: float = 2.0
    quorum: int = 3
    quorum_dwell: float = 1.6
    # monitoring-plane chaos / hardening knobs (all off by default; every
    # pre-existing golden fixture runs with these at their defaults)
    crash_at: float = -1.0           # host-clock time the DPU dies (<0: never)
    restart_after: float = 0.0       # dead time before warm restart (0: stays
                                     # down for the rest of the run)
    # post-gap/post-restart actuation holdoff.  Deliberately shorter than
    # the plane's poll interval (0.25 s): detector state resets at the gap,
    # so the first post-gap poll — the only shot a one-shot (latching)
    # detector gets — lands at gap + poll_interval, after the hold expires.
    # A holdoff >= the poll interval would race that poll by milliseconds
    # and silently swallow one-shot rows after every restart.
    quarantine_s: float = 0.2
    ping_every: float = 0.0          # liveness-probe cadence (0: disabled)


class IngestGuard:
    """Sequence/integrity screen between the uplink and the ingest ring.

    Batches stamped with a monotone ``batch_seq`` are checked for replays
    (seq <= newest seen: dropped), gaps (seq skips ahead: counted, and the
    ``dirty`` flag latches until ``resync()``), and — when the sender
    attached a checksum — content corruption (recomputed digest mismatch:
    dropped).  Unstamped batches pass through untouched, so producers that
    bypass the tap keep working.
    """

    def __init__(self) -> None:
        self.last_seq = -1
        self.gaps = 0            # distinct gap episodes
        self.missing = 0         # total sequence numbers skipped
        self.replays = 0         # duplicates/regressions dropped
        self.corrupt = 0         # checksum-mismatch batches dropped
        self.dirty = False       # latched on gap/corruption until resync()
        self.fresh_gap = False   # set by admit() on a NEW gap; caller clears

    def admit(self, batch: EventBatch) -> bool:
        """True if the batch should enter the ring."""
        if batch.checksum is not None \
                and batch.checksum != batch.content_checksum():
            self.corrupt += 1
            self.dirty = True
            self.fresh_gap = True
            return False
        seq = batch.batch_seq
        if seq < 0:
            return True
        if seq <= self.last_seq:
            self.replays += 1
            return False
        if seq > self.last_seq + 1 and self.last_seq >= 0:
            self.gaps += 1
            self.missing += seq - self.last_seq - 1
            self.dirty = True
            self.fresh_gap = True
        self.last_seq = seq
        return True

    def resync(self) -> None:
        """Host-side resync actuation: the stream is declared whole again."""
        self.dirty = False
        self.fresh_gap = False


class DPUSidecar:
    """Asynchronous feedback loop around one TelemetryPlane."""

    def __init__(self, plane: TelemetryPlane,
                 params: DPUParams | None = None,
                 engine: EngineControls | None = None,
                 seed: int = 0,
                 mitigate: bool = True) -> None:
        self.plane = plane
        if plane.controller is not None:
            # actuation belongs to the policy engine on this topology; the
            # inner plane only detects and attributes
            plane.controller = None
        self.params = p = params or DPUParams()
        self.rng = np.random.default_rng(seed ^ 0xD9B0)
        corruptor = (self._corrupt_batch
                     if p.uplink.corrupt_p > 0.0 else None)
        self.uplink = ModeledLink(p.uplink, self.rng, corruptor=corruptor)
        self.budget = DPUBudget(p.events_per_s, p.ring_events)
        self.guard = IngestGuard()
        self.policy: PolicyEngine | None = None
        self.bus: CommandBus | None = None
        if mitigate:
            self.policy = PolicyEngine(
                min_confidence=p.min_confidence,
                confirmations=p.confirmations, cooldown=p.cooldown,
                flap_window=p.flap_window, flap_limit=p.flap_limit,
                flap_backoff=p.flap_backoff, quorum=p.quorum,
                quorum_dwell=p.quorum_dwell)
        if mitigate or p.ping_every > 0.0:
            # the bus exists whenever something needs the channel: the
            # policy engine's commands, or bare liveness pings
            self.bus = CommandBus(
                engine, self.rng, down=p.downlink, ack=p.downlink,
                ack_timeout=p.ack_timeout, max_retries=p.max_retries,
                stale_after=p.stale_after, ack_backoff=p.ack_backoff,
                ack_timeout_cap=p.ack_timeout_cap,
                on_ack=self.policy.on_ack if self.policy else None,
                on_expired=(self.policy.on_expired if self.policy
                            else None))
        self._att_i = 0               # attributions already arbitrated
        self._shed_seen = 0           # sheds already self-reported
        self._stream_clock = 0.0      # newest event ts forwarded to the plane
        # newest event ts that ARRIVED at the DPU (delivered off the uplink,
        # whether or not the budget has processed it yet).  Self-telemetry
        # is stamped with this clock: a fully starved budget that forwards
        # nothing must still report its own saturation — that is the whole
        # point of the row.
        self._tap_clock = 0.0
        self._sample_builder = EventBatchBuilder()
        # chaos state
        self._batch_seq = 0           # tap-side stamp counter
        self.crashed = False
        self._crash_done = False
        self.crash_dropped = 0        # batches floor-dropped while dead
        self.crash_lost_rows = 0      # ring rows lost at crash
        self.restarts = 0
        self._ping_id = 0             # counts down (policy ids count up)
        self._next_ping = 0.0
        self._acked_seen = 0
        self._exhausted_seen = 0
        self._bus_dirty = False       # latched: exhaustion with no ack since
        self.heartbeat_ts = 0.0       # advances only while alive (OOB port)
        # hot-standby leadership (None on a legacy single-DPU deployment:
        # the sidecar then always arbitrates, exactly the pre-lease paths).
        # While a lease is attached but lapsed, detectors stay warm and
        # fresh attributions accumulate in a bounded recall buffer that is
        # replayed into the policy engine on promotion — that replay is
        # what makes hot failover confirm faster than a replay re-warm.
        self.lease = None
        self.recall_s = 1.3
        self._recent_atts: list = []
        # observability (observe-only; None = disabled)
        self.tracer = None
        self.trace_source = ""

    def attach_tracer(self, tracer, source: str,
                      recorder=None) -> None:
        """Thread one shared Tracer through every stage of this sidecar's
        loop (plane findings/attributions, policy decisions, bus
        lifecycle, crash/restart transitions).  Observe-only."""
        self.tracer = tracer
        self.trace_source = source
        self.plane.tracer = tracer
        self.plane.trace_source = source
        if recorder is not None:
            self.plane.recorder = recorder
        if self.policy is not None:
            self.policy.tracer = tracer
            self.policy.trace_source = source
        if self.bus is not None:
            self.bus.tracer = tracer
            self.bus.trace_source = source

    # -- producer-facing plane protocol -----------------------------------

    def observe_batch(self, batch: EventBatch) -> None:
        """Tap: the host hands a batch to the wire, not to the detectors."""
        n = len(batch)
        if n == 0:
            return
        # wire framing: monotone sequence stamp; content checksum only when
        # the uplink actually models corruption (zero-knob path stays free)
        self._batch_seq += 1
        batch.batch_seq = self._batch_seq
        if self.params.uplink.corrupt_p > 0.0:
            batch.checksum = batch.content_checksum()
        # the tap forwards as soon as the producer flushes: send time is the
        # newest timestamp in the batch (batches are built time-sorted)
        self.uplink.send(float(batch.ts[-1]), batch)

    def observe(self, ev) -> None:
        """Per-event compatibility shim (single-row batch on the wire)."""
        b = EventBatchBuilder()
        b.add(ev.ts, int(ev.kind), ev.node, ev.device, ev.flow, ev.size,
              ev.depth, ev.op, ev.group, ev.meta, ev.replica)
        self.observe_batch(b.build(sort=False))

    @staticmethod
    def _corrupt_batch(batch: EventBatch) -> EventBatch:
        """Wire bit-rot: mangle payload columns but keep the sender's frame
        metadata, so the receiver's recomputed digest disagrees with the
        attached checksum and the guard drops the batch."""
        mangled = EventBatch(batch.ts, batch.kind, batch.node, batch.device,
                             batch.flow,
                             np.bitwise_xor(batch.size, np.int64(0x5A5A)),
                             batch.depth, batch.op, batch.group, batch.meta,
                             batch.replica)
        mangled.batch_seq = batch.batch_seq
        mangled.checksum = batch.checksum
        return mangled

    @property
    def findings(self):
        return self.plane.findings

    @property
    def attributions(self):
        return self.plane.attributions

    @property
    def actions(self):
        return self.plane.actions

    @property
    def stats(self):
        return self.plane.stats

    @property
    def controller(self):
        """Non-None while actuation is live (producers use this to keep
        flushing per round so the loop timing stays honest)."""
        return self.policy

    def bind(self, engine: EngineControls) -> None:
        """Point the command bus at the host actuator."""
        if self.bus is not None:
            self.bus.engine = engine

    # -- host-side actuations routed back at the sidecar -------------------

    def resync(self, now: float) -> None:
        """``resync_telemetry`` actuation: the host re-registered the tap;
        the stream is whole from here.  Ends the ingest-dirty latch (and
        with it the blackout self-telemetry)."""
        self.guard.resync()

    # -- leadership (hot-standby pair) -------------------------------------

    def on_lease_granted(self, now: float) -> None:
        """Delivered lease grant: this sidecar now arbitrates.  The recall
        buffer — attributions observed while shadowing — is replayed as
        policy evidence so confirmation counts pick up where the deposed
        leader's would have been, instead of restarting from zero."""
        if self.policy is None:
            return
        for a in self._recent_atts:
            self.policy.observe(a)
        self._recent_atts.clear()

    def drain_recall(self) -> list:
        """Hand the recall buffer to the caller (the watchdog's demotion
        handover): what this sidecar observed while shadowing, for the new
        leader to re-arbitrate."""
        out = self._recent_atts
        self._recent_atts = []
        return out

    # -- chaos: crash / restart -------------------------------------------

    def _crash(self, now: float) -> None:
        self.crashed = True
        self._crash_done = True
        self.crash_lost_rows += self.budget.crash()
        # detector/attribution/dedup state is DPU DRAM — gone
        self.plane.reset_detector_state()
        if self.policy is not None:
            # half-confirmed decisions, cooldown marks, and flap history
            # are gone too; quarantine_until is re-derived at restart
            self.policy.crash_reset(now)
        if self.bus is not None:
            self.bus.drop_outstanding()
        self._recent_atts.clear()     # recall buffer is DPU DRAM too
        if self.tracer is not None:
            self.tracer.on_transition(
                "dpu_crash", now, self.trace_source,
                lost_rows=self.crash_lost_rows)

    def _restart(self, now: float) -> None:
        self.crashed = False
        self.restarts += 1
        # warm restart rejoins the stream mid-flight: the first admitted
        # batch will show a sequence gap, which (re)opens the quarantine;
        # opening it here too covers the no-traffic edge
        if self.policy is not None:
            self.policy.quarantine(now + self.params.quarantine_s)
        self._next_ping = now
        if self.tracer is not None:
            self.tracer.on_transition("dpu_restart", now, self.trace_source,
                                      restarts=self.restarts)

    # -- the DPU's own cycle ----------------------------------------------

    def advance(self, now: float) -> None:
        """One DPU scheduling quantum, driven by the host clock."""
        p = self.params
        if p.crash_at >= 0.0 and not self._crash_done and now >= p.crash_at:
            self._crash(now)
        if (self.crashed and p.restart_after > 0.0
                and now >= p.crash_at + p.restart_after):
            self._restart(now)
        if self.crashed:
            # the wire still delivers; a dead DPU drops frames on the floor
            self.crash_dropped += len(self.uplink.deliver(now))
            return
        for batch in self.uplink.deliver(now):
            if not self.guard.admit(batch):
                continue
            self._tap_clock = max(self._tap_clock, float(batch.ts[-1]))
            self.budget.offer(batch)
        if self.guard.fresh_gap:
            self.guard.fresh_gap = False
            # the stream is discontinuous: detector baselines straddling the
            # hole would read the resumption itself as a cluster pathology
            # (a 300 ms telemetry gap looks exactly like ingress
            # starvation), so the detectors re-warm from post-gap state and
            # the policy engine holds actuation while they do
            self.plane.reset_detector_state()
            if self.policy is not None:
                self.policy.quarantine(now + p.quarantine_s)
        drained = self.budget.drain(now)
        for batch in drained:
            self._stream_clock = max(self._stream_clock,
                                     float(batch.ts[-1]))
            self.plane.observe_batch(batch)
        if (self.bus is not None and p.ping_every > 0.0
                and now >= self._next_ping):
            self._ping_id -= 1
            self.bus.send(Command(cmd_id=self._ping_id, ts=now,
                                  action=PING_ACTION, node=-1,
                                  row_id="", locus="telemetry_plane"),
                          now)
            self._next_ping = now + p.ping_every
        self._self_telemetry()
        if self.policy is not None:
            atts = self.plane.attributions
            fresh = atts[self._att_i:]
            self._att_i = len(atts)
            if self.lease is None or self.lease.holds(now):
                for a in fresh:
                    self.policy.observe(a)
                for cmd in self.policy.decide(now):
                    self.bus.send(cmd, now)
            else:
                # shadow mode: a sidecar without a valid lease must not
                # arbitrate, but it remembers what it saw so promotion
                # can replay the recent evidence window
                self._recent_atts.extend(fresh)
                horizon = now - self.recall_s
                if self._recent_atts and self._recent_atts[0].ts < horizon:
                    self._recent_atts = [a for a in self._recent_atts
                                         if a.ts >= horizon]
        if self.bus is not None:
            recs = self.bus.advance(now)
            if recs:
                self.plane.actions.extend(recs)
                self.plane.agent.stats.actions += len(recs)
        self.heartbeat_ts = now

    def _self_telemetry(self) -> None:
        """Report DPU health into the plane itself: ring occupancy + shed
        deltas (the ``dpu_saturation`` signal), the latched ingest-gap flag
        (``telemetry_blackout``), and command-retry exhaustion
        (``command_partition``)."""
        if self._tap_clock <= 0.0:
            return                     # nothing has arrived yet; clock unset
        b = self._sample_builder
        emitted = False
        shed_delta = self.budget.events_shed - self._shed_seen
        self._shed_seen = self.budget.events_shed
        b.add(self._tap_clock, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
              shed_delta, int(self.budget.occupancy() * 100), -1, -1,
              META_DPU_RING, -1)
        emitted = True
        if self.guard.dirty:
            # latched until resync_telemetry lands: the detector keeps
            # seeing the condition even though actuation is quarantined
            # for the first part of it
            b.add(self._tap_clock, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
                  self.guard.missing + self.guard.corrupt,
                  self.guard.replays, -1, -1, META_MON_INGEST, -1)
        if self.bus is not None:
            s = self.bus.stats
            # only live acks (pings, applies) clear the latch: a late
            # straggler's stale/superseded/fenced nack closes its retry
            # state but proves nothing about current channel health
            if s.live_acked > self._acked_seen:
                self._bus_dirty = False     # channel demonstrably round-trips
            self._acked_seen = s.live_acked
            if s.exhausted > self._exhausted_seen:
                self._bus_dirty = True
            self._exhausted_seen = s.exhausted
            if self._bus_dirty:
                b.add(self._tap_clock, int(EventKind.QUEUE_SAMPLE), -1, -1,
                      -1, s.exhausted, s.retries, -1, -1, META_MON_BUS, -1)
        if emitted:
            self.plane.observe_batch(b.build(sort=False))
            b.clear()

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        out = {
            "uplink": {"sent": self.uplink.sent,
                       "dropped": self.uplink.dropped,
                       "delivered": self.uplink.delivered,
                       "partition_dropped": self.uplink.partition_dropped,
                       "corrupted": self.uplink.corrupted,
                       "duplicated": self.uplink.duplicated},
            "guard": {"gaps": self.guard.gaps,
                      "missing": self.guard.missing,
                      "replays": self.guard.replays,
                      "corrupt": self.guard.corrupt,
                      "dirty": self.guard.dirty},
            "budget": {"offered": self.budget.events_offered,
                       "accepted": self.budget.events_accepted,
                       "shed": self.budget.events_shed,
                       "processed": self.budget.events_processed,
                       "backlog": self.budget.backlog},
            "chaos": {"crashed": self.crashed,
                      "restarts": self.restarts,
                      "crash_dropped": self.crash_dropped,
                      "crash_lost_rows": self.crash_lost_rows},
        }
        if self.bus is not None:
            s = self.bus.stats
            out["commands"] = {
                "sent": s.sent, "retries": s.retries, "acked": s.acked,
                "applied": s.applied, "rejected": s.rejected,
                "stale_dropped": s.stale_dropped,
                "superseded": s.superseded, "expired": s.expired,
                "exhausted": s.exhausted,
            }
        if self.policy is not None:
            out["policy"] = {"issued": len(self.policy.issued),
                             "suppressed": len(self.policy.suppressed),
                             "quarantined": self.policy.quarantined}
        return out
