"""DPUSidecar — the DPU as a first-class asynchronous node.

Composes the whole on-DPU control plane and exposes the same producer-facing
protocol a ``TelemetryPlane`` does, so any event producer (the cluster
simulator, the live serving engine, a ReplicaSet front-end) can be pointed
at a *modeled* DPU instead of an in-process plane:

    host tap --(uplink: delay/jitter/drop)--> ingest ring (bounded)
      --> budget-paced drain --> detectors + attribution (TelemetryPlane)
      --> PolicyEngine (arbitration) --> CommandBus (RTT/acks/retries)
      --(downlink)--> host actuator (EngineControls.apply_action)

The host drives the loop by calling ``advance(now)`` once per scheduling
round; everything in between is event-time deterministic, so golden
fixtures can pin dpu-mode findings the same way they pin instant-mode ones.

Clock discipline: the detector plane runs on *event time* (batch
timestamps), exactly as in the direct-attach topology — transport delay
shifts *when* the DPU learns about an event, never the event's own
timestamp, so detector math (gap trackers, rate meters) is unchanged.  The
DPU's self-telemetry (ingest-ring occupancy / shed counters, the
``dpu_saturation`` row's signal) is stamped with the stream clock — the
newest event timestamp the plane has seen — keeping the plane's poll
cadence monotone.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.detectors import META_DPU_RING
from repro.core.events import EventBatch, EventBatchBuilder, EventKind
from repro.core.mitigation import EngineControls
from repro.core.telemetry import TelemetryPlane
from repro.dpu.budget import DPUBudget
from repro.dpu.command import CommandBus
from repro.dpu.policy import PolicyEngine
from repro.dpu.transport import LinkParams, ModeledLink


@dataclass(frozen=True)
class DPUParams:
    """Everything that distinguishes a modeled DPU from an in-process tap."""

    uplink: LinkParams = field(default_factory=LinkParams)     # host -> DPU
    downlink: LinkParams = field(default_factory=LinkParams)   # DPU -> host
    events_per_s: float = 2e6        # on-DPU detector compute ceiling
    ring_events: int = 65536         # bounded ingest ring (rows)
    ack_timeout: float = 20e-3
    max_retries: int = 3
    stale_after: float = 0.5         # command older than this is invalid
    # policy-engine knobs (see repro.dpu.policy for the 0.5 floor rationale)
    min_confidence: float = 0.5
    confirmations: int = 2
    cooldown: float = 5.0
    flap_window: float = 2.0
    flap_limit: int = 2
    flap_backoff: float = 2.0
    quorum: int = 3
    quorum_dwell: float = 1.6


class DPUSidecar:
    """Asynchronous feedback loop around one TelemetryPlane."""

    def __init__(self, plane: TelemetryPlane,
                 params: DPUParams | None = None,
                 engine: EngineControls | None = None,
                 seed: int = 0,
                 mitigate: bool = True) -> None:
        self.plane = plane
        if plane.controller is not None:
            # actuation belongs to the policy engine on this topology; the
            # inner plane only detects and attributes
            plane.controller = None
        self.params = p = params or DPUParams()
        self.rng = np.random.default_rng(seed ^ 0xD9B0)
        self.uplink = ModeledLink(p.uplink, self.rng)
        self.budget = DPUBudget(p.events_per_s, p.ring_events)
        self.policy: PolicyEngine | None = None
        self.bus: CommandBus | None = None
        if mitigate:
            self.policy = PolicyEngine(
                min_confidence=p.min_confidence,
                confirmations=p.confirmations, cooldown=p.cooldown,
                flap_window=p.flap_window, flap_limit=p.flap_limit,
                flap_backoff=p.flap_backoff, quorum=p.quorum,
                quorum_dwell=p.quorum_dwell)
            self.bus = CommandBus(
                engine, self.rng, down=p.downlink, ack=p.downlink,
                ack_timeout=p.ack_timeout, max_retries=p.max_retries,
                stale_after=p.stale_after, on_ack=self.policy.on_ack)
        self._att_i = 0               # attributions already arbitrated
        self._shed_seen = 0           # sheds already self-reported
        self._stream_clock = 0.0      # newest event ts forwarded to the plane
        # newest event ts that ARRIVED at the DPU (delivered off the uplink,
        # whether or not the budget has processed it yet).  Self-telemetry
        # is stamped with this clock: a fully starved budget that forwards
        # nothing must still report its own saturation — that is the whole
        # point of the row.
        self._tap_clock = 0.0
        self._sample_builder = EventBatchBuilder()

    # -- producer-facing plane protocol -----------------------------------

    def observe_batch(self, batch: EventBatch) -> None:
        """Tap: the host hands a batch to the wire, not to the detectors."""
        n = len(batch)
        if n == 0:
            return
        # the tap forwards as soon as the producer flushes: send time is the
        # newest timestamp in the batch (batches are built time-sorted)
        self.uplink.send(float(batch.ts[-1]), batch)

    def observe(self, ev) -> None:
        """Per-event compatibility shim (single-row batch on the wire)."""
        b = EventBatchBuilder()
        b.add(ev.ts, int(ev.kind), ev.node, ev.device, ev.flow, ev.size,
              ev.depth, ev.op, ev.group, ev.meta, ev.replica)
        self.observe_batch(b.build(sort=False))

    @property
    def findings(self):
        return self.plane.findings

    @property
    def attributions(self):
        return self.plane.attributions

    @property
    def actions(self):
        return self.plane.actions

    @property
    def stats(self):
        return self.plane.stats

    @property
    def controller(self):
        """Non-None while actuation is live (producers use this to keep
        flushing per round so the loop timing stays honest)."""
        return self.policy

    def bind(self, engine: EngineControls) -> None:
        """Point the command bus at the host actuator."""
        if self.bus is not None:
            self.bus.engine = engine

    # -- the DPU's own cycle ----------------------------------------------

    def advance(self, now: float) -> None:
        """One DPU scheduling quantum, driven by the host clock."""
        for batch in self.uplink.deliver(now):
            self._tap_clock = max(self._tap_clock, float(batch.ts[-1]))
            self.budget.offer(batch)
        drained = self.budget.drain(now)
        for batch in drained:
            self._stream_clock = max(self._stream_clock,
                                     float(batch.ts[-1]))
            self.plane.observe_batch(batch)
        self._self_telemetry()
        if self.policy is None:
            return
        atts = self.plane.attributions
        for a in atts[self._att_i:]:
            self.policy.observe(a)
        self._att_i = len(atts)
        for cmd in self.policy.decide(now):
            self.bus.send(cmd, now)
        recs = self.bus.advance(now)
        if recs:
            self.plane.actions.extend(recs)
            self.plane.agent.stats.actions += len(recs)

    def _self_telemetry(self) -> None:
        """Report ring occupancy + shed deltas into the plane itself —
        the ``dpu_saturation`` row's signal source."""
        if self._tap_clock <= 0.0:
            return                     # nothing has arrived yet; clock unset
        shed_delta = self.budget.events_shed - self._shed_seen
        self._shed_seen = self.budget.events_shed
        b = self._sample_builder
        b.add(self._tap_clock, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
              shed_delta, int(self.budget.occupancy() * 100), -1, -1,
              META_DPU_RING, -1)
        self.plane.observe_batch(b.build(sort=False))
        b.clear()

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        out = {
            "uplink": {"sent": self.uplink.sent,
                       "dropped": self.uplink.dropped,
                       "delivered": self.uplink.delivered},
            "budget": {"offered": self.budget.events_offered,
                       "accepted": self.budget.events_accepted,
                       "shed": self.budget.events_shed,
                       "processed": self.budget.events_processed,
                       "backlog": self.budget.backlog},
        }
        if self.bus is not None:
            s = self.bus.stats
            out["commands"] = {
                "sent": s.sent, "retries": s.retries, "acked": s.acked,
                "applied": s.applied, "rejected": s.rejected,
                "stale_dropped": s.stale_dropped,
                "superseded": s.superseded, "expired": s.expired,
            }
        if self.policy is not None:
            out["policy"] = {"issued": len(self.policy.issued),
                             "suppressed": len(self.policy.suppressed)}
        return out
