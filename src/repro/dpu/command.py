"""Command bus — reliable-ish delivery of mitigation commands to the host.

The downlink half of the control loop: commands cross a ``ModeledLink`` to
the host actuator, the actuation result crosses another link back as an
ack, and the bus supervises the exchange the way a real DPU control agent
must:

  retries             — an unacked command is re-sent on an exponential
                        backoff schedule (``ack_timeout`` doubled per
                        attempt by ``ack_backoff``, capped at
                        ``ack_timeout_cap``) up to ``max_retries`` attempts
                        (each resend re-risks the wire);
  exhaustion          — a command that burns every retry unacked counts in
                        ``BusStats.exhausted`` and fires ``on_expired``;
                        the sidecar surfaces the exhaustion rate as
                        self-telemetry so a partitioned command channel is
                        itself a detectable pathology (``command_partition``
                        row);
  liveness pings      — zero-cost ``PING_ACTION`` commands are acked by the
                        host without touching the actuator, giving the bus
                        an ack stream to measure even when the policy engine
                        is quiet;
  idempotent delivery — a retry that races a slow ack is applied at most
                        once (the host tracks applied cmd ids and re-acks);
  stale invalidation  — a command older than ``stale_after`` at delivery
                        time is discarded unapplied: the evidence that
                        produced it no longer describes the cluster;
  supersession        — if a newer command for the same (action, node) has
                        already been applied, an older straggler is dropped.

Every applied command is recorded as a ``core.mitigation.ActionRecord``
(host-clock timestamped) so closed-loop consumers see one action log
regardless of whether the instant controller or the DPU path produced it.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core.mitigation import ActionRecord, EngineControls
from repro.dpu.policy import Command
from repro.dpu.transport import LinkParams, ModeledLink

#: Liveness probe pseudo-action: acked by the host, never actuated.
PING_ACTION = "__ping__"


@dataclass
class _Outstanding:
    cmd: Command
    attempt: int
    last_sent: float


@dataclass
class BusStats:
    sent: int = 0
    retries: int = 0
    acked: int = 0
    applied: int = 0
    rejected: int = 0            # delivered but actuator returned False
    stale_dropped: int = 0
    superseded: int = 0
    duplicates: int = 0          # retry arrived after the original applied
    expired: int = 0             # gave up (retry exhaustion OR staleness)
    exhausted: int = 0           # subset of expired: burned every retry
    fenced: int = 0              # stale-term command rejected by the actuator
    # acks for *current* exchanges only: pings, applies, duplicate re-acks.
    # A negative ack for a stale/superseded/fenced command closes out its
    # retry state but is NOT channel liveness — a late straggler's nack
    # must not clear an exhaustion latch (see sidecar self-telemetry).
    live_acked: int = 0
    extra: dict = field(default_factory=dict)


class CommandBus:
    """Down/ack link pair + retry supervisor around one host actuator."""

    def __init__(self, engine: EngineControls | None, rng,
                 down: LinkParams | None = None,
                 ack: LinkParams | None = None,
                 ack_timeout: float = 20e-3,
                 max_retries: int = 3,
                 stale_after: float = 0.5,
                 ack_backoff: float = 2.0,
                 ack_timeout_cap: float = 0.25,
                 on_ack=None,
                 on_expired=None) -> None:
        self.engine = engine
        self.down = ModeledLink(down or LinkParams(), rng)
        self.ack = ModeledLink(ack or down or LinkParams(), rng)
        self.ack_timeout = ack_timeout
        self.max_retries = max_retries
        self.stale_after = stale_after
        self.ack_backoff = ack_backoff
        self.ack_timeout_cap = ack_timeout_cap
        self.on_ack = on_ack
        self.on_expired = on_expired
        # hot-standby wiring (set by the watchdog when a standby exists):
        # ``lease`` stamps outgoing commands with the sender's term;
        # ``fencing`` is the shared host-actuator authority that rejects
        # stale-term deliveries.  Both None on a legacy single-DPU bus.
        self.lease = None
        self.fencing = None
        # observability (observe-only; None = disabled)
        self.tracer = None
        self.trace_source = ""
        self._outstanding: dict[int, _Outstanding] = {}
        self._applied_ids: set[int] = set()
        # newest applied command id per (action, node): supersession check
        self._newest_applied: dict[tuple[str, int], int] = {}
        self.stats = BusStats()
        self.log: list[ActionRecord] = []

    # -- DPU side --------------------------------------------------------

    def send(self, cmd: Command, now: float) -> None:
        if self.lease is not None and cmd.term == 0:
            # the term is stamped at send time with whatever the sender
            # currently believes — a deposed-but-alive sidecar keeps
            # stamping its stale term, which is exactly what the host's
            # fencing registry needs to see to reject it
            cmd = replace(cmd, term=self.lease.term)
        self.stats.sent += 1
        self._outstanding[cmd.cmd_id] = _Outstanding(cmd, 1, now)
        if self.tracer is not None:
            self.tracer.on_bus("send", cmd, now, self.trace_source)
        self.down.send(now, cmd)

    def drop_outstanding(self) -> int:
        """DPU crash: the retry supervisor's state is DPU DRAM.  In-flight
        commands are simply forgotten — no expiry accounting, no callbacks
        (the policy engine that issued them is being reset too)."""
        n = len(self._outstanding)
        self._outstanding.clear()
        return n

    # -- pump (called once per host round, both clocks agree on ``now``) --

    def advance(self, now: float) -> list[ActionRecord]:
        """Deliver due commands, process acks, drive retries.

        Returns the ActionRecords applied during this call.
        """
        applied_now: list[ActionRecord] = []
        for cmd in self.down.deliver(now):
            applied_now.extend(self._deliver(cmd, now))
        for cmd, ok, live in self.ack.deliver(now):
            if cmd.cmd_id in self._outstanding:
                del self._outstanding[cmd.cmd_id]
                self.stats.acked += 1
                if live:
                    self.stats.live_acked += 1
                if self.tracer is not None:
                    self.tracer.on_bus("ack", cmd, now, self.trace_source,
                                       ok=ok, live=live)
                if self.on_ack is not None:
                    self.on_ack(cmd, ok)
        self._retry(now)
        return applied_now

    def _deliver(self, cmd: Command, now: float) -> list[ActionRecord]:
        if self.fencing is not None and not self.fencing.admit(cmd, now):
            # stale-term sender: every command — pings included — is
            # rejected at the door, the way a Raft follower nacks any RPC
            # carrying an old term.  The nack is how a deposed leader
            # learns; the FencedCommand record is the split-brain audit
            # trail (split_brain_fenced row).
            self.stats.fenced += 1
            if self.tracer is not None:
                self.tracer.on_bus("fenced", cmd, now, self.trace_source,
                                   fence_term=self.fencing.term)
            self.ack.send(now, (cmd, False, False))
            return []
        if cmd.action == PING_ACTION:
            # liveness probe: ack immediately, never touch the actuator,
            # never log an ActionRecord — its only job is to measure the
            # round trip (or fail to, under partition)
            self.ack.send(now, (cmd, True, True))
            return []
        if cmd.cmd_id in self._applied_ids:
            # retry raced the ack: apply-at-most-once, re-ack
            self.stats.duplicates += 1
            self.ack.send(now, (cmd, True, True))
            return []
        if now - cmd.ts > self.stale_after:
            self.stats.stale_dropped += 1
            if self.tracer is not None:
                self.tracer.on_bus("stale", cmd, now, self.trace_source,
                                   age=now - cmd.ts)
            self.ack.send(now, (cmd, False, False))
            return []
        newest = self._newest_applied.get((cmd.action, cmd.node))
        if newest is not None and newest > cmd.cmd_id:
            self.stats.superseded += 1
            if self.tracer is not None:
                self.tracer.on_bus("superseded", cmd, now,
                                   self.trace_source, newest=newest)
            self.ack.send(now, (cmd, False, False))
            return []
        # actuators that need wall time (e.g. ReplicaSet view refresh) read
        # it from the detail; the command's own ts is its decision time
        detail = {**cmd.detail, "now": now}
        if (self.fencing is not None and cmd.term > 0
                and cmd.term < self.fencing.term):
            # belt-and-braces: admit() already fenced stale terms, so this
            # counter staying zero is the at-most-one-actuator proof the
            # chaos lane asserts
            self.fencing.stale_applied += 1
        if self.tracer is not None:
            # before the actuator runs, so the synchronous apply hook can
            # attribute its decided_ts to this command's issue time
            self.tracer.on_bus("deliver", cmd, now, self.trace_source,
                               attempt_age=now - cmd.ts)
        ok = (self.engine.apply_action(cmd.action, cmd.node, detail)
              if self.engine is not None else False)
        self._applied_ids.add(cmd.cmd_id)
        self._newest_applied[(cmd.action, cmd.node)] = cmd.cmd_id
        self.stats.applied += 1
        if not ok:
            self.stats.rejected += 1
        rec = ActionRecord(ts=now, action=cmd.action, node=cmd.node,
                           row_id=cmd.row_id, locus=cmd.locus, applied=ok,
                           detail=cmd.detail)
        self.log.append(rec)
        self.ack.send(now, (cmd, ok, True))
        return [rec]

    def backoff_delay(self, attempt: int) -> float:
        """Wait before resend number ``attempt + 1`` — exponential in the
        attempts already made, capped so a long partition cannot push the
        next probe past any useful horizon."""
        return min(self.ack_timeout * self.ack_backoff ** (attempt - 1),
                   self.ack_timeout_cap)

    def _retry(self, now: float) -> None:
        for cid in list(self._outstanding):
            st = self._outstanding[cid]
            if now - st.last_sent < self.backoff_delay(st.attempt):
                continue
            if (st.attempt >= self.max_retries
                    or now - st.cmd.ts > self.stale_after):
                del self._outstanding[cid]
                self.stats.expired += 1
                if st.attempt >= self.max_retries:
                    self.stats.exhausted += 1
                if self.tracer is not None:
                    self.tracer.on_bus(
                        "expired", st.cmd, now, self.trace_source,
                        attempts=st.attempt,
                        exhausted=st.attempt >= self.max_retries)
                if self.on_expired is not None:
                    self.on_expired(st.cmd, st.attempt >= self.max_retries)
                continue
            st.attempt += 1
            st.last_sent = now
            self.stats.retries += 1
            if self.tracer is not None:
                self.tracer.on_bus("retry", st.cmd, now, self.trace_source,
                                   attempt=st.attempt)
            self.down.send(now, st.cmd)
