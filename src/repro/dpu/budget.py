"""On-DPU compute/ingest budget — a BlueField is not an infinite sink.

The paper's feasibility argument (§4.4) is that detector math fits a DPU's
ARM cores *at line rate*; this module makes the other side of that claim
executable: when event volume exceeds the budget, the DPU must shed load,
and the shedding itself is a self-diagnosable pathology
(``dpu_saturation`` runbook row).

Two resources are modeled:

  * a processing ceiling (``events_per_s``): each ``drain(now)`` call may
    forward at most ``elapsed * events_per_s`` event rows to the detector
    plane; unprocessed rows stay queued,
  * a bounded ingest ring (``ring_events`` rows): ``offer`` accepts the
    prefix of a batch that fits and sheds the rest — exactly what a
    ring-buffer DMA producer does when the consumer falls behind.

Draining is FIFO and may split a batch (``EventBatch.slice``), so a batch
larger than one interval's budget still makes progress.  All arithmetic is
integer/deterministic; the golden fixtures pin the resulting findings.
"""

from __future__ import annotations

from collections import deque

from repro.core.events import EventBatch


class DPUBudget:
    """Events/sec ceiling + bounded ingest ring with shed accounting."""

    def __init__(self, events_per_s: float = 2e6,
                 ring_events: int = 65536) -> None:
        if events_per_s <= 0 or ring_events < 1:
            raise ValueError("budget must be positive")
        self.events_per_s = float(events_per_s)
        self.ring_events = int(ring_events)
        self._ring: deque[EventBatch] = deque()
        self._head_off = 0            # rows of the head batch already drained
        self.backlog = 0              # rows currently queued
        self.events_offered = 0
        self.events_accepted = 0
        self.events_shed = 0
        self.events_processed = 0
        self._last_drain: float | None = None
        self._credit = 0.0      # fractional capacity carried across drains

    # -- producer side --------------------------------------------------

    def offer(self, batch: EventBatch) -> int:
        """Admit up to the ring's free space; returns rows shed."""
        n = len(batch)
        if n == 0:
            return 0
        self.events_offered += n
        free = self.ring_events - self.backlog
        if free <= 0:
            self.events_shed += n
            return n
        if n > free:
            batch = batch.slice(0, free)
            shed = n - free
            n = free
        else:
            shed = 0
        self._ring.append(batch)
        self.backlog += n
        self.events_accepted += n
        self.events_shed += shed
        return shed

    # -- consumer side --------------------------------------------------

    def drain(self, now: float) -> list[EventBatch]:
        """Forward queued batches up to this interval's processing budget."""
        if self._last_drain is None:
            # first call anchors the clock; capacity accrues from here
            self._last_drain = now
            return []
        elapsed = now - self._last_drain
        self._last_drain = now
        if elapsed <= 0 or not self._ring:
            return []
        # carry fractional capacity across calls: a budget smaller than one
        # row per drain interval must still make progress, and int-floor
        # losses must not leak throughput
        self._credit += elapsed * self.events_per_s
        quota = int(self._credit)
        self._credit -= quota
        out: list[EventBatch] = []
        while quota > 0 and self._ring:
            head = self._ring[0]
            remaining = len(head) - self._head_off
            if remaining <= quota:
                out.append(head.slice(self._head_off, len(head))
                           if self._head_off else head)
                self._ring.popleft()
                self._head_off = 0
                quota -= remaining
                self.backlog -= remaining
                self.events_processed += remaining
            else:
                out.append(head.slice(self._head_off,
                                      self._head_off + quota))
                self._head_off += quota
                self.backlog -= quota
                self.events_processed += quota
                quota = 0
        return out

    def occupancy(self) -> float:
        """Ring fill fraction in [0, 1]."""
        return self.backlog / self.ring_events

    # -- chaos ----------------------------------------------------------

    def crash(self) -> int:
        """Power-loss model: the ring is DPU DRAM — everything queued is
        gone.  Cumulative shed/offer counters survive (they are *our*
        experiment accounting, not DPU state); the drain clock and credit
        reset so a restarted DPU accrues no phantom capacity for the time
        it spent dead.  Returns rows lost."""
        lost = self.backlog
        self._ring.clear()
        self._head_off = 0
        self.backlog = 0
        self.events_shed += lost
        self._last_drain = None
        self._credit = 0.0
        return lost
