"""On-DPU policy engine — arbitration layer between attribution and action.

``core.mitigation.MitigationController`` (retained as the *instant*-mode
reference) maps one attribution to one action with per-key hysteresis.  At
cluster scale the DPU sees *concurrent* attributions — several rows firing
across nodes and replicas within one decision interval — and a command
channel with real latency and loss, so naive per-finding actuation thrashes.
This engine adds the arbitration the controller lacks:

  priority            — critical beats warn, then confidence, then score;
  confirmations       — repeated evidence per (action, node) before
                        actuating (critical short-circuits) — deliberately
                        the controller's exact hysteresis, so instant-mode
                        and dpu-mode decisions differ only by the modeled
                        loop latency on any scenario both can handle;
  quorum escalation   — the same (row, action) reported by >= ``quorum``
                        distinct nodes in one decision round is a cluster
                        incident; it actuates as one cluster-wide command
                        after a ``dwell`` holdoff.  This rescues one-shot
                        rows whose self-calibrating detector fires each
                        node exactly once (per-node hysteresis can never
                        confirm those), and the dwell keeps the escalated
                        path strictly slower than a working per-node one;
  per-action cooldown — an issued (action, node) pair is held down for
                        ``cooldown`` seconds;
  flap damping        — if the same pair keeps re-triggering (fire, clear,
                        fire), its effective cooldown backs off
                        exponentially — an oscillation guard against
                        detector/actuation limit cycles;
  conflict resolution — actions touching the same control surface on the
                        same node (admission knobs, routing knobs, ...) are
                        arbitrated: only the top-priority one is issued per
                        decision round, the rest are recorded as suppressed.

The confidence floor defaults to 0.5 (the controller uses 0.6): the
arbitration and confirmation gates above make weaker single-vantage
attributions safe to act on, which is precisely what lets the DPU path
recover the straggler-default (confidence-0.5) rows the instant controller
ignores.

The engine is transport-agnostic: ``decide`` returns ``Command`` records;
the caller (``DPUSidecar``) hands them to a ``CommandBus``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.attribution import Attribution
from repro.core.mitigation import ACTIONS
from repro.core.runbooks import BY_ID

#: actions that steer the same control surface; issuing two members against
#: one node in one decision round would fight each other
CONFLICT_GROUPS: dict[str, str] = {}
for _group, _members in (
    ("admission", ("smooth_admission", "admission_control",
                   "widen_batch_window", "shrink_batch")),
    ("routing", ("rebalance_frontend", "rebalance_replicas",
                 "rebalance_nodes", "reroute_traffic", "qos_partition",
                 "reroute_rail")),
    ("placement", ("rebalance_shards", "repartition_stages",
                   "rebalance_microbatches", "inflight_remap")),
    ("transport", ("tune_transport", "widen_rdma_window",
                   "enlarge_egress_buffers", "compress_kv")),
):
    for _a in _members:
        CONFLICT_GROUPS[_a] = _group

_SEV_RANK = {"critical": 2, "warn": 1}


@dataclass(frozen=True)
class Command:
    """One mitigation directive bound for a host actuator."""

    cmd_id: int
    ts: float                 # decision time (DPU clock)
    action: str
    node: int
    row_id: str
    locus: str
    # leader-lease term stamped by the CommandBus at send time.  0 marks
    # a legacy/unleased bus; the host actuator fences anything below the
    # currently granted term (see repro.dpu.election.FencingRegistry).
    term: int = 0
    detail: dict = field(default_factory=dict, compare=False)


class PolicyEngine:
    """Attribution arbitration with cooldown, damping, and conflicts."""

    def __init__(self, min_confidence: float = 0.5,
                 confirmations: int = 2,
                 cooldown: float = 5.0,
                 flap_window: float = 2.0,
                 flap_limit: int = 2,
                 flap_backoff: float = 2.0,
                 quorum: int = 3,
                 quorum_dwell: float = 1.6) -> None:
        self.min_confidence = min_confidence
        self.confirmations = confirmations
        self.cooldown = cooldown
        self.flap_window = flap_window
        self.flap_limit = flap_limit
        self.flap_backoff = flap_backoff
        self.quorum = quorum
        self.quorum_dwell = quorum_dwell
        self._staged: list[Attribution] = []
        self._pending: dict[tuple[str, int], int] = {}    # (action, node)
        self._last_issued: dict[tuple[str, int], float] = {}
        self._issue_log: dict[tuple[str, int], list[float]] = {}
        # quorum-escalation state, keyed (row, action).  An issued (or
        # redundant) escalation clears its first-seen mark, so a RECURRING
        # cluster incident re-arms: fresh quorum evidence re-seeds the
        # dwell, and the (action, -1) cooldown spaces the re-issues.
        self._first_seen: dict[tuple[str, str], float] = {}
        self._escalations: dict[tuple[str, str], tuple] = {}  # -> (due, att)
        self._next_id = 0
        self.issued: list[Command] = []
        self.suppressed: list[tuple[str, float, str, int, str]] = []
        # actuation quarantine: while now < quarantine_until every decision
        # is suppressed (recorded), so detectors re-warming after an ingest
        # gap / DPU restart can never fire a command off stale state
        self.quarantine_until = float("-inf")
        self.quarantined = 0
        # observability (observe-only; None = disabled)
        self.tracer = None
        self.trace_source = ""

    # -- chaos / hardening hooks -----------------------------------------

    def quarantine(self, until: float) -> None:
        """Open (or extend) the actuation quarantine window and drop every
        half-confirmed decision: post-gap evidence must re-confirm from
        scratch against the re-warmed detectors."""
        if until > self.quarantine_until:
            self.quarantine_until = until
        self._staged.clear()
        self._pending.clear()
        self._first_seen.clear()
        self._escalations.clear()

    def drain_escalations(self) -> dict:
        """Hand off every armed-but-unfired quorum escalation.  Called by
        the watchdog at demotion: a pending cluster-scoped action is part
        of the *lease* state (like a leadership transfer carrying the
        log), not the controller's confirmation chain — dropping it with
        the deposed controller would lose one-shot quorum evidence the
        incoming leader can never re-observe."""
        out = self._escalations
        self._escalations = {}
        return out

    def adopt_escalations(self, esc: dict, now: float) -> None:
        """Install escalations drained from a deposed controller.  The
        original dwell deadline is preserved (never shortened — the
        holdoff that keeps the escalated path slower than a working
        per-node one must survive the handover), and an escalation this
        engine armed on its own evidence wins over the adopted copy."""
        for ekey, (due, a) in esc.items():
            if ekey not in self._escalations:
                self._escalations[ekey] = (max(due, now), a)

    def on_expired(self, cmd: Command, exhausted: bool) -> None:
        """Bus gave up on a command unacked.  Clear the pair's cooldown
        mark: the action never landed, so holding it down would leave the
        fault unactuated for a full cooldown after the channel heals."""
        self._last_issued.pop((cmd.action, cmd.node), None)

    def crash_reset(self, now: float) -> None:
        """DPU power-cycle: everything in DRAM is lost, including cooldown
        and flap history — a command dropped in flight at crash time must
        not hold its (action, node) pair down after restart.  Re-issuing
        after the restart quarantine is safe: it only happens if the
        re-warmed detectors still see the fault, i.e. the action never
        landed (or did not work).  The ``issued``/``suppressed`` logs are
        the experiment record and survive."""
        self.quarantine(now)
        self._last_issued.clear()
        self._issue_log.clear()

    # -- feeding ---------------------------------------------------------

    def observe(self, attribution: Attribution) -> None:
        """Stage one attribution for the next ``decide`` round."""
        self._staged.append(attribution)

    # -- bookkeeping the bus reports back --------------------------------

    def on_ack(self, cmd: Command, applied: bool) -> None:
        """Host acknowledged a command; nothing to re-arm on failure —
        cooldown ran from issue time, so a rejected action retries
        naturally once fresh evidence confirms again."""
        if applied:
            self._pending[(cmd.action, cmd.node)] = 0

    # -- decision --------------------------------------------------------

    def effective_cooldown(self, key: tuple[str, int], now: float) -> float:
        """Base cooldown, backed off exponentially while the pair flaps."""
        recent = [t for t in self._issue_log.get(key, ())
                  if now - t <= self.flap_window]
        extra = max(0, len(recent) - self.flap_limit + 1)
        return self.cooldown * (self.flap_backoff ** extra)

    def _candidates(self, now: float) -> list[tuple[tuple, Attribution, str]]:
        """Filter + confirm staged attributions into actionable candidates."""
        out = []
        round_nodes: dict[tuple[str, str], tuple[set, Attribution]] = {}
        for a in self._staged:
            entry = BY_ID.get(a.primary.name)
            if entry is None or a.confidence < self.min_confidence:
                continue
            ekey = (entry.row_id, entry.action)
            self._first_seen.setdefault(ekey, now)
            seen = round_nodes.get(ekey)
            if seen is None:
                round_nodes[ekey] = ({a.node}, a)
            else:
                seen[0].add(a.node)
            key = (entry.action, a.node)
            hits = self._pending.get(key, 0) + 1
            self._pending[key] = hits
            needed = 1 if a.primary.severity == "critical" \
                else self.confirmations
            if hits < needed:
                continue
            last = self._last_issued.get(key, float("-inf"))
            if now - last < self.effective_cooldown(key, now):
                self.suppressed.append(
                    ("cooldown", now, entry.action, a.node, entry.row_id))
                continue
            out.append((key, a, entry.action))
        self._staged.clear()
        # quorum check: the same (row, action) on >= quorum distinct nodes
        # within one decision round escalates to a deferred cluster command
        for ekey, (nodes, a) in round_nodes.items():
            if len(nodes) >= self.quorum and ekey not in self._escalations:
                due = max(now, self._first_seen[ekey] + self.quorum_dwell)
                self._escalations[ekey] = (due, a)
        return out

    def _due_escalations(self, now: float) -> list[tuple[tuple, Attribution,
                                                         str]]:
        out = []
        for ekey in list(self._escalations):
            due, a = self._escalations[ekey]
            if now < due:
                continue
            del self._escalations[ekey]
            self._first_seen.pop(ekey, None)    # re-arm on fresh evidence
            row_id, action = ekey
            # a successful per-node issue of the same action within its
            # cooldown makes the escalation redundant
            recent = any(k[0] == action
                         and now - t < self.effective_cooldown(k, now)
                         for k, t in self._last_issued.items())
            if recent:
                self.suppressed.append(
                    ("escalation_redundant", now, action, -1, row_id))
                continue
            out.append(((action, -1), a, action))
        return out

    @staticmethod
    def _priority(a: Attribution) -> tuple:
        return (_SEV_RANK.get(a.primary.severity, 0), a.confidence,
                a.primary.score, -a.ts)

    def decide(self, now: float) -> list[Command]:
        """Arbitrate this round's candidates into at most one command per
        (conflict-group, node)."""
        sup0 = len(self.suppressed)
        cmds = self._decide(now)
        tracer = self.tracer
        if tracer is not None:
            for reason, ts, action, node, row in self.suppressed[sup0:]:
                tracer.on_suppressed(reason, ts, action, node, row,
                                     self.trace_source)
            for cmd in cmds:
                tracer.on_command(cmd, self.trace_source)
        return cmds

    def _decide(self, now: float) -> list[Command]:
        if now < self.quarantine_until:
            for a in self._staged:
                self.suppressed.append(
                    ("quarantine", now,
                     BY_ID[a.primary.name].action
                     if a.primary.name in BY_ID else a.primary.name,
                     a.node, a.primary.name))
                self.quarantined += 1
            self._staged.clear()
            return []
        cands = self._candidates(now) + self._due_escalations(now)
        if not cands:
            return []
        best: dict[tuple[str, int], tuple] = {}
        for key, a, action in cands:
            gkey = (CONFLICT_GROUPS.get(action, action), key[1])
            cur = best.get(gkey)
            if cur is None or self._priority(a) > self._priority(cur[1]):
                if cur is not None:
                    self.suppressed.append(
                        ("conflict", now, cur[2], cur[0][1],
                         cur[1].primary.name))
                best[gkey] = (key, a, action)
            else:
                self.suppressed.append(
                    ("conflict", now, action, key[1], a.primary.name))
        cmds: list[Command] = []
        for key, a, action in best.values():
            f = a.primary
            self._next_id += 1
            cmd = Command(
                cmd_id=self._next_id, ts=now, action=action, node=key[1],
                row_id=f.name, locus=a.locus,
                detail={"row": f.name, "locus": a.locus, "score": f.score,
                        "narrative": a.narrative, **f.evidence})
            self._last_issued[key] = now
            self._issue_log.setdefault(key, []).append(now)
            self._pending[key] = 0
            cmds.append(cmd)
        self.issued.extend(cmds)
        return cmds


# CONFLICT_GROUPS ⊆ ACTIONS (the arbitration layer may only group actions
# the controller registry knows about) is enforced statically by
# repro.lint.wiring.check_wiring — the wiring-action rule — gated in CI
# and in tests/test_runbooks.py, replacing the import-time assert that
# used to live here.
