"""Leader lease + fencing for the standby-DPU hot-failover pair.

Production monitoring planes run *two* BlueField sidecars per node: a
primary that actuates and a standby that shadows the same telemetry tap
(see ``TapFanout`` in :mod:`repro.dpu.transport`).  Exactly one of them
may drive mitigation at any instant.  This module models the control
half of that contract:

* ``LeaderLease`` — one sidecar's local view of its authority: a term
  number plus an expiry instant, both written only by renewal/grant
  messages delivered over the modeled OOB management port.
* ``ElectionArbiter`` — the host-side lease issuer (owned by the
  watchdog, which already speaks the OOB port).  Terms are monotone and
  a new term is granted only once every previously *delivered* lease
  horizon has expired — at-most-one-valid-lease holds by construction,
  not by luck (this is the invariant the property tests hammer).
* ``FencingRegistry`` — the host actuator's view of the current term.
  The ``CommandBus`` stamps every command with the issuing sidecar's
  term and the delivery path rejects (and records) anything older than
  the registry's granted term, so a deposed-but-alive sidecar cannot
  double-actuate even while it still believes it leads.

Determinism contract: nothing in here touches an RNG and nothing reads
a wall clock — every decision is a pure comparison against the caller's
simulated ``now``, so runs with the standby disabled are bit-identical
to the pre-standby code paths.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class LeaseParams:
    """Knobs for the OOB lease protocol.

    ``lease_s`` is deliberately *shorter* than the watchdog's silence
    timeout (0.08 s): renewals are only issued against a heartbeat that
    visibly advanced, so a dead primary's horizon expires before its
    silence even trips — the hot promotion then costs exactly one
    failure-detection latency, the same price the degraded host failover
    pays, instead of detection *plus* a full lease horizon.
    """

    lease_s: float = 0.06    # validity horizon per delivered renewal
    renew_every: float = 0.02  # arbiter renewal cadence (= watchdog probe)
    recall_s: float = 1.3    # attribution recall replayed on promotion


class LeaderLease:
    """One sidecar's locally-held lease (DPU-DRAM state).

    Written only by the arbiter's delivered messages; read by the
    sidecar (``holds``) to gate policy arbitration and by its
    ``CommandBus`` to stamp outgoing command terms.
    """

    def __init__(self, holder: str) -> None:
        self.holder = holder
        self.term = 0
        self.lease_until = float("-inf")
        self.grants = 0

    def holds(self, now: float) -> bool:
        return now < self.lease_until

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"LeaderLease({self.holder!r}, term={self.term}, "
                f"until={self.lease_until:.3f})")


@dataclass(frozen=True)
class FencedCommand:
    """Audit record of one rejected stale-term command."""

    ts: float
    term: int          # stale term the command carried
    granted_term: int  # authority in force at rejection time
    action: str
    node: int
    row_id: str


@dataclass
class FencingRegistry:
    """Host-actuator authority: highest granted term + fencing log.

    Shared by every ``CommandBus`` in the node (primary, standby, host)
    because they all terminate at the same actuator.  ``stale_applied``
    counts commands that reached ``apply`` with an out-of-date term —
    it must stay zero; the chaos lane asserts it.
    """

    term: int = 0
    holder: str = ""
    fenced: list = field(default_factory=list)
    stale_applied: int = 0

    def admit(self, cmd, now: float) -> bool:
        """True if ``cmd``'s term is current.  A stale term is fenced
        and recorded; term 0 marks a legacy/unleased bus and always
        passes (fencing is opt-in per bus)."""
        if cmd.term == 0 or cmd.term >= self.term:
            return True
        self.fenced.append(FencedCommand(
            ts=now, term=cmd.term, granted_term=self.term,
            action=cmd.action, node=cmd.node, row_id=cmd.row_id))
        return False


class ElectionArbiter:
    """Host-side lease issuance over the OOB management port.

    The arbiter tracks, per holder, the newest lease horizon it has ever
    *delivered* (``_horizon``).  Renewals that fail delivery (OOB
    partition) advance nothing, so the holder's horizon freezes exactly
    where its local lease will expire.  ``grant`` refuses to start a new
    term while any other holder's delivered horizon is still in the
    future — two valid leases can therefore never overlap, regardless of
    how heartbeat loss, expiry, and partition windows interleave.
    """

    def __init__(self, params: LeaseParams | None = None) -> None:
        self.p = params or LeaseParams()
        self.registry = FencingRegistry()
        self.leases: dict[str, LeaderLease] = {}
        self._horizon: dict[str, float] = {}
        self.leader: str | None = None
        self.grants = 0
        self.renewals = 0
        self.lost_renewals = 0
        # observability (observe-only; None = disabled)
        self.tracer = None

    def register(self, holder: str) -> LeaderLease:
        lease = self.leases.get(holder)
        if lease is None:
            lease = LeaderLease(holder)
            self.leases[holder] = lease
            self._horizon[holder] = float("-inf")
        return lease

    def holder_valid(self, holder: str, now: float) -> bool:
        lease = self.leases.get(holder)
        return (lease is not None and lease.holds(now)
                and lease.term == self.registry.term)

    def valid_holders(self, now: float) -> list:
        """Holders with a live lease at the current term (<= 1 always)."""
        return [h for h in self.leases if self.holder_valid(h, now)]

    def can_promote(self, holder: str, now: float) -> bool:
        """True when no *other* holder's delivered horizon is still live."""
        return all(now >= hz for h, hz in self._horizon.items()
                   if h != holder)

    def renew(self, now: float, delivered: bool = True) -> bool:
        """Extend the current leader's lease by ``lease_s``.

        ``delivered=False`` models an OOB partition: the arbiter tried,
        but the sidecar-side lease object never learned — its horizon
        stays wherever the last delivered renewal put it.
        """
        if self.leader is None:
            return False
        if not delivered:
            self.lost_renewals += 1
            return False
        lease = self.leases[self.leader]
        lease.term = self.registry.term  # renewals carry the term
        lease.lease_until = now + self.p.lease_s
        self._horizon[self.leader] = max(
            self._horizon[self.leader], lease.lease_until)
        self.renewals += 1
        return True

    def revoke(self, holder: str, now: float) -> None:
        """Delivered demotion notice: the holder's lease ends *now*."""
        lease = self.leases.get(holder)
        if lease is None:
            return
        lease.lease_until = min(lease.lease_until, now)
        self._horizon[holder] = min(self._horizon[holder], now)
        if self.leader == holder:
            self.leader = None
        if self.tracer is not None:
            self.tracer.on_transition("lease_revoke", now, "arbiter",
                                      holder=holder, term=lease.term)

    def grant(self, holder: str, now: float,
              delivered: bool = True) -> int:
        """Promote ``holder`` under a fresh term; returns the term, or 0
        if refused (some other delivered lease could still be valid).

        Granting to the current leader is a renewal, not a new term.
        ``delivered=False`` bumps the host-side authority (the fencing
        registry) without the sidecar learning its new lease — it models
        a grant lost on the OOB wire; the holder stays quiesced until a
        later delivered renewal.
        """
        self.register(holder)
        if self.leader == holder:
            self.renew(now, delivered)
            return self.registry.term
        if not self.can_promote(holder, now):
            return 0
        self.registry.term += 1
        self.registry.holder = holder
        self.leader = holder
        self.grants += 1
        if self.tracer is not None:
            self.tracer.on_transition("lease_grant", now, "arbiter",
                                      holder=holder,
                                      term=self.registry.term,
                                      delivered=delivered)
        lease = self.leases[holder]
        if delivered:
            lease.term = self.registry.term
            lease.lease_until = now + self.p.lease_s
            lease.grants += 1
            self._horizon[holder] = max(
                self._horizon[holder], lease.lease_until)
        return self.registry.term

    def report(self) -> dict:
        return {
            "term": self.registry.term,
            "leader": self.leader,
            "grants": self.grants,
            "renewals": self.renewals,
            "lost_renewals": self.lost_renewals,
            "fenced": len(self.registry.fenced),
            "stale_applied": self.registry.stale_applied,
        }
