"""Host-side watchdog — failover for the monitoring plane itself.

The paper makes the DPU the cluster's nervous system, which makes it a
single point of failure: a crashed DPU (or a partitioned command channel)
leaves every runbook row blind or unactuatable.  ``Watchdog`` is the
host-side answer, modeled after how BlueField deployments actually monitor
their DPUs: the card exposes a dedicated out-of-band 1GbE management port
that shares no failure domain with the data-path links, so the host can
probe DPU liveness (heartbeat cadence, command-bus ack counters) even while
the telemetry uplink or the command downlink is dark.

State machine (single-DPU deployment)::

    NORMAL --(heartbeat silent > silence_timeout,
              or command retries exhaust with zero intervening acks)-->
    FALLBACK --(DPU alive + channel acking for >= failback_hold)--> NORMAL

With a hot standby attached (``standby=`` a second :class:`DPUSidecar`
shadowing the same tap through a :class:`~repro.dpu.transport.TapFanout`),
the watchdog is promoted from "failover to host" to *lease arbiter*
(:class:`~repro.dpu.election.ElectionArbiter` over the same OOB port)::

    NORMAL --(primary dark AND every delivered lease horizon expired
              AND the host-side probe corroborates)--> STANDBY
    NORMAL/STANDBY --(both sidecars dark)--> FALLBACK
    STANDBY --(primary healthy >= failback_hold)--> NORMAL
    FALLBACK --(primary healthy >= failback_hold)--> NORMAL

The standby's detectors are already warm (it shadowed every batch), so a
promotion costs one lease expiry instead of a ``retain_s`` replay, and
the recall buffer it kept while shadowing is replayed into its policy
engine so confirmation counts resume rather than restart.  Split-brain
is fenced, not assumed away: every command carries its issuer's term,
the host actuator rejects stale terms (``split_brain_fenced`` row), and
a new term is only granted once every previously *delivered* lease
horizon has expired — the promotion also requires a host-side
data-path corroboration (ack-channel activity) so a mere OOB partition
with a healthy, actuating primary never elects a second leader.

In FALLBACK the watchdog runs a *degraded* host-side loop: a standby
``TelemetryPlane`` (warmed by replaying the last ``retain_s`` seconds of
tapped batches, then fed live) drives a conservative controller — higher
confidence floor, more confirmations, no cluster-scoped quorum escalation
(the host sees one vantage; cluster-wide actions need the DPU's).  Failback
is hysteretic: the DPU must look healthy for ``failback_hold`` before the
watchdog stands down, and the handover drops half-confirmed policy state so
both controllers never compose a confirmation chain.  The handover back is
also a *state transfer*, in two parts.  First, the returning DPU's plane is
warm-started: its retained tap window is replayed with logging suppressed
(``TelemetryPlane.warm_start``), because a DPU that re-warmed only on
fault-era traffic would calibrate its baselines to the fault — the
pathology reads as normal and rate/peak-latch rows never fire again.
Second, the standby's *evidence* is handed over: attributions observed
during the dark window that the conservative fallback declined to act on
are re-staged through the returning DPU's own arbitration (minus the mon
rows — the DPU's own obituary — and minus anything the fallback already
applied), delivered only once the restart quarantine has expired so a
single-copy handover is never swallowed by a racing hold.

The watchdog wraps a :class:`DPUSidecar` and speaks the same plane
protocol, so ``run_scenario`` can swap it in transparently; its
``findings`` / ``attributions`` / ``actions`` views merge the sidecar's
plane with the standby's (the experiment record spans both).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.detectors import (
    META_MON_BUS,
    META_MON_FENCE,
    META_MON_HEARTBEAT,
    META_MON_RETAIN,
    META_MON_STANDBY,
)
from repro.core.events import EventBatch, EventBatchBuilder, EventKind
from repro.core.mitigation import EngineControls, MitigationController
from repro.core.runbooks import BY_ID, DEFAULT_TABLES
from repro.core.telemetry import TelemetryPlane
from repro.dpu.election import ElectionArbiter, LeaseParams
from repro.dpu.sidecar import DPUSidecar
from repro.dpu.transport import TapFanout


@dataclass(frozen=True)
class WatchdogParams:
    """Host-side liveness supervision + degraded-mode policy knobs."""

    silence_timeout: float = 0.08    # heartbeat silence before failover (s)
    probe_every: float = 0.02        # OOB liveness-probe cadence (s)
    failback_hold: float = 0.2       # healthy time required before failback
    # tapped-batch replay window on failover.  Long enough that the replay
    # usually spans pre-incident traffic: the standby's detectors need a
    # healthy baseline to judge the fault era against, and rate-latch rows
    # (e.g. the HBM cliff) are undetectable from fault-era history alone
    retain_s: float = 1.2
    # hard cap on retained batches: ``retain_s`` alone prunes by payload
    # timestamp, so a producer that flushes faster than its event clock
    # advances (many small batches per simulated second) would grow the
    # window without bound.  The cap bounds watchdog memory outright.
    retain_max: int = 4096
    exhaust_min: int = 3             # ack-less retry exhaustions => failover
    # degraded-mode controller: conservative by construction
    min_confidence: float = 0.7
    confirmations: int = 3
    cooldown: float = 5.0
    # chaos: scheduled partition of the OOB management port to the
    # *primary* sidecar — heartbeat/bus-counter reads and lease renewals
    # all fail inside the window.  Pure clock comparison, zero RNG.
    oob_partition_start: float = -1.0
    oob_partition_s: float = 0.0


class Watchdog:
    """Liveness supervisor + degraded host-side fallback around a sidecar."""

    NORMAL = "normal"
    STANDBY = "standby"            # hot standby sidecar holds the lease
    FALLBACK = "fallback"

    def __init__(self, sidecar: DPUSidecar,
                 params: WatchdogParams | None = None,
                 tables: tuple[str, ...] = DEFAULT_TABLES,
                 mitigate: bool = True,
                 standby: DPUSidecar | None = None,
                 lease: LeaseParams | None = None) -> None:
        self.sidecar = sidecar
        self.params = params or WatchdogParams()
        # the standby plane detects + attributes only; actuation goes
        # through the (gated) fallback controller below
        self.standby = TelemetryPlane(n_nodes=sidecar.plane.n_nodes,
                                      mitigate=False, tables=tables)
        self.fallback: MitigationController | None = None
        if mitigate:
            p = self.params
            self.fallback = MitigationController(
                engine=None, min_confidence=p.min_confidence,
                confirmations=p.confirmations, cooldown=p.cooldown)
        self.state = self.NORMAL
        self.failovers = 0
        self.failbacks = 0
        self.failover_ts = -1.0
        self._retained: list[EventBatch] = []
        # count-cap evictions: batches dropped while still inside the
        # retain_s horizon.  Nonzero means the replay window is silently
        # narrower than configured — exactly the condition the
        # META_MON_RETAIN probe gauge makes observable
        self.retain_evictions = 0
        # observability (observe-only; None = disabled)
        self.tracer = None
        self._next_probe = 0.0
        self._alive_since = -1.0      # first healthy probe after failover
        self._att_i = 0               # standby attributions already consumed
        self._dark_atts = []          # dark-window evidence for the handover
        self._handover = []           # staged evidence awaiting quarantine end
        self._handover_esc = {}       # drained escalations riding the handover
        self._exh_seen = 0            # bus exhaustion watermark (OOB read)
        self._ack_seen = 0
        self._builder = EventBatchBuilder()
        # last heartbeat value actually read over the OOB port: identical
        # to reading live while the port is up; frozen across a partition
        # window so silence accumulates exactly as the host would see it
        self._hb_read = 0.0
        # -- hot-standby pair (all None/inert on a single-DPU deployment,
        # so every pre-standby code path is bit-identical) ----------------
        self.standby_side = standby
        self.arbiter: ElectionArbiter | None = None
        self.fanout: TapFanout | None = None
        self.promotions = 0           # NORMAL -> STANDBY transitions
        self._satt_i = 0              # standby-plane attribution watermark
        self._fence_seen = 0          # fencing-log watermark (probe rows)
        self._host_act_seen = 0       # host-side ack-channel activity
        self._host_act_ts = 0.0
        self._restarts_seen = 0       # primary restarts at promotion time
        self._promote_ts = -1.0
        self._hb_renewed = -1.0       # heartbeat value behind the last renewal
        if standby is not None:
            self.arbiter = ElectionArbiter(lease or LeaseParams())
            self.primary_lease = self.arbiter.register("primary")
            self.standby_lease = self.arbiter.register("standby")
            self.arbiter.register("host")
            recall = self.arbiter.p.recall_s
            for side, side_lease in ((sidecar, self.primary_lease),
                                     (standby, self.standby_lease)):
                side.lease = side_lease
                side.recall_s = recall
                if side.bus is not None:
                    side.bus.lease = side_lease
                    # both buses terminate at the same host actuator: one
                    # shared fencing authority
                    side.bus.fencing = self.arbiter.registry
            self.fanout = TapFanout(sidecar, standby)
            # the primary leads from t=0 under term 1
            self.arbiter.grant("primary", 0.0)

    # -- producer-facing plane protocol -----------------------------------

    def observe_batch(self, batch: EventBatch) -> None:
        n = len(batch)
        if n == 0:
            return
        # retain a replay window so a failover starts warm, not cold
        self._retained.append(batch)
        horizon = float(batch.ts[-1]) - self.params.retain_s
        while self._retained and float(self._retained[0].ts[-1]) < horizon:
            self._retained.pop(0)
        # the time horizon bounds *payload* age, not memory: a tap that
        # flushes many small batches per simulated second can outrun it,
        # so an explicit count cap keeps the window bounded outright
        while len(self._retained) > self.params.retain_max:
            self._retained.pop(0)
            self.retain_evictions += 1
        if self.fanout is not None:
            self.fanout.observe_batch(batch)
        else:
            self.sidecar.observe_batch(batch)
        if self.state == self.FALLBACK:
            self.standby.observe_batch(batch)

    def observe(self, ev) -> None:
        b = EventBatchBuilder()
        b.add(ev.ts, int(ev.kind), ev.node, ev.device, ev.flow, ev.size,
              ev.depth, ev.op, ev.group, ev.meta, ev.replica)
        self.observe_batch(b.build(sort=False))

    @property
    def findings(self):
        merged = self.sidecar.plane.findings + self.standby.findings
        if self.standby_side is not None:
            merged = merged + self.standby_side.plane.findings
        return sorted(merged, key=lambda f: f.ts)

    @property
    def attributions(self):
        merged = (self.sidecar.plane.attributions
                  + self.standby.attributions)
        if self.standby_side is not None:
            merged = merged + self.standby_side.plane.attributions
        return sorted(merged, key=lambda a: a.ts)

    @property
    def actions(self):
        merged = list(self.sidecar.plane.actions)
        if self.standby_side is not None:
            merged.extend(self.standby_side.plane.actions)
        if self.fallback is not None:
            merged.extend(self.fallback.log)
        return sorted(merged, key=lambda r: r.ts)

    @property
    def stats(self):
        return self.sidecar.plane.stats

    @property
    def controller(self):
        return self.sidecar.policy or self.fallback

    def bind(self, engine: EngineControls) -> None:
        self.sidecar.bind(engine)
        if self.standby_side is not None:
            self.standby_side.bind(engine)
        if self.fallback is not None:
            self.fallback.engine = engine

    def attach_tracer(self, tracer, recorder=None) -> None:
        """Thread one shared Tracer through every vantage the watchdog
        supervises.  The flight recorder rides only on the primary
        sidecar's plane (failover replays into the degraded plane are
        historical traffic, not fresh frames).  Observe-only."""
        self.tracer = tracer
        self.sidecar.attach_tracer(tracer, "primary", recorder=recorder)
        if self.standby_side is not None:
            self.standby_side.attach_tracer(tracer, "standby")
        self.standby.tracer = tracer
        self.standby.trace_source = "fallback"
        if self.arbiter is not None:
            self.arbiter.tracer = tracer

    # -- actuations routed back from the host ------------------------------

    def force_failover(self, now: float) -> bool:
        """``failover_controller`` actuation target (idempotent).

        Only a NORMAL-state watchdog actually fails over.  A force landing
        during an already-degraded window (FALLBACK, or a hot standby
        already leading) is a no-op that must NOT reset ``failover_ts``:
        the dark-window evidence staging keys off the *original* failover
        instant, and re-stamping it would silently drop everything the
        fallback observed before the redundant force landed.
        """
        if self.state == self.NORMAL:
            self._failover(now)
            if self.arbiter is not None:
                self.arbiter.revoke("primary", now)
                if self.arbiter.can_promote("host", now):
                    self.arbiter.grant("host", now)
        return True

    def resync(self, now: float) -> None:
        """``resync_telemetry`` passthrough to the sidecar's ingest guard."""
        self.sidecar.resync(now)

    def remirror(self, now: float) -> bool:
        """``remirror_standby`` actuation: replay the retained tap window
        into the lagging standby sidecar and resync its sequence stream,
        catching its detector state back up to the primary's."""
        if self.standby_side is None:
            return False
        sb = self.standby_side
        sb.plane.reset_detector_state()
        sb.plane.warm_start(self._retained)
        sb.guard.resync()
        # the replay came off the host-side retained window, so the
        # standby's view of tap time catches up to what it replayed
        if self._retained:
            sb._tap_clock = max(sb._tap_clock,
                                float(self._retained[-1].ts[-1]))
            sb._stream_clock = max(sb._stream_clock, sb._tap_clock)
        return True

    def fence_stale(self, now: float) -> bool:
        """``fence_stale_controller`` actuation: deliver the currently
        granted term to any deposed-but-alive sidecar so it quiesces, and
        purge its outstanding commands — the fence already rejected what
        arrived; this stops the stale retry stream at its source."""
        if self.arbiter is None:
            return False
        term = self.arbiter.registry.term
        for side in (self.sidecar, self.standby_side):
            if side is None or side.lease is None:
                continue
            if side.lease.term < term:
                # a delivered step-down notice, Raft-style: the deposed
                # sidecar learns the current term (its future pings stop
                # reading as split-brain attempts) but NOT a lease — it
                # stays quiesced until the arbiter grants it one again
                side.lease.term = term
                side.lease.lease_until = min(side.lease.lease_until, now)
                if side.bus is not None:
                    side.bus.drop_outstanding()
        return True

    # -- the supervision loop ----------------------------------------------

    def _oob_dark(self, now: float) -> bool:
        """True inside the scheduled OOB-port partition window (pure clock
        comparison, mirroring ``ModeledLink.partitioned``)."""
        p = self.params
        return (p.oob_partition_start >= 0.0
                and p.oob_partition_start <= now
                < p.oob_partition_start + p.oob_partition_s)

    def _host_probe_alive(self, now: float) -> bool:
        """Corroborating host-side probe, sharing no path with the OOB
        port: the ack channel's send counter advances *host-side* every
        time the actuator answers the primary (pings included), so a
        primary that is actuating is visibly alive from the host's end of
        the wire even when the OOB port is partitioned.  This is the
        second opinion the split-brain guard demands before a promotion."""
        if self.sidecar.crashed:
            return False
        bus = self.sidecar.bus
        if bus is None:
            return False
        activity = bus.ack.sent + bus.stats.applied
        if activity > self._host_act_seen:
            self._host_act_ts = now
        self._host_act_seen = activity
        return now - self._host_act_ts <= self.params.silence_timeout

    def advance(self, now: float) -> None:
        self.sidecar.advance(now)
        if self.standby_side is not None:
            self.standby_side.advance(now)
        self._deliver_handover(now)
        p = self.params
        if now < self._next_probe:
            self._drive_fallback()
            return
        self._next_probe = now + p.probe_every
        # the heartbeat is read over the OOB port: while a partition window
        # is scheduled the last-read value freezes and silence accumulates
        # (with no window configured this is exactly the live read)
        oob_dark = self._oob_dark(now)
        if not oob_dark:
            self._hb_read = self.sidecar.heartbeat_ts
        silence = now - self._hb_read
        silent = silence > p.silence_timeout
        # OOB management-port read of the bus counters: retry exhaustion
        # with zero intervening acks means the command channel is dark even
        # though the DPU itself is alive.  Only *live* acks re-arm the
        # watermark — a late straggler's stale/superseded nack closes out
        # retry state without proving the channel carries current traffic.
        bus = self.sidecar.bus
        bus_dark = False
        if bus is not None and not oob_dark:
            s = bus.stats
            if s.live_acked > self._ack_seen:
                self._exh_seen = s.exhausted   # channel round-trips; re-arm
            elif s.exhausted - self._exh_seen >= p.exhaust_min:
                bus_dark = True
            self._ack_seen = s.live_acked
        if self.arbiter is not None and silent:
            # an OOB-silent primary that the host-side data path can still
            # see actuating is partitioned, not dead: without this
            # corroboration a mere management-port blip would depose a
            # healthy leader (the textbook split-brain opener)
            silent = not self._host_probe_alive(now)
        # probe rows feed the standby plane's mon detectors (heartbeat
        # always; bus health only while it is dark, mirroring the sidecar's
        # own latched emission)
        b = self._builder
        b.add(now, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
              1 if silent else 0, int(silence * 1000), -1, -1,
              META_MON_HEARTBEAT, -1)
        if self._retained:
            # retained-window gauge: occupancy (batches) + payload span
            # (ms).  A span visibly below retain_s (count-cap evictions)
            # is what makes a thin remirror_standby replay *observable*
            # instead of inferred after the fact
            span_ms = int((float(self._retained[-1].ts[-1])
                           - float(self._retained[0].ts[-1])) * 1000.0)
            b.add(now, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
                  len(self._retained), span_ms, -1, -1,
                  META_MON_RETAIN, -1)
        if bus_dark:
            b.add(now, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
                  bus.stats.exhausted, bus.stats.retries, -1, -1,
                  META_MON_BUS, -1)
        if self.standby_side is not None:
            # standby-shadow probe: how far is the standby's detector state
            # behind the primary's?  Clamped at zero — a *primary* falling
            # behind is the outage/blackout rows' business, not this one's
            lag_ms = max(0, int((self.sidecar._tap_clock
                                 - self.standby_side._tap_clock) * 1000.0))
            b.add(now, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
                  lag_ms, 0 if self.standby_side.crashed else 1, -1, -1,
                  META_MON_STANDBY, -1)
            fenced = len(self.arbiter.registry.fenced)
            if fenced > self._fence_seen:
                b.add(now, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
                      fenced - self._fence_seen,
                      self.arbiter.registry.term, -1, -1,
                      META_MON_FENCE, -1)
                self._fence_seen = fenced
        self.standby.observe_batch(b.build(sort=False))
        b.clear()
        healthy = not silent and not bus_dark
        if self.arbiter is None:
            # single-DPU deployment: the PR-7 two-state machine, verbatim
            if self.state == self.NORMAL and not healthy:
                self._failover(now)
            elif self.state == self.FALLBACK:
                if healthy:
                    if self._alive_since < 0:
                        self._alive_since = now
                    elif now - self._alive_since >= p.failback_hold:
                        self._failback(now)
                else:
                    self._alive_since = -1.0
        else:
            self._arbitrate(now, healthy, oob_dark)
        self._drive_fallback()

    def _standby_alive(self, now: float) -> bool:
        sb = self.standby_side
        return (sb is not None and not sb.crashed
                and now - sb.heartbeat_ts <= self.params.silence_timeout)

    def _arbitrate(self, now: float, healthy: bool, oob_dark: bool) -> None:
        """Lease-arbiter state machine (hot standby attached)."""
        p, arb = self.params, self.arbiter
        standby_ok = self._standby_alive(now)
        if self.state == self.NORMAL:
            if healthy:
                if oob_dark:
                    # renewals ride the OOB port; inside a partition window
                    # the arbiter tries and fails — the primary's lease
                    # keeps counting down toward expiry
                    arb.renew(now, delivered=False)
                elif self._hb_read > self._hb_renewed:
                    # renew only against a heartbeat that visibly advanced:
                    # a frozen heartbeat still inside the silence tolerance
                    # must not extend the horizon, or every promotion pays
                    # detection latency PLUS a full lease on top
                    self._hb_renewed = self._hb_read
                    arb.renew(now)
                return
            # primary suspect: stop renewing.  Promotion requires every
            # previously delivered lease horizon to have expired first —
            # the at-most-one-actuator invariant is enforced here, not
            # hoped for
            if not oob_dark:
                # the management port still reaches the primary (dark *bus*,
                # not dark OOB): deliver an explicit demotion instead of
                # waiting out its lease horizon.  A partitioned OOB port
                # cannot deliver the notice, so there the horizon wait is
                # mandatory — that is the split-brain guard.
                arb.revoke("primary", now)
            if not arb.can_promote("standby", now):
                return
            if standby_ok:
                self._promote_standby(now)
            else:
                # both sidecars dark: degraded host mode (PR-7 path), with
                # the host taking the term so zombie commands stay fenced
                self._failover(now)
                arb.grant("host", now)
        elif self.state == self.STANDBY:
            if standby_ok:
                arb.renew(now)
            primary_back = healthy and not oob_dark
            if primary_back:
                if self._alive_since < 0:
                    self._alive_since = now
                elif now - self._alive_since >= p.failback_hold:
                    self._demote_standby(now)
                    return
            else:
                self._alive_since = -1.0
            if not standby_ok and not healthy:
                # dual-dark mid-incident: revoke the (dead) standby's lease
                # and degrade to host mode once its horizon clears
                arb.revoke("standby", now)
                if arb.can_promote("host", now):
                    self._failover(now)
                    arb.grant("host", now)
        elif self.state == self.FALLBACK:
            if healthy and not oob_dark:
                if self._alive_since < 0:
                    self._alive_since = now
                elif now - self._alive_since >= p.failback_hold:
                    self._failback(now)
                    arb.revoke("host", now)
                    arb.grant("primary", now)
            else:
                self._alive_since = -1.0

    def _promote_standby(self, now: float) -> None:
        """Hot failover: the standby's detectors are already warm — the
        promotion costs one lease grant, not a replay re-warm."""
        term = self.arbiter.grant("standby", now)
        if term == 0:
            return
        self.state = self.STANDBY
        self.promotions += 1
        if self.tracer is not None:
            self.tracer.on_transition("promote_standby", now, "watchdog",
                                      term=term)
        self._alive_since = -1.0
        self._promote_ts = now
        self._satt_i = len(self.standby_side.plane.attributions)
        self._restarts_seen = self.sidecar.restarts
        # the demotion handover must reach back past the promotion
        # instant: evidence the standby attributed while still shadowing
        # (e.g. a quorum row's one-shot findings that landed during the
        # primary's death throes) exists nowhere else once the primary's
        # own recall buffer died with it
        self._dark_atts = [
            a for a in self.standby_side.plane.attributions
            if a.ts >= now - self.standby_side.recall_s]
        # replay the recall buffer: confirmation counts resume where the
        # deposed leader's would have been
        self.standby_side.on_lease_granted(now)

    def _demote_standby(self, now: float) -> None:
        """Hysteretic failback from the hot standby to the primary."""
        arb = self.arbiter
        arb.revoke("standby", now)
        term = arb.grant("primary", now)
        if term == 0:
            return
        self.state = self.NORMAL
        self.failbacks += 1
        if self.tracer is not None:
            self.tracer.on_transition("demote_standby", now, "watchdog",
                                      term=term)
        self._alive_since = -1.0
        # a pending quorum escalation is lease state, not confirmation
        # state: its one-shot evidence (e.g. per-node findings that landed
        # during the primary's death throes) can never be re-observed by
        # the incoming leader, so the handover carries it — original dwell
        # deadline intact — instead of letting it die with the deposed
        # controller.  Drained BEFORE the quarantine below can clear it.
        if self.standby_side.policy is not None:
            self._handover_esc.update(
                self.standby_side.policy.drain_escalations())
        policy = self.sidecar.policy
        if policy is not None:
            # drop half-confirmed state at the handover boundary (the two
            # controllers must never compose a confirmation chain) without
            # extending any already-open hold
            policy.quarantine(now)
        if self.sidecar.restarts > self._restarts_seen:
            # the primary restarted during the dark window, so its plane
            # re-warmed on fault-era traffic only: replay the retained tap
            # window for honest baselines (PR-7 failback state transfer).
            # A deposed-but-alive primary skips this — its detector state
            # never went dark
            self.sidecar.plane.reset_detector_state()
            self.sidecar.plane.warm_start(self._retained)
        # evidence handover, both directions of it: what the standby
        # attributed while it led, and what the primary recalled while
        # shadowing — minus mon rows and minus anything already applied.
        # Routed through the deferred-delivery path so a still-open restart
        # quarantine can never swallow the single copy.
        acted = set()
        if self.standby_side.bus is not None:
            acted = {(r.action, r.node)
                     for r in self.standby_side.bus.log
                     if r.applied and r.ts >= self._promote_ts}
        for a in self._dark_atts + self.sidecar.drain_recall():
            entry = BY_ID.get(a.primary.name)
            if entry is None or entry.table == "mon":
                continue
            if (entry.action, a.node) in acted:
                continue
            self._handover.append(a)
        self._dark_atts = []

    def _failover(self, now: float) -> None:
        self.state = self.FALLBACK
        self.failovers += 1
        if self.tracer is not None:
            self.tracer.on_transition(
                "failover", now, "watchdog",
                retained_batches=len(self._retained))
        self.failover_ts = now
        self._alive_since = -1.0
        self._dark_atts = []
        self._handover = []           # stale evidence must not outlive a new outage
        self._handover_esc = {}
        # until now the standby's only traffic was probe rows — to its
        # detectors every node has been silent since t=0.  Re-warm from a
        # clean slate: drop that probe-only history, then replay the
        # retained tap window so baselines span real recent traffic
        self.standby.reset_detector_state()
        for batch in self._retained:
            self.standby.observe_batch(batch)

    def _failback(self, now: float) -> None:
        self.state = self.NORMAL
        self.failbacks += 1
        if self.tracer is not None:
            self.tracer.on_transition("failback", now, "watchdog")
        self._alive_since = -1.0
        # the live tee stops here; without a reset the standby's detectors
        # would read the taper as cluster-wide starvation on the next probe
        self.standby.reset_detector_state()
        # drop half-confirmed policy state at the handover so the two
        # controllers can never compose a confirmation chain across it —
        # but do NOT extend the actuation hold: the restart path already
        # opened its own quarantine, and stacking another full window on
        # top of it would swallow the one shot a latching detector gets
        # at the first post-reset poll
        policy = self.sidecar.policy
        if policy is not None:
            policy.quarantine(now)
        # state transfer: the restarted DPU re-warmed on fault-era traffic,
        # so its baselines think the pathology is normal — rate/peak-latch
        # rows would never fire again.  Replay the supervisor's retained
        # tap window (spans pre-incident traffic) into the returning plane
        # with logging suppressed; the next live poll then detects against
        # honest baselines
        self.sidecar.plane.reset_detector_state()
        self.sidecar.plane.warm_start(self._retained)
        # evidence handover: attributions the standby observed while the
        # DPU was dark are re-staged through the primary's own arbitration
        # — minus the mon rows (the DPU's own obituary; the outage is over
        # by definition of failback) and minus anything the fallback
        # already applied.  Delivery is deferred until the restart
        # quarantine has actually expired: failback and quarantine-end can
        # land microseconds apart, and evidence staged inside the hold is
        # dropped — fatal for a single-copy handover
        acted = set()
        if self.fallback is not None:
            acted = {(r.action, r.node) for r in self.fallback.log
                     if r.applied and r.ts >= self.failover_ts}
        for a in self._dark_atts:
            entry = BY_ID.get(a.primary.name)
            if entry is None or entry.table == "mon":
                continue
            if (entry.action, a.node) in acted:
                continue
            self._handover.append(a)
        self._dark_atts = []

    def _deliver_handover(self, now: float) -> None:
        if not self._handover and not self._handover_esc:
            return
        policy = self.sidecar.policy
        if policy is None or self.state != self.NORMAL:
            self._handover = []
            self._handover_esc = {}
            return
        if now < policy.quarantine_until:
            return
        for a in self._handover:
            policy.observe(a)
        self._handover = []
        if self._handover_esc:
            policy.adopt_escalations(self._handover_esc, now)
            self._handover_esc = {}

    def _drive_fallback(self) -> None:
        """Feed new standby attributions to the degraded controller.  Only
        FALLBACK state actuates the full table set; attributions arriving
        while NORMAL are consumed (watermark) but not acted on — the DPU
        path owns them.  With the lease arbiter attached, mon-table rows
        actuate host-side in *every* state: they are the watchdog's own
        probe-row detections (standby lag, split-brain fencing), and their
        remedies (``remirror_standby``, ``fence_stale_controller``) target
        the watchdog itself — no sidecar can self-actuate them."""
        if self.state == self.STANDBY:
            # evidence the leading standby attributes is staged for the
            # demotion handover, exactly like FALLBACK's dark window
            satts = self.standby_side.plane.attributions
            self._dark_atts.extend(satts[self._satt_i:])
            self._satt_i = len(satts)
        atts = self.standby.attributions
        if self.fallback is None or not atts[self._att_i:]:
            self._att_i = len(atts)
            return
        fresh = atts[self._att_i:]
        self._att_i = len(atts)
        if self.state == self.FALLBACK:
            self._dark_atts.extend(fresh)
            recs = self.fallback.consider_all(fresh)
        elif self.arbiter is not None:
            mon = [a for a in fresh
                   if (e := BY_ID.get(a.primary.name)) is not None
                   and e.table == "mon"]
            if not mon:
                return
            recs = self.fallback.consider_all(mon)
        else:
            return
        if recs:
            self.standby.actions.extend(recs)
            self.standby.agent.stats.actions += len(recs)

    # -- reporting ---------------------------------------------------------

    def report(self) -> dict:
        out = self.sidecar.report()
        out["watchdog"] = {
            "state": self.state,
            "failovers": self.failovers,
            "failbacks": self.failbacks,
            "standby_findings": len(self.standby.findings),
            "fallback_actions": (len(self.fallback.log)
                                 if self.fallback else 0),
            "retained_batches": len(self._retained),
            "retained_span_s": (
                float(self._retained[-1].ts[-1])
                - float(self._retained[0].ts[-1])
                if self._retained else 0.0),
            "retain_evictions": self.retain_evictions,
        }
        if self.arbiter is not None:
            out["watchdog"]["promotions"] = self.promotions
            out["watchdog"]["election"] = self.arbiter.report()
        return out
