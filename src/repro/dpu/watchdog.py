"""Host-side watchdog — failover for the monitoring plane itself.

The paper makes the DPU the cluster's nervous system, which makes it a
single point of failure: a crashed DPU (or a partitioned command channel)
leaves every runbook row blind or unactuatable.  ``Watchdog`` is the
host-side answer, modeled after how BlueField deployments actually monitor
their DPUs: the card exposes a dedicated out-of-band 1GbE management port
that shares no failure domain with the data-path links, so the host can
probe DPU liveness (heartbeat cadence, command-bus ack counters) even while
the telemetry uplink or the command downlink is dark.

State machine::

    NORMAL --(heartbeat silent > silence_timeout,
              or command retries exhaust with zero intervening acks)-->
    FALLBACK --(DPU alive + channel acking for >= failback_hold)--> NORMAL

In FALLBACK the watchdog runs a *degraded* host-side loop: a standby
``TelemetryPlane`` (warmed by replaying the last ``retain_s`` seconds of
tapped batches, then fed live) drives a conservative controller — higher
confidence floor, more confirmations, no cluster-scoped quorum escalation
(the host sees one vantage; cluster-wide actions need the DPU's).  Failback
is hysteretic: the DPU must look healthy for ``failback_hold`` before the
watchdog stands down, and the handover drops half-confirmed policy state so
both controllers never compose a confirmation chain.  The handover back is
also a *state transfer*, in two parts.  First, the returning DPU's plane is
warm-started: its retained tap window is replayed with logging suppressed
(``TelemetryPlane.warm_start``), because a DPU that re-warmed only on
fault-era traffic would calibrate its baselines to the fault — the
pathology reads as normal and rate/peak-latch rows never fire again.
Second, the standby's *evidence* is handed over: attributions observed
during the dark window that the conservative fallback declined to act on
are re-staged through the returning DPU's own arbitration (minus the mon
rows — the DPU's own obituary — and minus anything the fallback already
applied), delivered only once the restart quarantine has expired so a
single-copy handover is never swallowed by a racing hold.

The watchdog wraps a :class:`DPUSidecar` and speaks the same plane
protocol, so ``run_scenario`` can swap it in transparently; its
``findings`` / ``attributions`` / ``actions`` views merge the sidecar's
plane with the standby's (the experiment record spans both).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.detectors import META_MON_BUS, META_MON_HEARTBEAT
from repro.core.events import EventBatch, EventBatchBuilder, EventKind
from repro.core.mitigation import EngineControls, MitigationController
from repro.core.runbooks import BY_ID, DEFAULT_TABLES
from repro.core.telemetry import TelemetryPlane
from repro.dpu.sidecar import DPUSidecar


@dataclass(frozen=True)
class WatchdogParams:
    """Host-side liveness supervision + degraded-mode policy knobs."""

    silence_timeout: float = 0.08    # heartbeat silence before failover (s)
    probe_every: float = 0.02        # OOB liveness-probe cadence (s)
    failback_hold: float = 0.2       # healthy time required before failback
    # tapped-batch replay window on failover.  Long enough that the replay
    # usually spans pre-incident traffic: the standby's detectors need a
    # healthy baseline to judge the fault era against, and rate-latch rows
    # (e.g. the HBM cliff) are undetectable from fault-era history alone
    retain_s: float = 1.2
    exhaust_min: int = 3             # ack-less retry exhaustions => failover
    # degraded-mode controller: conservative by construction
    min_confidence: float = 0.7
    confirmations: int = 3
    cooldown: float = 5.0


class Watchdog:
    """Liveness supervisor + degraded host-side fallback around a sidecar."""

    NORMAL = "normal"
    FALLBACK = "fallback"

    def __init__(self, sidecar: DPUSidecar,
                 params: WatchdogParams | None = None,
                 tables: tuple[str, ...] = DEFAULT_TABLES,
                 mitigate: bool = True) -> None:
        self.sidecar = sidecar
        self.params = params or WatchdogParams()
        # the standby plane detects + attributes only; actuation goes
        # through the (gated) fallback controller below
        self.standby = TelemetryPlane(n_nodes=sidecar.plane.n_nodes,
                                      mitigate=False, tables=tables)
        self.fallback: MitigationController | None = None
        if mitigate:
            p = self.params
            self.fallback = MitigationController(
                engine=None, min_confidence=p.min_confidence,
                confirmations=p.confirmations, cooldown=p.cooldown)
        self.state = self.NORMAL
        self.failovers = 0
        self.failbacks = 0
        self.failover_ts = -1.0
        self._retained: list[EventBatch] = []
        self._next_probe = 0.0
        self._alive_since = -1.0      # first healthy probe after failover
        self._att_i = 0               # standby attributions already consumed
        self._dark_atts = []          # dark-window evidence for the handover
        self._handover = []           # staged evidence awaiting quarantine end
        self._exh_seen = 0            # bus exhaustion watermark (OOB read)
        self._ack_seen = 0
        self._builder = EventBatchBuilder()

    # -- producer-facing plane protocol -----------------------------------

    def observe_batch(self, batch: EventBatch) -> None:
        n = len(batch)
        if n == 0:
            return
        # retain a replay window so a failover starts warm, not cold
        self._retained.append(batch)
        horizon = float(batch.ts[-1]) - self.params.retain_s
        while self._retained and float(self._retained[0].ts[-1]) < horizon:
            self._retained.pop(0)
        self.sidecar.observe_batch(batch)
        if self.state == self.FALLBACK:
            self.standby.observe_batch(batch)

    def observe(self, ev) -> None:
        b = EventBatchBuilder()
        b.add(ev.ts, int(ev.kind), ev.node, ev.device, ev.flow, ev.size,
              ev.depth, ev.op, ev.group, ev.meta, ev.replica)
        self.observe_batch(b.build(sort=False))

    @property
    def findings(self):
        return sorted(self.sidecar.plane.findings + self.standby.findings,
                      key=lambda f: f.ts)

    @property
    def attributions(self):
        return sorted(self.sidecar.plane.attributions
                      + self.standby.attributions, key=lambda a: a.ts)

    @property
    def actions(self):
        merged = list(self.sidecar.plane.actions)
        if self.fallback is not None:
            merged.extend(self.fallback.log)
        return sorted(merged, key=lambda r: r.ts)

    @property
    def stats(self):
        return self.sidecar.plane.stats

    @property
    def controller(self):
        return self.sidecar.policy or self.fallback

    def bind(self, engine: EngineControls) -> None:
        self.sidecar.bind(engine)
        if self.fallback is not None:
            self.fallback.engine = engine

    # -- actuations routed back from the host ------------------------------

    def force_failover(self, now: float) -> bool:
        """``failover_controller`` actuation target (idempotent)."""
        if self.state != self.FALLBACK:
            self._failover(now)
        return True

    def resync(self, now: float) -> None:
        """``resync_telemetry`` passthrough to the sidecar's ingest guard."""
        self.sidecar.resync(now)

    # -- the supervision loop ----------------------------------------------

    def advance(self, now: float) -> None:
        self.sidecar.advance(now)
        self._deliver_handover(now)
        p = self.params
        if now < self._next_probe:
            self._drive_fallback()
            return
        self._next_probe = now + p.probe_every
        silence = now - self.sidecar.heartbeat_ts
        silent = silence > p.silence_timeout
        # OOB management-port read of the bus counters: retry exhaustion
        # with zero intervening acks means the command channel is dark even
        # though the DPU itself is alive
        bus = self.sidecar.bus
        bus_dark = False
        if bus is not None:
            s = bus.stats
            if s.acked > self._ack_seen:
                self._exh_seen = s.exhausted   # channel round-trips; re-arm
            elif s.exhausted - self._exh_seen >= p.exhaust_min:
                bus_dark = True
            self._ack_seen = s.acked
        # probe rows feed the standby plane's mon detectors (heartbeat
        # always; bus health only while it is dark, mirroring the sidecar's
        # own latched emission)
        b = self._builder
        b.add(now, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
              1 if silent else 0, int(silence * 1000), -1, -1,
              META_MON_HEARTBEAT, -1)
        if bus_dark:
            b.add(now, int(EventKind.QUEUE_SAMPLE), -1, -1, -1,
                  bus.stats.exhausted, bus.stats.retries, -1, -1,
                  META_MON_BUS, -1)
        self.standby.observe_batch(b.build(sort=False))
        b.clear()
        healthy = not silent and not bus_dark
        if self.state == self.NORMAL and not healthy:
            self._failover(now)
        elif self.state == self.FALLBACK:
            if healthy:
                if self._alive_since < 0:
                    self._alive_since = now
                elif now - self._alive_since >= p.failback_hold:
                    self._failback(now)
            else:
                self._alive_since = -1.0
        self._drive_fallback()

    def _failover(self, now: float) -> None:
        self.state = self.FALLBACK
        self.failovers += 1
        self.failover_ts = now
        self._alive_since = -1.0
        self._dark_atts = []
        self._handover = []           # stale evidence must not outlive a new outage
        # until now the standby's only traffic was probe rows — to its
        # detectors every node has been silent since t=0.  Re-warm from a
        # clean slate: drop that probe-only history, then replay the
        # retained tap window so baselines span real recent traffic
        self.standby.reset_detector_state()
        for batch in self._retained:
            self.standby.observe_batch(batch)

    def _failback(self, now: float) -> None:
        self.state = self.NORMAL
        self.failbacks += 1
        self._alive_since = -1.0
        # the live tee stops here; without a reset the standby's detectors
        # would read the taper as cluster-wide starvation on the next probe
        self.standby.reset_detector_state()
        # drop half-confirmed policy state at the handover so the two
        # controllers can never compose a confirmation chain across it —
        # but do NOT extend the actuation hold: the restart path already
        # opened its own quarantine, and stacking another full window on
        # top of it would swallow the one shot a latching detector gets
        # at the first post-reset poll
        policy = self.sidecar.policy
        if policy is not None:
            policy.quarantine(now)
        # state transfer: the restarted DPU re-warmed on fault-era traffic,
        # so its baselines think the pathology is normal — rate/peak-latch
        # rows would never fire again.  Replay the supervisor's retained
        # tap window (spans pre-incident traffic) into the returning plane
        # with logging suppressed; the next live poll then detects against
        # honest baselines
        self.sidecar.plane.reset_detector_state()
        self.sidecar.plane.warm_start(self._retained)
        # evidence handover: attributions the standby observed while the
        # DPU was dark are re-staged through the primary's own arbitration
        # — minus the mon rows (the DPU's own obituary; the outage is over
        # by definition of failback) and minus anything the fallback
        # already applied.  Delivery is deferred until the restart
        # quarantine has actually expired: failback and quarantine-end can
        # land microseconds apart, and evidence staged inside the hold is
        # dropped — fatal for a single-copy handover
        acted = set()
        if self.fallback is not None:
            acted = {(r.action, r.node) for r in self.fallback.log
                     if r.applied and r.ts >= self.failover_ts}
        for a in self._dark_atts:
            entry = BY_ID.get(a.primary.name)
            if entry is None or entry.table == "mon":
                continue
            if (entry.action, a.node) in acted:
                continue
            self._handover.append(a)
        self._dark_atts = []

    def _deliver_handover(self, now: float) -> None:
        if not self._handover:
            return
        policy = self.sidecar.policy
        if policy is None or self.state != self.NORMAL:
            self._handover = []
            return
        if now < policy.quarantine_until:
            return
        for a in self._handover:
            policy.observe(a)
        self._handover = []

    def _drive_fallback(self) -> None:
        """Feed new standby attributions to the degraded controller.  Only
        FALLBACK state actuates; attributions arriving while NORMAL are
        consumed (watermark) but not acted on — the DPU path owns them."""
        atts = self.standby.attributions
        if self.fallback is None or not atts[self._att_i:]:
            self._att_i = len(atts)
            return
        fresh = atts[self._att_i:]
        self._att_i = len(atts)
        if self.state != self.FALLBACK:
            return
        self._dark_atts.extend(fresh)
        recs = self.fallback.consider_all(fresh)
        if recs:
            self.standby.actions.extend(recs)
            self.standby.agent.stats.actions += len(recs)

    # -- reporting ---------------------------------------------------------

    def report(self) -> dict:
        out = self.sidecar.report()
        out["watchdog"] = {
            "state": self.state,
            "failovers": self.failovers,
            "failbacks": self.failbacks,
            "standby_findings": len(self.standby.findings),
            "fallback_actions": (len(self.fallback.log)
                                 if self.fallback else 0),
        }
        return out
