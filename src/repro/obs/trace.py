"""Causal control-loop tracing: incidents, spans, and TTM decomposition.

A :class:`Tracer` rides along the closed mitigation loop as a passive
observer.  The first finding a detector emits *opens* an incident (one
trace context per fault episode); every later finding, attribution,
policy decision, bus command/ack/retry/fencing event, watchdog
transition, and actuator application attaches to that open incident.
The apply that flips the fault's ``mitigated`` flag *closes* it.

Because every hook receives a timestamp already flowing through the
loop (batch event time, poll time, or the host round clock — all one
virtual timeline), the tracer needs no clock of its own, draws zero
randomness, and never mutates an event: runs are bit-identical with
tracing on or off.

Time-to-mitigate decomposes into telescoping phases::

    fault_start --t_detect--> detected --t_attribute--> attributed
        --t_decide--> decided --t_bus_rtt--> applied --t_apply-->
        recovered

``decided`` is the issue timestamp of the command that ultimately
recovered the fault, so ``t_bus_rtt`` absorbs queueing, the modeled
down-link, and any retries.  Paths that bypass the bus (instant
control, degraded host fallback) telescope ``decided == applied`` and
report ``t_bus_rtt == 0`` — which is exactly what makes the chaos
lane's hot-vs-degraded gap attributable to named phases.  The phases
always sum to ``recovered - fault_start``, i.e. the existing
``t_recover`` scalar.
"""

from __future__ import annotations

from typing import Any

__all__ = [
    "SpanEvent",
    "Incident",
    "Tracer",
    "validate_report",
    "REPORT_VERSION",
]

REPORT_VERSION = 1

# Phases, in causal order.  Used for span-tree grouping and validation.
PHASES = ("detect", "attribute", "decide", "bus", "apply", "control",
          "recover")

# Hard cap on retained span events per incident so a never-mitigated
# sweep run cannot grow without bound; overflow is counted, not silent.
MAX_EVENTS_PER_INCIDENT = 2048


class SpanEvent:
    """One timestamped occurrence inside an incident's span tree."""

    __slots__ = ("ts", "phase", "name", "source", "detail")

    def __init__(self, ts: float, phase: str, name: str, source: str,
                 detail: dict[str, Any] | None = None) -> None:
        self.ts = ts
        self.phase = phase
        self.name = name
        self.source = source
        self.detail = detail or {}

    def to_dict(self) -> dict[str, Any]:
        return {"ts": round(self.ts, 6), "phase": self.phase,
                "name": self.name, "source": self.source,
                "detail": self.detail}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"SpanEvent({self.ts:.3f}, {self.phase}/{self.name}"
                f" @{self.source})")


class Incident:
    """One fault episode: a trace context plus its span events."""

    __slots__ = (
        "incident_id", "row", "opened_ts", "fault_start", "fault_row",
        "events", "dropped_events", "closed",
        "detected_ts", "attributed_ts", "decided_ts", "applied_ts",
        "recovered_ts", "recover_cmd_id", "recover_action",
        "telemetry_snapshot",
    )

    def __init__(self, incident_id: str, row: str, opened_ts: float,
                 fault_start: float | None, fault_row: str | None) -> None:
        self.incident_id = incident_id
        self.row = row
        self.opened_ts = opened_ts
        self.fault_start = fault_start
        self.fault_row = fault_row
        self.events: list[SpanEvent] = []
        self.dropped_events = 0
        self.closed = False
        # TTM milestones (virtual-clock seconds); None = not reached.
        self.detected_ts: float | None = opened_ts
        self.attributed_ts: float | None = None
        self.decided_ts: float | None = None
        self.applied_ts: float | None = None
        self.recovered_ts: float | None = None
        self.recover_cmd_id: int | None = None
        self.recover_action: str | None = None
        self.telemetry_snapshot: dict[str, Any] | None = None

    # -- recording -------------------------------------------------------

    def add(self, ts: float, phase: str, name: str, source: str,
            detail: dict[str, Any] | None = None) -> None:
        if len(self.events) >= MAX_EVENTS_PER_INCIDENT:
            self.dropped_events += 1
            return
        self.events.append(SpanEvent(ts, phase, name, source, detail))

    # -- TTM decomposition ----------------------------------------------

    def milestones(self) -> dict[str, float | None]:
        return {
            "fault_start": self.fault_start,
            "detected": self.detected_ts,
            "attributed": self.attributed_ts,
            "decided": self.decided_ts,
            "applied": self.applied_ts,
            "recovered": self.recovered_ts,
        }

    def ttm(self) -> dict[str, float | None]:
        """Telescoped phase durations; present phases sum to t_recover.

        Unreached milestones inherit their predecessor (a path that
        skipped the bus contributes 0 to ``t_bus_rtt``, not a gap), so
        whenever ``recovered`` is known the six phases sum *exactly*
        to ``recovered - fault_start``.
        """
        start = self.fault_start
        detected = self.detected_ts
        if start is None or detected is None:
            return {k: None for k in ("t_detect", "t_attribute", "t_decide",
                                      "t_bus_rtt", "t_apply", "t_recover")}
        attributed = self.attributed_ts if self.attributed_ts is not None \
            else detected
        decided = self.decided_ts if self.decided_ts is not None \
            else (self.applied_ts if self.applied_ts is not None
                  else attributed)
        applied = self.applied_ts if self.applied_ts is not None else decided
        recovered = self.recovered_ts
        out: dict[str, float | None] = {
            "t_detect": detected - start,
            "t_attribute": attributed - detected,
            "t_decide": decided - attributed,
            "t_bus_rtt": applied - decided,
            "t_apply": (recovered - applied) if recovered is not None
            else None,
            "t_recover": (recovered - start) if recovered is not None
            else None,
        }
        return out

    # -- export ----------------------------------------------------------

    def span_tree(self) -> dict[str, Any]:
        """Group the flat event list into a per-phase span tree.

        Bus events are further grouped per command id so a retried or
        fenced command reads as one child span with its full lifecycle.
        """
        by_phase: dict[str, list[SpanEvent]] = {p: [] for p in PHASES}
        for ev in self.events:
            by_phase.setdefault(ev.phase, []).append(ev)
        children: list[dict[str, Any]] = []
        for phase in by_phase:
            evs = by_phase[phase]
            if not evs:
                continue
            node: dict[str, Any] = {
                "name": phase,
                "start_ts": round(min(e.ts for e in evs), 6),
                "end_ts": round(max(e.ts for e in evs), 6),
                "events": [],
                "children": [],
            }
            if phase == "bus":
                by_cmd: dict[int, list[SpanEvent]] = {}
                loose: list[SpanEvent] = []
                for e in evs:
                    cid = e.detail.get("cmd_id")
                    if cid is None:
                        loose.append(e)
                    else:
                        by_cmd.setdefault(cid, []).append(e)
                node["events"] = [e.to_dict() for e in loose]
                for cid in sorted(by_cmd):
                    ce = by_cmd[cid]
                    node["children"].append({
                        "name": f"cmd-{cid} "
                                f"{ce[0].detail.get('action', '?')}",
                        "start_ts": round(min(e.ts for e in ce), 6),
                        "end_ts": round(max(e.ts for e in ce), 6),
                        "events": [e.to_dict() for e in ce],
                        "children": [],
                    })
            else:
                node["events"] = [e.to_dict() for e in evs]
            children.append(node)
        return {
            "name": f"incident {self.incident_id} ({self.row})",
            "start_ts": round(self.opened_ts, 6),
            "end_ts": round(self.recovered_ts, 6)
            if self.recovered_ts is not None
            else (round(self.events[-1].ts, 6) if self.events
                  else round(self.opened_ts, 6)),
            "events": [],
            "children": children,
        }

    def to_report(self) -> dict[str, Any]:
        return {
            "version": REPORT_VERSION,
            "incident_id": self.incident_id,
            "row": self.row,
            "fault_row": self.fault_row,
            "opened_ts": round(self.opened_ts, 6),
            "fault_start": self.fault_start,
            "closed": self.closed,
            "recover_action": self.recover_action,
            "milestones": {
                k: (round(v, 6) if v is not None else None)
                for k, v in self.milestones().items()
            },
            "ttm": {
                k: (round(v, 6) if v is not None else None)
                for k, v in self.ttm().items()
            },
            "timeline": [e.to_dict() for e in self.events],
            "dropped_events": self.dropped_events,
            "span_tree": self.span_tree(),
            "telemetry": self.telemetry_snapshot,
        }


class Tracer:
    """Passive observer threaded through plane, policy, bus, and host.

    Components hold a ``tracer`` attribute (``None`` by default); every
    hook site is guarded by ``if self.tracer is not None`` so the
    disabled path costs one attribute load.  All hooks are observe-only.
    """

    def __init__(self, fault_start: float | None = None,
                 fault_row: str | None = None,
                 recorder: Any = None) -> None:
        self.fault_start = fault_start
        self.fault_row = fault_row
        self.recorder = recorder
        self.incidents: list[Incident] = []
        self._current: Incident | None = None
        # cmd_id -> (issue_ts, action, node, incident) for correlating
        # bus lifecycle events back to the incident that caused them.
        self._cmds: dict[int, tuple[float, str, int, Incident]] = {}
        # Last bus delivery, so the synchronous apply that follows can
        # attribute its decided_ts to the command's issue time.
        self._last_deliver: tuple[int, str, int, float] | None = None
        # Control-plane events with no open incident (e.g. a chaos
        # schedule crashing the DPU before any finding) land here.
        self.orphan_events: list[SpanEvent] = []
        self.counters: dict[str, Any] = {
            "findings": 0,
            "findings_by_row": {},
            "attributions": 0,
            "commands": 0,
            "suppressed": 0,
            "bus_send": 0,
            "bus_retry": 0,
            "bus_deliver": 0,
            "bus_ack": 0,
            "bus_fenced": 0,
            "bus_stale": 0,
            "bus_expired": 0,
            "applies": 0,
            "failovers": 0,
            "failbacks": 0,
            "promotions": 0,
            "demotions": 0,
            "crashes": 0,
            "lease_grants": 0,
        }

    # -- incident lifecycle ---------------------------------------------

    @property
    def current(self) -> Incident | None:
        return self._current

    def _open(self, row: str, ts: float) -> Incident:
        inc = Incident(
            incident_id=f"inc-{len(self.incidents):03d}",
            row=row, opened_ts=ts,
            fault_start=self.fault_start, fault_row=self.fault_row)
        if self.recorder is not None:
            inc.telemetry_snapshot = self.recorder.snapshot(ts)
        self.incidents.append(inc)
        self._current = inc
        return inc

    # -- hooks: detection / attribution ---------------------------------

    def on_finding(self, f: Any, source: str = "") -> None:
        c = self.counters
        c["findings"] += 1
        c["findings_by_row"][f.name] = \
            c["findings_by_row"].get(f.name, 0) + 1
        inc = self._current
        if inc is None:
            inc = self._open(f.name, f.ts)
        inc.add(f.ts, "detect", f.name, source,
                {"node": f.node, "severity": f.severity,
                 "score": round(f.score, 4)})

    def on_attribution(self, a: Any, source: str = "") -> None:
        self.counters["attributions"] += 1
        inc = self._current
        if inc is None:
            return
        if inc.attributed_ts is None:
            inc.attributed_ts = a.ts
        inc.add(a.ts, "attribute", a.locus, source,
                {"node": a.node, "confidence": a.confidence,
                 "primary": a.primary.name})

    # -- hooks: policy ---------------------------------------------------

    def on_command(self, cmd: Any, source: str = "") -> None:
        self.counters["commands"] += 1
        inc = self._current
        if inc is None:
            return
        self._cmds[cmd.cmd_id] = (cmd.ts, cmd.action, cmd.node, inc)
        inc.add(cmd.ts, "decide", cmd.action, source,
                {"cmd_id": cmd.cmd_id, "node": cmd.node,
                 "row": cmd.row_id, "term": cmd.term})

    def on_suppressed(self, reason: str, now: float, action: str,
                      node: int, row: str, source: str = "") -> None:
        self.counters["suppressed"] += 1
        inc = self._current
        if inc is None:
            return
        inc.add(now, "decide", f"suppressed:{reason}", source,
                {"action": action, "node": node, "row": row})

    # -- hooks: command bus ---------------------------------------------

    def on_bus(self, event: str, cmd: Any, now: float, source: str = "",
               **detail: Any) -> None:
        if cmd.cmd_id < 0:  # liveness pings are not causal traffic
            return
        key = "bus_" + event
        if key in self.counters:
            self.counters[key] += 1
        entry = self._cmds.get(cmd.cmd_id)
        inc = entry[3] if entry is not None else self._current
        if event == "deliver":
            self._last_deliver = (cmd.cmd_id, cmd.action, cmd.node, now)
        if inc is None:
            return
        d: dict[str, Any] = {"cmd_id": cmd.cmd_id, "action": cmd.action,
                             "node": cmd.node, "term": cmd.term}
        d.update(detail)
        inc.add(now, "bus", event, source, d)

    # -- hooks: actuator -------------------------------------------------

    def on_apply(self, action: str, node: int, now: float,
                 matched: bool, newly_recovered: bool,
                 source: str = "host") -> None:
        self.counters["applies"] += 1
        inc = self._current
        if inc is None:
            return
        inc.add(now, "apply", action, source,
                {"node": node, "matched": matched})
        if not newly_recovered:
            return
        inc.applied_ts = now
        inc.recovered_ts = now
        inc.recover_action = action
        ld = self._last_deliver
        if ld is not None and ld[1] == action and ld[2] == node \
                and ld[3] == now:
            inc.recover_cmd_id = ld[0]
            entry = self._cmds.get(ld[0])
            if entry is not None:
                inc.decided_ts = entry[0]
        inc.add(now, "recover", "mitigated", source,
                {"action": action, "node": node,
                 "cmd_id": inc.recover_cmd_id})
        inc.closed = True
        self._current = None

    # -- hooks: control-plane transitions -------------------------------

    def on_transition(self, name: str, now: float, source: str = "",
                      **detail: Any) -> None:
        key = {"failover": "failovers", "failback": "failbacks",
               "promote_standby": "promotions",
               "demote_standby": "demotions",
               "dpu_crash": "crashes", "dpu_restart": "crashes",
               "lease_grant": "lease_grants"}.get(name)
        if key is not None and name != "dpu_restart":
            self.counters[key] += 1
        inc = self._current
        if inc is not None:
            inc.add(now, "control", name, source, dict(detail))
        elif len(self.orphan_events) < MAX_EVENTS_PER_INCIDENT:
            self.orphan_events.append(
                SpanEvent(now, "control", name, source, dict(detail)))

    # -- export ----------------------------------------------------------

    def reports(self) -> list[dict[str, Any]]:
        return [inc.to_report() for inc in self.incidents]


# -- incident report schema ---------------------------------------------

_REQUIRED: dict[str, type | tuple[type, ...]] = {
    "version": int,
    "incident_id": str,
    "row": str,
    "opened_ts": (int, float),
    "closed": bool,
    "milestones": dict,
    "ttm": dict,
    "timeline": list,
    "span_tree": dict,
}

_TTM_KEYS = ("t_detect", "t_attribute", "t_decide", "t_bus_rtt",
             "t_apply", "t_recover")


def validate_report(report: Any) -> list[str]:
    """Structural check of an incident report; returns a list of
    problems (empty == valid).  Hand-rolled so the repo needs no
    jsonschema dependency."""
    errs: list[str] = []
    if not isinstance(report, dict):
        return ["report is not a dict"]
    for key, typ in _REQUIRED.items():
        if key not in report:
            errs.append(f"missing key: {key}")
        elif not isinstance(report[key], typ):
            errs.append(f"bad type for {key}: {type(report[key]).__name__}")
    if errs:
        return errs
    if report["version"] != REPORT_VERSION:
        errs.append(f"unknown report version {report['version']}")
    for k in _TTM_KEYS:
        if k not in report["ttm"]:
            errs.append(f"ttm missing {k}")
        elif report["ttm"][k] is not None \
                and not isinstance(report["ttm"][k], (int, float)):
            errs.append(f"ttm[{k}] not numeric")
    for i, ev in enumerate(report["timeline"]):
        if not isinstance(ev, dict):
            errs.append(f"timeline[{i}] not a dict")
            continue
        for k in ("ts", "phase", "name", "source"):
            if k not in ev:
                errs.append(f"timeline[{i}] missing {k}")
        if "phase" in ev and ev["phase"] not in PHASES:
            errs.append(f"timeline[{i}] unknown phase {ev['phase']!r}")
    tree = report["span_tree"]
    for k in ("name", "children"):
        if k not in tree:
            errs.append(f"span_tree missing {k}")
    ttm = report["ttm"]
    if ttm.get("t_recover") is not None:
        phases = [ttm.get(k) for k in _TTM_KEYS[:-1]]
        if any(not isinstance(p, (int, float)) for p in phases):
            errs.append("ttm has t_recover but a phase is missing")
        else:
            total = sum(phases)
            # tolerance absorbs per-phase 1e-6 export rounding only
            if abs(total - ttm["t_recover"]) > 1e-4:
                errs.append(
                    f"ttm phases sum {total:.6f} != t_recover "
                    f"{ttm['t_recover']:.6f}")
    return errs
