"""Incident flight recorder: a bounded ring of recent telemetry frames.

The recorder rides on one :class:`~repro.core.telemetry.TelemetryPlane`
(the primary sidecar's, so degraded-mode replay floods never pollute
it) and keeps *references* to the last ``max_frames`` delivered
``EventBatch`` objects — batches are freshly built per tap flush and
never mutated downstream, so holding them is O(1) per frame with no
copying.  When an incident opens, :meth:`snapshot` freezes a compact
summary of the window: per-frame shape, and every ``META_*``
self-telemetry row (queue samples with ``meta >= META_KV_OCC``) so the
report shows what the plane knew about *itself* in the seconds before
the fault was detected.
"""

from __future__ import annotations

from collections import deque
from typing import Any

from repro.core.detectors import META_KV_OCC
from repro.core.events import EventKind

__all__ = ["FlightRecorder"]

# Cap on frozen META rows per snapshot so reports stay small even with
# chatty self-telemetry; newest rows win.
MAX_META_ROWS = 256


class FlightRecorder:
    """Bounded ring of recent EventBatch frames + freeze-on-incident."""

    def __init__(self, max_frames: int = 64) -> None:
        self.max_frames = max_frames
        self._frames: deque[tuple[float, Any]] = deque(maxlen=max_frames)
        self.frames_seen = 0
        self.events_seen = 0

    # -- feeding (hot path: one append) ----------------------------------

    def on_batch(self, recv_ts: float, batch: Any) -> None:
        self._frames.append((recv_ts, batch))
        self.frames_seen += 1
        self.events_seen += len(batch)

    # -- introspection ----------------------------------------------------

    def occupancy(self) -> int:
        return len(self._frames)

    def window_span(self) -> float:
        """Event-time span covered by the retained ring (seconds)."""
        if not self._frames:
            return 0.0
        lo = None
        hi = None
        for _, b in self._frames:
            if len(b) == 0:
                continue
            t0 = float(b.ts[0])
            t1 = float(b.ts[-1])
            lo = t0 if lo is None or t0 < lo else lo
            hi = t1 if hi is None or t1 > hi else hi
        if lo is None or hi is None:
            return 0.0
        return hi - lo

    # -- freeze -----------------------------------------------------------

    def snapshot(self, freeze_ts: float) -> dict[str, Any]:
        """Frozen summary of the ring at incident-open time."""
        frames: list[dict[str, Any]] = []
        meta_rows: list[dict[str, Any]] = []
        qs = int(EventKind.QUEUE_SAMPLE)
        for recv_ts, b in self._frames:
            n = len(b)
            frames.append({
                "recv_ts": round(recv_ts, 6),
                "events": n,
                "ts_min": round(float(b.ts[0]), 6) if n else None,
                "ts_max": round(float(b.ts[-1]), 6) if n else None,
            })
            if n == 0:
                continue
            mask = (b.kind == qs) & (b.meta >= META_KV_OCC)
            if not mask.any():
                continue
            sel = b.compress(mask)
            for i in range(len(sel)):
                meta_rows.append({
                    "ts": round(float(sel.ts[i]), 6),
                    "meta": int(sel.meta[i]),
                    "node": int(sel.node[i]),
                    "size": int(sel.size[i]),
                    "depth": int(sel.depth[i]),
                })
        dropped = 0
        if len(meta_rows) > MAX_META_ROWS:
            dropped = len(meta_rows) - MAX_META_ROWS
            meta_rows = meta_rows[-MAX_META_ROWS:]
        return {
            "freeze_ts": round(freeze_ts, 6),
            "frames": frames,
            "frames_seen": self.frames_seen,
            "events_seen": self.events_seen,
            "window_span_s": round(self.window_span(), 6),
            "meta_rows": meta_rows,
            "meta_rows_dropped": dropped,
        }
