"""Observability layer: causal spans, incident flight recorder, metrics.

Everything in this package is strictly *observe-only*: attaching a
:class:`~repro.obs.trace.Tracer` to the control loop draws no randomness,
mutates no events, and changes no decision — goldens are bit-identical
with tracing on or off (enforced by ``tests/test_obs.py``).
"""

from repro.obs.trace import (  # noqa: F401
    Incident,
    SpanEvent,
    Tracer,
    validate_report,
)
from repro.obs.recorder import FlightRecorder  # noqa: F401
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    collect_metrics,
)
