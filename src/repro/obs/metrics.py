"""Metrics exposition: counters/gauges/histograms in Prometheus text.

Hand-rolled (no prometheus_client dependency): the repo only needs the
text exposition format, which is trivially a sorted dump of
``name{labels} value`` lines.  ``collect_metrics`` walks a finished
run (tracer + plane/sidecar/watchdog stats) and populates a registry;
callers render it with :meth:`MetricsRegistry.render`.
"""

from __future__ import annotations

from typing import Any

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "collect_metrics"]


def _fmt_labels(labels: dict[str, Any]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{str(v)}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help_text: str) -> None:
        self.name = name
        self.help = help_text
        self._vals: dict[tuple[tuple[str, Any], ...], float] = {}

    def _key(self, labels: dict[str, Any]) -> tuple[tuple[str, Any], ...]:
        return tuple(sorted(labels.items()))

    def samples(self) -> list[tuple[str, str, float]]:
        return [(self.name, _fmt_labels(dict(k)), v)
                for k, v in sorted(self._vals.items())]


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        k = self._key(labels)
        self._vals[k] = self._vals.get(k, 0.0) + amount


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels: Any) -> None:
        self._vals[self._key(labels)] = float(value)


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help_text: str,
                 buckets: tuple[float, ...]) -> None:
        super().__init__(name, help_text)
        self.buckets = tuple(sorted(buckets))
        self._obs: dict[tuple[tuple[str, Any], ...],
                        tuple[list[int], float, int]] = {}

    def observe(self, value: float, **labels: Any) -> None:
        k = self._key(labels)
        counts, total, n = self._obs.get(
            k, ([0] * len(self.buckets), 0.0, 0))
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                counts[i] += 1
        self._obs[k] = (counts, total + value, n + 1)

    def samples(self) -> list[tuple[str, str, float]]:
        out: list[tuple[str, str, float]] = []
        for k, (counts, total, n) in sorted(self._obs.items()):
            base = dict(k)
            for i, ub in enumerate(self.buckets):
                lbl = dict(base)
                lbl["le"] = f"{ub:g}"
                out.append((self.name + "_bucket", _fmt_labels(lbl),
                            float(counts[i])))
            inf = dict(base)
            inf["le"] = "+Inf"
            out.append((self.name + "_bucket", _fmt_labels(inf), float(n)))
            out.append((self.name + "_sum", _fmt_labels(base), total))
            out.append((self.name + "_count", _fmt_labels(base), float(n)))
        return out


class MetricsRegistry:
    def __init__(self) -> None:
        self._metrics: dict[str, _Metric] = {}

    def counter(self, name: str, help_text: str = "") -> Counter:
        return self._get(name, lambda: Counter(name, help_text))

    def gauge(self, name: str, help_text: str = "") -> Gauge:
        return self._get(name, lambda: Gauge(name, help_text))

    def histogram(self, name: str, help_text: str = "",
                  buckets: tuple[float, ...] = (
                      0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0),
                  ) -> Histogram:
        return self._get(name, lambda: Histogram(name, help_text, buckets))

    def _get(self, name: str, factory: Any) -> Any:
        m = self._metrics.get(name)
        if m is None:
            m = factory()
            self._metrics[name] = m
        return m

    def render(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for sample_name, labels, value in m.samples():
                if value == int(value):
                    lines.append(f"{sample_name}{labels} {int(value)}")
                else:
                    lines.append(f"{sample_name}{labels} {value:.9g}")
        return "\n".join(lines) + "\n"


def collect_metrics(tracer: Any = None, plane: Any = None,
                    sidecar: Any = None, watchdog: Any = None,
                    recorder: Any = None,
                    registry: MetricsRegistry | None = None,
                    ) -> MetricsRegistry:
    """Populate a registry from a finished run's components.

    Every argument is optional — pass whatever the run had.  Pure
    post-hoc aggregation: nothing here touches the hot path.
    """
    reg = registry if registry is not None else MetricsRegistry()

    if tracer is not None:
        c = tracer.counters
        findings = reg.counter(
            "repro_findings_total", "Detector findings by runbook row")
        for row, n in sorted(c["findings_by_row"].items()):
            findings.inc(n, row=row)
        bus = reg.counter(
            "repro_bus_events_total", "Command-bus lifecycle events")
        for ev in ("send", "retry", "deliver", "ack", "fenced", "stale",
                   "expired"):
            if c.get("bus_" + ev):
                bus.inc(c["bus_" + ev], event=ev)
        if c["bus_fenced"]:
            reg.counter(
                "repro_commands_fenced_total",
                "Stale-term commands rejected at the host actuator",
            ).inc(c["bus_fenced"])
        ctl = reg.counter(
            "repro_control_transitions_total",
            "Watchdog / election control-plane transitions")
        for kind in ("failovers", "failbacks", "promotions", "demotions",
                     "crashes", "lease_grants"):
            if c.get(kind):
                ctl.inc(c[kind], kind=kind)
        reg.gauge("repro_incidents_open",
                  "Incidents currently open").set(
            1.0 if tracer.current is not None else 0.0)
        if tracer.incidents:
            reg.counter("repro_incidents_total",
                        "Incidents opened").inc(len(tracer.incidents))
        ttm_h = reg.histogram(
            "repro_ttm_seconds", "Per-phase time-to-mitigate",
            buckets=(0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0))
        for inc in tracer.incidents:
            ttm = inc.ttm()
            for phase, v in ttm.items():
                if v is not None:
                    ttm_h.observe(v, phase=phase)

    if plane is not None:
        st = plane.stats
        reg.gauge("repro_plane_events_total",
                  "Events observed by the telemetry plane").set(st.events)
        reg.gauge("repro_detector_ns_per_event",
                  "Sampled plane-wide detector cost").set(
            st.ns_per_event())
        per_det = getattr(st, "ns_per_event_by_detector", None)
        if per_det is not None:
            g = reg.gauge(
                "repro_detector_family_ns_per_event",
                "Sampled per-detector-family cost (same every-Nth "
                "cadence as the plane-wide figure)")
            for name, ns in sorted(per_det().items()):
                g.set(ns, detector=name)

    if sidecar is not None:
        rep = sidecar.report() if hasattr(sidecar, "report") else {}
        g = reg.gauge("repro_dpu_sidecar", "DPU sidecar health scalars")
        for key in ("dropped_events", "deferred_events", "overload_s"):
            if key in rep:
                g.set(rep[key], field=key)

    if watchdog is not None:
        rep = watchdog.report() if hasattr(watchdog, "report") else {}
        # Watchdog.report() nests its scalars under a "watchdog" key
        if isinstance(rep.get("watchdog"), dict):
            rep = rep["watchdog"]
        g = reg.gauge("repro_watchdog", "Watchdog state scalars")
        for key, val in rep.items():
            if isinstance(val, (int, float)) and not isinstance(val, bool):
                g.set(val, field=key)

    if recorder is not None:
        reg.gauge("repro_flight_recorder_frames",
                  "Flight-recorder ring occupancy").set(
            recorder.occupancy())
        reg.gauge("repro_flight_recorder_window_seconds",
                  "Event-time span covered by the ring").set(
            recorder.window_span())

    return reg
