"""Data pipeline: synthetic corpus, packing, length bucketing, prefetch."""
from repro.data.pipeline import (DataConfig, Prefetcher, SyntheticCorpus,
                                 length_buckets, pack_documents,
                                 padding_waste)
__all__ = ["DataConfig", "Prefetcher", "SyntheticCorpus", "length_buckets",
           "pack_documents", "padding_waste"]
