"""Data substrate: synthetic corpus, packing, LENGTH BUCKETING (one of the
paper's Table 3(a) mitigations), and a prefetching host-side loader.

The synthetic corpus is a seeded Zipf token stream with document structure
(variable-length docs + EOS) so packing/bucketing behave like real text.
"""

from __future__ import annotations

import threading
import queue as _q
from dataclasses import dataclass

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    batch: int
    doc_len_mean: int = 256
    zipf_a: float = 1.2
    eos: int = 0
    seed: int = 0


class SyntheticCorpus:
    """Deterministic document stream."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

    def doc(self) -> np.ndarray:
        n = max(8, int(self.rng.exponential(self.cfg.doc_len_mean)))
        toks = self.rng.zipf(self.cfg.zipf_a, n) % (self.cfg.vocab - 1) + 1
        return np.concatenate([toks.astype(np.int32),
                               [self.cfg.eos]]).astype(np.int32)


def pack_documents(corpus: SyntheticCorpus, n_batches: int):
    """Greedy sequence packing into (batch, seq_len) token/label arrays."""
    cfg = corpus.cfg
    buf = np.empty(0, np.int32)
    for _ in range(n_batches):
        need = cfg.batch * (cfg.seq_len + 1)
        while buf.size < need:
            buf = np.concatenate([buf, corpus.doc()])
        chunk = buf[:need].reshape(cfg.batch, cfg.seq_len + 1)
        buf = buf[need:]
        yield {"tokens": chunk[:, :-1].copy(),
               "labels": chunk[:, 1:].copy()}


def length_buckets(lengths: list[int],
                   edges: tuple[int, ...] = (64, 128, 256, 512, 1024, 2048)
                   ) -> dict[int, list[int]]:
    """Group request indices by padded-length bucket (3a mitigation:
    'length bucketing, batch formation')."""
    out: dict[int, list[int]] = {}
    for i, n in enumerate(lengths):
        b = next((e for e in edges if n <= e), edges[-1])
        out.setdefault(b, []).append(i)
    return out


def padding_waste(lengths: list[int], bucketed: bool,
                  edges: tuple[int, ...] = (64, 128, 256, 512, 1024, 2048)
                  ) -> float:
    """Fraction of padded tokens — quantifies the bucketing win.

    Unbucketed = every request padded to ONE compiled shape (the bucket
    edge covering the longest request); bucketed = per-request bucket.
    """
    if not lengths:
        return 0.0
    if bucketed:
        waste = tot = 0
        for b, idxs in length_buckets(lengths, edges).items():
            for i in idxs:
                waste += b - lengths[i]
                tot += b
        return waste / max(tot, 1)
    m = next((e for e in edges if max(lengths) <= e), edges[-1])
    return sum(m - n for n in lengths) / (m * len(lengths))


class Prefetcher:
    """Host-side background prefetch (overlap data with compute)."""

    def __init__(self, it, depth: int = 2) -> None:
        self._q: _q.Queue = _q.Queue(maxsize=depth)
        self._done = object()

        def worker():
            for item in it:
                self._q.put(item)
            self._q.put(self._done)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
