"""Distribution substrate: sharding rules, collectives, pipeline."""
from repro.parallel.sharding import MeshRules, fit
__all__ = ["MeshRules", "fit"]
