"""GPipe-style pipeline parallelism over the 'pod' axis.

Inter-pod links are the slow tier of a multi-pod system, which is exactly
where pipeline parallelism belongs: each pod holds a contiguous block of
layers (a stage); microbatches stream through stages with activations
handed off by ``jax.lax.ppermute`` inside ``shard_map``.

This is the selectable alternative to pure DP over 'pod' (the dry-run
default).  The schedule is 1F1B-flush (GPipe): with M microbatches and P
stages, bubble fraction = (P-1)/(M+P-1).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax >= 0.6 promotes shard_map to the top level (replication checking is
# spelled check_vma there); older releases keep it in experimental with
# check_rep.  Support both so the dry-run works across toolchains.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = {"check_vma": False}
else:  # pragma: no cover - exercised on jax < 0.6 toolchains
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = {"check_rep": False}


def pipeline_forward(stage_fn, stage_params, x_micro, *, mesh,
                     axis: str = "pod"):
    """Run microbatches through pipeline stages laid out on ``axis``.

    stage_fn: (params_slice, x) -> x        one stage's computation
    stage_params: pytree with leading dim = n_stages (sharded over axis)
    x_micro: (n_micro, mb, ...) microbatched input (replicated)
    Returns (n_micro, mb, ...) outputs (valid on the LAST stage; earlier
    stages hold zeros — caller reduces or reads from the last stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]

    def per_stage(params_slice, xs):
        stage = jax.lax.axis_index(axis)
        params_local = jax.tree.map(lambda a: a[0], params_slice)
        n_steps = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def step(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t; others use the handed-off act
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(stage == 0, xs[inject], buf)
            live = (t - stage >= 0) & (t - stage < n_micro)
            y = stage_fn(params_local, x_in)
            y = jnp.where(live, y, jnp.zeros_like(y))
            # hand off to the next stage (ring; last stage's output wraps
            # to stage 0 where it is ignored)
            nxt = jax.lax.ppermute(
                y, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            mb_idx = jnp.clip(t - stage, 0, n_micro - 1)
            outs = jnp.where(
                (stage == n_stages - 1) & live,
                outs.at[mb_idx].set(y), outs)
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(step, (buf, outs),
                                    jnp.arange(n_steps))
        # only the last stage holds real outputs; psum replicates them
        # (all other stages contribute zeros)
        return jax.lax.psum(outs, axis)

    fn = _shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),      # stage dim sharded; input replicated
        out_specs=P(),
        **_CHECK_KW,
    )
    return fn(stage_params, x_micro)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
