"""Distributed-optimization helpers: gradient compression with error
feedback, and microbatched gradient accumulation that overlaps the
per-microbatch reduction with the next microbatch's compute.

On a real pod the bf16 cast halves all-reduce bytes (XLA reduces in the
operand dtype); the error-feedback buffer makes the compression unbiased
over time (Seide et al. / Karimireddy et al.).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def compress_with_feedback(grads, error_buf):
    """bf16 compression with error feedback.

    Returns (compressed grads [bf16], new error buffer [f32 residual]).
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        gc = gf.astype(jnp.bfloat16)
        return gc, gf - gc.astype(jnp.float32)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_buf)
    cs, es = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return (jax.tree.unflatten(treedef, list(cs)),
            jax.tree.unflatten(treedef, list(es)))


def init_error_buf(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def accumulate_grads(loss_fn, params, microbatches, *,
                     compress: bool = False, error_buf=None):
    """Gradient accumulation over microbatches via lax.scan.

    Each microbatch's gradient is (optionally) compressed before joining
    the accumulator — modeling per-microbatch reduce-scatter that overlaps
    the next microbatch's compute (the scan pipeline gives XLA the overlap
    opportunity; on TPU the async collective scheduler exploits it).

    microbatches: pytree with leading axis n_micro.
    Returns (mean loss, accumulated grads [f32], new error buffer).
    """
    n_micro = jax.tree.leaves(microbatches)[0].shape[0]
    grad_fn = jax.value_and_grad(loss_fn)

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if error_buf is None:
        error_buf = init_error_buf(params)

    def body(carry, mb):
        acc, ebuf, loss_sum = carry
        loss, g = grad_fn(params, mb)
        if compress:
            g, ebuf = compress_with_feedback(g, ebuf)
        acc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32),
                           acc, g)
        return (acc, ebuf, loss_sum + loss), None

    (acc, ebuf, loss_sum), _ = jax.lax.scan(
        body, (zeros, error_buf, jnp.zeros((), jnp.float32)), microbatches)
    grads = jax.tree.map(lambda a: a / n_micro, acc)
    return loss_sum / n_micro, grads, ebuf
