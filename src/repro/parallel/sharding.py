"""Sharding rules: logical parameter/activation/cache layouts -> PartitionSpec.

Mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model') multi-pod.
  - batch dims shard over ('pod', 'data')           [DP across pods]
  - attention heads / d_ff / vocab over 'model'     [TP]
  - params additionally over 'data' when fsdp=True  [FSDP / ZeRO]
  - KV caches shard the *sequence* dim over 'model' (robust for GQA where
    n_kv_heads < TP degree; softmax reductions over the sharded seq are
    handled by SPMD with all-reduces)
  - MoE experts shard over 'model'                  [EP == TP axis]

``fit()`` drops any axis that does not divide a dim, so the same rules serve
every (arch x shape) cell — e.g. batch=1 long-context decode simply loses
its batch sharding instead of failing.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def fit(mesh: Mesh, shape: tuple[int, ...], spec: tuple) -> P:
    """Drop axes that don't divide their dim; returns a valid PartitionSpec."""
    fixed = []
    for dim, axes in zip(shape, spec):
        if axes is None:
            fixed.append(None)
            continue
        cand = (axes,) if isinstance(axes, str) else tuple(axes)
        kept = []
        size = dim
        for a in cand:
            if a in mesh.shape and size % mesh.shape[a] == 0:
                kept.append(a)
                size //= mesh.shape[a]
        fixed.append(tuple(kept) if len(kept) > 1 else
                     (kept[0] if kept else None))
    # trailing dims beyond spec -> replicated
    fixed += [None] * (len(shape) - len(fixed))
    return P(*fixed)


@dataclass
class MeshRules:
    """Bound to a mesh; produces shardings for params/acts/caches/batches.

    Optimization variants (see EXPERIMENTS.md §Perf):
      seq_parallel — residual-stream activations shard their sequence dim
        over 'model' (Korthikanti-style sequence parallelism): the
        per-layer TP combine becomes reduce-scatter (+ all-gather before
        qkv) instead of a full all-reduce.
      decode_2d — weight-stationary decode sharding: FFN weights live 2D
        over (data x model) and are NEVER gathered; the tiny decode
        activations move instead (vs ZeRO-inference all-gathering the
        whole model every step).
    """

    mesh: Mesh
    fsdp: bool = True
    seq_parallel: bool = False
    decode_2d: bool = False

    @property
    def batch_axes(self):
        return (("pod", "data") if "pod" in self.mesh.shape else ("data",))

    @property
    def fsdp_axis(self):
        return "data" if self.fsdp else None

    # ------------------------------------------------------------------
    # activation constraints (Sharder protocol for the model stacks)
    # ------------------------------------------------------------------

    def act(self, x: jax.Array, kind: str) -> jax.Array:
        if x.ndim == 3:
            if kind == "logits":
                spec = fit(self.mesh, x.shape,
                           (self.batch_axes, None, "model"))
            elif self.seq_parallel and kind == "act" and x.shape[1] > 1:
                spec = fit(self.mesh, x.shape,
                           (self.batch_axes, "model", None))
            elif self.decode_2d and kind == "ffn_in" and x.shape[1] == 1:
                # weight-stationary FFN: move the (tiny) decode activation
                # onto the weights' 'data' shards; weights never move
                spec = fit(self.mesh, x.shape, (None, None, "data"))
            else:
                spec = fit(self.mesh, x.shape, (self.batch_axes, None, None))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, spec))
        if x.ndim == 4 and kind == "moe_inner":
            # (G, E, C, d): groups over DP, experts over TP (EP)
            spec = fit(self.mesh, x.shape,
                       (self.batch_axes, "model", None, None))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, spec))
        if x.ndim == 5 and kind == "attn_logits" and x.shape[3] == 1:
            # decode logits (B, Hkv, G, 1, S): keep the kv/seq dim on the
            # TP axis — distributed softmax over the seq-sharded cache
            # instead of all-gathering KV every step
            spec = fit(self.mesh, x.shape,
                       (self.batch_axes, None, None, None, "model"))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, spec))
        return x

    # ------------------------------------------------------------------
    # parameter specs
    # ------------------------------------------------------------------

    def param_specs(self, params: Any) -> Any:
        """Pytree of PartitionSpec matching the params pytree."""
        fs = self.fsdp_axis

        def leaf_spec(path, arr) -> P:
            keys = [getattr(k, "key", getattr(k, "idx", None))
                    for k in path]
            name = next((k for k in reversed(keys)
                         if isinstance(k, str)), "")
            in_moe = "moe" in keys and "shared" not in keys
            nd = arr.ndim
            if nd == 0:
                return P()
            if self.decode_2d:
                # weight-stationary decode: never gather weights; FFN 2D
                # over (data x model), attention column/row over model only
                spec2d = self._decode_2d_spec(name, in_moe, nd)
                if spec2d is not None:
                    lead = nd - len(spec2d)
                    return fit(self.mesh, arr.shape,
                               (None,) * max(lead, 0) + spec2d[:nd])
            if name in ("scale", "A_log", "D", "dt_bias", "f_bias",
                        "bias"):
                trailing = (None,) * 1
            elif name == "embed":
                trailing = ("model", fs)
            elif name == "lm_head":
                trailing = (fs, "model")
            elif in_moe and name in ("w_gate", "w_up"):
                trailing = ("model", fs, None)       # experts over TP axis
            elif in_moe and name == "w_down":
                trailing = ("model", None, fs)
            elif in_moe and name == "router":
                trailing = (None, None)
            elif name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj",
                          "w_x", "w_i", "w_f"):
                trailing = (fs, "model")             # column parallel
            elif name in ("wo", "w_down", "out_proj", "w_o"):
                trailing = ("model", fs)             # row parallel
            elif name == "r_h":
                trailing = ("model", None, None)
            else:
                trailing = (None,) * min(nd, 2)
            lead = nd - len(trailing)
            spec = (None,) * max(lead, 0) + trailing[:nd]
            return fit(self.mesh, arr.shape, spec)

        return jax.tree_util.tree_map_with_path(leaf_spec, params)

    @staticmethod
    def _decode_2d_spec(name: str, in_moe: bool, nd: int):
        """Weight-stationary decode layouts (None = fall through)."""
        if name in ("w_gate", "w_up") and not in_moe:
            # contracting dim over 'data' (pairs with the ffn_in activation
            # constraint), output over 'model' — never gathered
            return ("data", "model")
        if name == "w_down" and not in_moe:
            # row-parallel over 'model'; output dim replicated over data so
            # the batch-sharded residual consumer never gathers the weight
            return ("model", None)
        if in_moe and name in ("w_gate", "w_up"):
            return ("model", "data", None)
        if in_moe and name == "w_down":
            return ("model", None, "data")
        if name in ("wq", "wk", "wv", "in_proj", "w_x", "w_i", "w_f"):
            return (None, "model")
        if name in ("wo", "out_proj", "w_o"):
            return ("model", None)
        if name == "embed":
            return ("model", None)
        if name == "lm_head":
            return (None, "model")
        return None

    def param_shardings(self, params: Any) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_specs(params),
                            is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------------------------
    # batch / cache specs
    # ------------------------------------------------------------------

    def batch_specs(self, batch: Any) -> Any:
        ba = self.batch_axes

        def leaf(arr) -> P:
            return fit(self.mesh, arr.shape,
                       (ba,) + (None,) * (arr.ndim - 1))

        return jax.tree.map(leaf, batch)

    def cache_specs(self, cache: Any) -> Any:
        """KV/state cache layouts (leading layer-stack dims replicated)."""
        ba = self.batch_axes

        def leaf(path, arr) -> P:
            keys = [getattr(k, "key", None) for k in path]
            name = next((k for k in reversed(keys)
                         if isinstance(k, str)), "")
            if arr.ndim == 0:      # pos scalar
                return P()
            if name in ("k", "v"):
                # (L, B, S, Hkv, hd) or (n_super, B, S, Hkv, hd):
                # batch over DP, SEQUENCE over TP (robust to Hkv < TP)
                return fit(self.mesh, arr.shape,
                           (None, ba, "model", None, None))
            if name == "enc_out":  # (B, S_src, d)
                return fit(self.mesh, arr.shape, (ba, None, None))
            if name in ("ssm", "ssm_tail"):
                # (..., B, H, P, N): heads over TP
                spec = (None,) * (arr.ndim - 4) + (ba, "model", None, None)
                return fit(self.mesh, arr.shape, spec)
            if name == "mlstm":
                # tuple leaves: (n_pairs, B, h, dh[, dh]) — shard dh
                if arr.ndim >= 4:
                    return fit(self.mesh, arr.shape,
                               (None, ba, None, "model") +
                               (None,) * (arr.ndim - 4))
                return fit(self.mesh, arr.shape, (None, ba, None))
            if name == "slstm":    # (n_pairs, B, d)
                return fit(self.mesh, arr.shape, (None, ba, "model"))
            spec = (None,) + (ba,) + (None,) * (arr.ndim - 2)
            return fit(self.mesh, arr.shape, spec[:arr.ndim])

        return jax.tree_util.tree_map_with_path(leaf, cache)

    def shardings_of(self, specs: Any) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))
