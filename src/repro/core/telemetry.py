"""TelemetryPlane — the DPU-analog observability fabric, end to end.

One ``DPUAgent`` per node plays the BlueField role: it subscribes to that
node's event stream, drives the full detector set at line rate, and exports
findings.  The ``TelemetryPlane`` aggregates agents cluster-wide, runs the
§4.2 attribution engine over the merged findings, and (optionally) closes
the loop through the mitigation controller — the paper's architecture in
~200 lines.

Overhead accounting is built in: the plane tracks wall-time spent in
update/poll so benchmarks can report the per-event cost (the paper's claim
is that this work belongs OFF the accelerator's critical path; here we prove
it is cheap enough to run on the host data path).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.attribution import Attribution, Attributor
from repro.core.detectors import Detector, DetectorConfig, Finding
from repro.core.events import Event, EventKind, EventStream
from repro.core.mitigation import (
    ActionRecord,
    EngineControls,
    MitigationController,
    NullEngine,
)
from repro.core.runbooks import build_detectors


@dataclass
class TelemetryStats:
    events: int = 0
    findings: int = 0
    attributions: int = 0
    actions: int = 0
    update_seconds: float = 0.0
    poll_seconds: float = 0.0

    def ns_per_event(self) -> float:
        if self.events == 0:
            return 0.0
        return self.update_seconds / self.events * 1e9


class DPUAgent:
    """Per-node line-rate observer: detector fan-out over one event stream."""

    def __init__(self, node: int, cfg: DetectorConfig | None = None,
                 tables: tuple[str, ...] = ("3a", "3b", "3c", "3d")) -> None:
        self.node = node
        self.detectors: dict[str, Detector] = build_detectors(cfg, tables)
        self.stream = EventStream()
        # pre-index detectors by event kind for O(interested) dispatch
        self._by_kind: dict[EventKind, list[Detector]] = {}
        for det in self.detectors.values():
            for kind in det.interested:
                self._by_kind.setdefault(kind, []).append(det)
        self.stats = TelemetryStats()

    def observe(self, ev: Event) -> None:
        t0 = time.perf_counter()
        self.stream.emit(ev)
        for det in self._by_kind.get(ev.kind, ()):
            det.update(ev)
        self.stats.events += 1
        self.stats.update_seconds += time.perf_counter() - t0

    def poll(self, now: float) -> list[Finding]:
        t0 = time.perf_counter()
        findings: list[Finding] = []
        for det in self.detectors.values():
            findings.extend(det.poll(now))
        self.stats.poll_seconds += time.perf_counter() - t0
        self.stats.findings += len(findings)
        return findings


class TelemetryPlane:
    """Cluster-wide aggregation + attribution + (optional) mitigation."""

    def __init__(self, n_nodes: int = 1,
                 cfg: DetectorConfig | None = None,
                 engine: EngineControls | None = None,
                 poll_interval: float = 0.25,
                 tables: tuple[str, ...] = ("3a", "3b", "3c", "3d"),
                 mitigate: bool = True) -> None:
        self.cfg = cfg or DetectorConfig()
        # A single shared agent set sees the merged cluster stream (the
        # paper's "distributed view" aggregated at the telemetry collector);
        # per-node separation lives in the Event.node field, which every
        # detector already keys on.
        self.agent = DPUAgent(node=-1, cfg=self.cfg, tables=tables)
        self.n_nodes = n_nodes
        self.attributor = Attributor()
        self.controller: MitigationController | None = None
        if mitigate:
            self.controller = MitigationController(engine or NullEngine())
        self.poll_interval = poll_interval
        self._next_poll = 0.0
        self.findings: list[Finding] = []
        self.attributions: list[Attribution] = []
        self.actions: list[ActionRecord] = []
        # dedup: (name, node) -> last finding ts, to avoid re-reporting the
        # same steady-state condition every poll
        self._last_seen: dict[tuple[str, int], float] = {}
        self.dedup_window = 1.0

    # -- ingestion -------------------------------------------------------

    def observe(self, ev: Event) -> None:
        self.agent.observe(ev)
        if ev.ts >= self._next_poll:
            self.tick(ev.ts)
            self._next_poll = ev.ts + self.poll_interval

    def observe_many(self, events) -> None:
        for ev in events:
            self.observe(ev)

    # -- control path ----------------------------------------------------

    def tick(self, now: float) -> list[Finding]:
        raw = self.agent.poll(now)
        fresh: list[Finding] = []
        for f in raw:
            key = (f.name, f.node)
            last = self._last_seen.get(key, float("-inf"))
            if now - last >= self.dedup_window:
                fresh.append(f)
                self._last_seen[key] = now
        if not fresh:
            return []
        self.findings.extend(fresh)
        atts = self.attributor.observe(fresh)
        self.attributions.extend(atts)
        self.agent.stats.attributions += len(atts)
        if self.controller is not None:
            acts = self.controller.consider_all(atts)
            self.actions.extend(acts)
            self.agent.stats.actions += len(acts)
        return fresh

    # -- reporting -------------------------------------------------------

    @property
    def stats(self) -> TelemetryStats:
        return self.agent.stats

    def report(self) -> dict:
        by_row: dict[str, int] = {}
        for f in self.findings:
            by_row[f.name] = by_row.get(f.name, 0) + 1
        by_locus: dict[str, int] = {}
        for a in self.attributions:
            by_locus[a.locus] = by_locus.get(a.locus, 0) + 1
        return {
            "events": self.stats.events,
            "findings": len(self.findings),
            "findings_by_row": by_row,
            "attributions_by_locus": by_locus,
            "actions": [(r.ts, r.action, r.node) for r in self.actions],
            "ns_per_event": self.stats.ns_per_event(),
        }
