"""TelemetryPlane — the DPU-analog observability fabric, end to end.

One ``DPUAgent`` per node plays the BlueField role: it subscribes to that
node's event stream, drives the full detector set at line rate, and exports
findings.  The ``TelemetryPlane`` aggregates agents cluster-wide, runs the
§4.2 attribution engine over the merged findings, and (optionally) closes
the loop through the mitigation controller — the paper's architecture in
~200 lines.

Overhead accounting is built in: the plane tracks wall-time spent in
update/poll so benchmarks can report the per-event cost (the paper's claim
is that this work belongs OFF the accelerator's critical path; here we prove
it is cheap enough to run on the host data path).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.attribution import Attribution, Attributor
from repro.core.detectors import Detector, DetectorConfig, Finding
from repro.core.events import Event, EventBatch, EventKind, EventStream
from repro.core.mitigation import (
    ActionRecord,
    EngineControls,
    MitigationController,
    NullEngine,
)
from repro.core.runbooks import DEFAULT_TABLES, build_detectors


@dataclass
class TelemetryStats:
    events: int = 0
    findings: int = 0
    attributions: int = 0
    actions: int = 0
    update_seconds: float = 0.0   # wall-time inside SAMPLED ingest windows
    timed_events: int = 0         # events covered by those windows
    poll_seconds: float = 0.0
    # per-detector-family breakdown, from *separate* sampled windows
    # (offset half a cadence from the plane-wide ones so the inner timer
    # pairs never sit inside — and inflate — the plane-wide measurement)
    det_seconds: dict = field(default_factory=dict)
    det_events: dict = field(default_factory=dict)

    def ns_per_event(self) -> float:
        """Per-event detector-update cost, from sampled timing windows.

        Timing is sampled (every Nth batch / Nth event), so the estimate
        measures detector work rather than the timer overhead that a
        per-event ``perf_counter`` pair would add to — and dominate on —
        the hot path.
        """
        if self.timed_events == 0:
            return 0.0
        return self.update_seconds / self.timed_events * 1e9

    def ns_per_event_by_detector(self) -> dict:
        """Per-detector-family cost (ns per event *that family saw*).

        Same every-Nth sampling cadence as :meth:`ns_per_event`; one
        slow detector no longer hides inside the plane-wide average.
        """
        out = {}
        for name, secs in self.det_seconds.items():
            n = self.det_events.get(name, 0)
            if n:
                out[name] = secs / n * 1e9
        return out


class DPUAgent:
    """Per-node line-rate observer: detector fan-out over one event stream.

    Two ingest paths share every detector's state:

      observe(ev)        — per-event compatibility path (kind-indexed
                           dispatch, exactly the seed behavior)
      observe_batch(b)   — columnar hot path: vectorized detectors get
                           per-kind sub-batches (each built once and shared
                           across all interested detectors); scalar fallback
                           detectors share one materialization of the batch.

    Overhead timing is sampled every ``sample_every`` batches (or events on
    the scalar path) so the measurement doesn't tax the path it measures.

    Batches below ``SMALL_BATCH`` rows replay through the per-event dispatch
    instead: the columnar path's fixed per-batch cost (per-kind filters,
    array slicing) only amortizes once a batch is ring-DMA-sized, and a
    producer emitting a handful of events per step (the live engine) must
    not pay 3x the scalar price for them.  Both paths are bit-identical, so
    the crossover is purely a performance choice.
    """

    SMALL_BATCH = 64

    def __init__(self, node: int, cfg: DetectorConfig | None = None,
                 tables: tuple[str, ...] = DEFAULT_TABLES,
                 full_trace: bool = False,
                 sample_every: int = 32) -> None:
        self.node = node
        self._cfg = cfg
        self._tables = tables
        self.detectors: dict[str, Detector] = build_detectors(cfg, tables)
        self.stream = EventStream(full_trace=full_trace)
        self.sample_every = max(sample_every, 1)
        # per-detector breakdown windows sit half a cadence away from the
        # plane-wide ones so their inner timer pairs never inflate the
        # plane-wide figure (disabled when sample_every == 1: every
        # window is already plane-timed)
        self._det_slot = self.sample_every // 2
        self._batches = 0
        self._index_detectors()
        self.stats = TelemetryStats()

    def _index_detectors(self) -> None:
        # pre-index detectors by event kind for O(interested) dispatch
        self._by_kind: dict[EventKind, list[Detector]] = {}
        for det in self.detectors.values():
            for kind in det.interested:
                self._by_kind.setdefault(kind, []).append(det)
        # batch dispatch plan: vectorized detectors receive per-kind
        # sub-batches (built once per present kind, shared across every
        # detector interested in it — each wire row is copied at most once);
        # scalar-fallback detectors share one per-event replay over a single
        # cached materialization, preserving cross-kind interleaving for the
        # pairing-sensitive rows (dispatch->D2H latency etc.)
        self._vec_dets: list[Detector] = []
        self._fallback_by_kind: dict[EventKind, list[Detector]] = {}
        for det in self.detectors.values():
            if type(det).update_batch is not Detector.update_batch:
                self._vec_dets.append(det)
            else:
                for kind in det.interested:
                    self._fallback_by_kind.setdefault(kind, []).append(det)
        self._fallback_kinds = frozenset(self._fallback_by_kind)
        # detector object -> runbook-row name, for the per-family
        # timing breakdown (rebuilt with the detectors after a crash)
        self._det_name: dict[int, str] = {
            id(det): name for name, det in self.detectors.items()}

    def reset_detectors(self) -> None:
        """Rebuild every detector from scratch — the DPU-crash model:
        detector state is DPU DRAM and does not survive a power cycle.
        Cumulative stats and the event stream are the *experiment's*
        record, not DPU state, so they survive."""
        self.detectors = build_detectors(self._cfg, self._tables)
        self._index_detectors()

    def _update_timed(self, dets, ev: Event) -> None:
        # per-detector breakdown window: one timer pair per update call
        names = self._det_name
        ds = self.stats.det_seconds
        de = self.stats.det_events
        for det in dets:
            d0 = time.perf_counter()
            det.update(ev)
            dt = time.perf_counter() - d0
            name = names[id(det)]
            ds[name] = ds.get(name, 0.0) + dt
            de[name] = de.get(name, 0) + 1

    def observe(self, ev: Event) -> None:
        stats = self.stats
        slot = stats.events % self.sample_every
        timed = slot == 0
        t0 = time.perf_counter() if timed else 0.0
        self.stream.emit(ev)
        if not timed and slot == self._det_slot:
            self._update_timed(self._by_kind.get(ev.kind, ()), ev)
        else:
            for det in self._by_kind.get(ev.kind, ()):
                det.update(ev)
        stats.events += 1
        if timed:
            stats.update_seconds += time.perf_counter() - t0
            stats.timed_events += 1

    def observe_batch(self, batch: EventBatch) -> None:
        n = len(batch)
        if n == 0:
            return
        stats = self.stats
        slot = self._batches % self.sample_every
        timed = slot == 0
        det_timed = not timed and slot == self._det_slot
        self._batches += 1
        t0 = time.perf_counter() if timed else 0.0
        self.stream.emit_batch(batch)
        if n < self.SMALL_BATCH:
            # per-event replay: cheaper than columnar below the crossover
            by_kind = self._by_kind
            if det_timed:
                for ev in batch.iter_events():
                    self._update_timed(by_kind.get(ev.kind, ()), ev)
            else:
                for ev in batch.iter_events():
                    for det in by_kind.get(ev.kind, ()):
                        det.update(ev)
        else:
            kinds = batch.kind
            present = set(np.unique(kinds).tolist())
            single = len(present) == 1
            subs: dict[int, EventBatch] = {}
            names = self._det_name
            for det in self._vec_dets:
                for k in det.interested:
                    if k not in present:
                        continue
                    sub = subs.get(k)
                    if sub is None:
                        sub = batch if single else batch.compress(kinds == k)
                        subs[k] = sub
                    if det_timed:
                        d0 = time.perf_counter()
                        det.update_batch(sub)
                        dt = time.perf_counter() - d0
                        name = names[id(det)]
                        stats.det_seconds[name] = \
                            stats.det_seconds.get(name, 0.0) + dt
                        stats.det_events[name] = \
                            stats.det_events.get(name, 0) + len(sub)
                    else:
                        det.update_batch(sub)
            if self._fallback_kinds & present:
                fbk = self._fallback_by_kind
                if det_timed:
                    for ev in batch.iter_events():
                        self._update_timed(fbk.get(ev.kind, ()), ev)
                else:
                    for ev in batch.iter_events():
                        for det in fbk.get(ev.kind, ()):
                            det.update(ev)
        stats.events += n
        if timed:
            stats.update_seconds += time.perf_counter() - t0
            stats.timed_events += n

    def poll(self, now: float) -> list[Finding]:
        t0 = time.perf_counter()
        findings: list[Finding] = []
        for det in self.detectors.values():
            findings.extend(det.poll(now))
        self.stats.poll_seconds += time.perf_counter() - t0
        self.stats.findings += len(findings)
        return findings


class TelemetryPlane:
    """Cluster-wide aggregation + attribution + (optional) mitigation."""

    def __init__(self, n_nodes: int = 1,
                 cfg: DetectorConfig | None = None,
                 engine: EngineControls | None = None,
                 poll_interval: float = 0.25,
                 tables: tuple[str, ...] = DEFAULT_TABLES,
                 mitigate: bool = True,
                 full_trace: bool = False) -> None:
        self.cfg = cfg or DetectorConfig()
        # A single shared agent set sees the merged cluster stream (the
        # paper's "distributed view" aggregated at the telemetry collector);
        # per-node separation lives in the Event.node field, which every
        # detector already keys on.
        self.agent = DPUAgent(node=-1, cfg=self.cfg, tables=tables,
                              full_trace=full_trace)
        self.n_nodes = n_nodes
        self.attributor = Attributor()
        self.controller: MitigationController | None = None
        if mitigate:
            self.controller = MitigationController(engine or NullEngine())
        self.poll_interval = poll_interval
        self._next_poll = 0.0
        self.findings: list[Finding] = []
        self.attributions: list[Attribution] = []
        self.actions: list[ActionRecord] = []
        # dedup: (name, node) -> last finding ts, to avoid re-reporting the
        # same steady-state condition every poll
        self._last_seen: dict[tuple[str, int], float] = {}
        self.dedup_window = 1.0
        self._warming = False
        # observability (observe-only; None = disabled, the default)
        self.tracer = None
        self.trace_source = ""
        self.recorder = None

    # -- ingestion -------------------------------------------------------

    def observe(self, ev: Event) -> None:
        self.agent.observe(ev)
        if ev.ts >= self._next_poll:
            self.tick(ev.ts)
            self._next_poll = ev.ts + self.poll_interval

    def observe_batch(self, batch: EventBatch) -> None:
        """Columnar ingest — behaviorally identical to observing each event.

        The batch is split at poll boundaries: the scalar path polls at the
        first event whose ts crosses ``_next_poll``, so the batch path feeds
        the sub-batch up to AND INCLUDING that event, ticks at its timestamp,
        and continues — detectors see the same state at the same poll times
        either way (the equivalence property test asserts this).
        """
        n = len(batch)
        if n == 0:
            return
        ts = batch.ts
        if self.recorder is not None and not self._warming:
            # flight recorder: one ring append per delivered frame
            # (warm-start replays are historical, not fresh telemetry)
            self.recorder.on_batch(float(ts[n - 1]), batch)
        start = 0
        while True:
            # first event (in wire order — batches need not be globally
            # sorted) whose ts crosses the poll boundary, exactly like the
            # scalar path's per-event check
            crossed = ts[start:] >= self._next_poll
            if not crossed.any():
                if start == 0:
                    self.agent.observe_batch(batch)
                else:
                    self.agent.observe_batch(batch.slice(start, n))
                return
            i = start + int(np.argmax(crossed))
            self.agent.observe_batch(batch.slice(start, i + 1))
            now = float(ts[i])
            self.tick(now)
            self._next_poll = now + self.poll_interval
            start = i + 1
            if start >= n:
                return

    def observe_many(self, events) -> None:
        for ev in events:
            self.observe(ev)

    # -- chaos -----------------------------------------------------------

    def reset_detector_state(self) -> None:
        """DPU crash: all warm detector/attribution/dedup state is lost.
        The findings/attributions/actions logs survive — they are what the
        experiment already observed, not state on the failed device.

        The poll anchor resets with the detectors: a replay of retained
        history (watchdog failover) must tick at the *historical* poll
        boundaries, not accumulate silently until the pre-reset
        ``_next_poll`` — one giant catch-up window blurs exactly the rate
        sags and skews the replay was meant to preserve."""
        self.agent.reset_detectors()
        self.attributor._recent.clear()
        self._last_seen.clear()
        self._next_poll = 0.0

    def warm_start(self, batches) -> None:
        """Rebuild detector state by replaying retained history WITHOUT
        re-logging it — the host-side state transfer a supervisor performs
        when it hands control back to a restarted monitor.

        A power-cycled DPU that re-warms only on fault-era traffic
        calibrates its baselines to the fault: the pathology reads as
        normal and rate/peak-latch rows never fire again.  Replaying the
        supervisor's retained tap window (which spans pre-incident
        traffic) restores honest baselines.  Findings produced during the
        replay are discarded — the experiment record already holds what
        was observed live, and a replay must not duplicate it — and the
        dedup map is left unpopulated so the first *live* detection after
        the warm-start logs fresh.  Call ``reset_detector_state`` first;
        poll ticks then land on the historical boundaries and the anchor
        ends at the replay edge, so live ingest continues seamlessly."""
        s = self.agent.stats
        snap = (s.events, s.findings, s.update_seconds, s.timed_events,
                s.poll_seconds, dict(s.det_seconds), dict(s.det_events))
        self._warming = True
        try:
            for b in batches:
                self.observe_batch(b)
        finally:
            self._warming = False
            (s.events, s.findings, s.update_seconds, s.timed_events,
             s.poll_seconds, s.det_seconds, s.det_events) = snap

    # -- control path ----------------------------------------------------

    def tick(self, now: float) -> list[Finding]:
        raw = self.agent.poll(now)
        if self._warming:
            # warm-start replay: detectors drained at the historical poll
            # boundary, but nothing downstream — no log, no dedup mark,
            # no attribution, no actuation
            return []
        fresh: list[Finding] = []
        for f in raw:
            key = (f.name, f.node)
            last = self._last_seen.get(key, float("-inf"))
            if now - last >= self.dedup_window:
                fresh.append(f)
                self._last_seen[key] = now
        if not fresh:
            return []
        self.findings.extend(fresh)
        tracer = self.tracer
        if tracer is not None:
            for f in fresh:
                tracer.on_finding(f, self.trace_source)
        atts = self.attributor.observe(fresh)
        self.attributions.extend(atts)
        if tracer is not None:
            for a in atts:
                tracer.on_attribution(a, self.trace_source)
        self.agent.stats.attributions += len(atts)
        if self.controller is not None:
            acts = self.controller.consider_all(atts)
            self.actions.extend(acts)
            self.agent.stats.actions += len(acts)
        return fresh

    # -- reporting -------------------------------------------------------

    @property
    def stats(self) -> TelemetryStats:
        return self.agent.stats

    def report(self) -> dict:
        by_row: dict[str, int] = {}
        for f in self.findings:
            by_row[f.name] = by_row.get(f.name, 0) + 1
        by_locus: dict[str, int] = {}
        for a in self.attributions:
            by_locus[a.locus] = by_locus.get(a.locus, 0) + 1
        return {
            "events": self.stats.events,
            "findings": len(self.findings),
            "findings_by_row": by_row,
            "attributions_by_locus": by_locus,
            "actions": [(r.ts, r.action, r.node) for r in self.actions],
            "ns_per_event": self.stats.ns_per_event(),
            "ns_per_event_by_detector":
                self.stats.ns_per_event_by_detector(),
        }
