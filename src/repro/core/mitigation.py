"""Closed-loop mitigation controller — the paper's §5 thesis, executable.

    "combining software-based record keeping with DPU-based telemetry can
     create a much [more] efficient closed feedback loop that would allow
     inference clusters to adaptively balance workloads, minimize idle
     bubbles, and deliver predictable low-latency performance at scale."

The controller consumes attributions (``core.attribution``) and issues typed
*actions* against anything implementing ``EngineControls`` — the live JAX
serving engine, the trainer, and the cluster simulator all implement it.
Every runbook row's "Mitigation Directives" column maps to one action key
(``runbooks.RunbookEntry.action``); the ``repro.lint.wiring`` static pass
keeps the two registries in lockstep.  The controller adds per-(action, node)
hysteresis and a cooldown so a single noisy finding doesn't thrash the
engine.

This is the *instant*-mode reference: attribution -> action in the same
call, zero transport latency.  The default closed-loop topology routes
decisions through ``repro.dpu`` instead (``PolicyEngine`` arbitration over
a modeled transport and command bus), which subsumes this hysteresis; the
controller is retained verbatim so instant-mode golden fixtures and the
``control_loop`` benchmark's baseline stay bit-identical to the seed
behavior.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Protocol

from repro.core.attribution import Attribution
from repro.core.detectors import Finding
from repro.core.runbooks import BY_ID


class EngineControls(Protocol):
    """Actuation surface the mitigation plane drives.

    Implementations: ``serving.engine.InferenceEngine`` (live),
    ``training.train_loop.Trainer`` (live), ``sim.cluster.ClusterSim`` (sim).
    All methods are best-effort; unknown knobs may no-op, but must return a
    bool saying whether anything changed (for the action log).
    """

    def apply_action(self, action: str, node: int, detail: dict) -> bool: ...


#: action key -> description of what the engine should do (documentation +
#: the closed set tests assert against).
ACTIONS: dict[str, str] = {
    "smooth_admission": "spread request admission over the batching window; "
                        "rate-limit offending clients",
    "rebalance_frontend": "rehash flows across front-end shards / queues",
    "tune_transport": "adjust transport offloads / congestion control",
    "enlarge_egress_buffers": "grow egress buffering; enable zero-copy path",
    "widen_batch_window": "increase decode batching window to absorb jitter",
    "inflight_remap": "remap/pack inflight decode slots onto busy shards "
                      "(load stealing for early-finished sequences)",
    "admission_control": "throttle new request admission until drained",
    "pin_and_coalesce": "pre-pin transfer pools and coalesce small DMAs",
    "batch_launches": "aggregate device launches; enlarge launch queue",
    "rebalance_microbatches": "shift microbatch quota away from slow device",
    "stagger_io": "phase-shift bulk I/O away from compute-critical windows",
    "replace_topology": "prefer direct interconnect path / repin devices",
    "isolate_host_threads": "pin runtime threads; isolate IRQs",
    "rebalance_shards": "resize/reassign TP shards toward slow rank",
    "repartition_stages": "move layers between pipeline stages",
    "reroute_traffic": "enable adaptive routing / spread ranks over links",
    "qos_partition": "partition queues per traffic class (QoS/ECN)",
    "widen_rdma_window": "increase RDMA QP window / credit budget",
    "compress_kv": "enable KV-cache compression for transfers",
    "rebalance_replicas": "redistribute queued requests across DP replicas; "
                          "refresh the router view / break hot affinity",
    "rebalance_nodes": "level queued requests across the nodes inside each "
                       "replica; restore the intra-replica spread",
    "throttle_telemetry": "raise the telemetry tap's sampling stride / shed "
                          "low-priority event classes so the DPU ingest "
                          "budget recovers",
    "shrink_batch": "halve the decode batch-slot cap so the active batch "
                    "drops back below the memory-bandwidth knee",
    "reroute_rail": "spread cross-domain collective legs over all rails "
                    "instead of their home rail (hot-rail bypass)",
    "failover_controller": "fail mitigation over to the degraded host-side "
                           "fallback controller (high-confidence rows only, "
                           "longer confirmations, no cluster-scoped quorum) "
                           "until the DPU path round-trips again",
    "resync_telemetry": "re-register the telemetry tap and resync the "
                        "batch sequence stream after an ingest gap; clears "
                        "the blackout latch once the stream is whole",
    "remirror_standby": "replay the watchdog's retained tap history into "
                        "the lagging standby sidecar and resync its "
                        "sequence stream so its detector state catches "
                        "back up to the primary's",
    "fence_stale_controller": "deliver the currently granted lease term "
                              "to any deposed-but-alive sidecar (quiesce "
                              "it) and purge its outstanding commands; "
                              "the fence itself already blocked the stale "
                              "actuations",
}

# keep the two registries in lockstep: every runbook row must actuate
# through a key the controller (and the DPU policy engine) understands.
# ACTIONS <-> runbook sync (rows only reference registered actions; every
# action is emitted by some row) is enforced statically by
# repro.lint.wiring.check_wiring — the wiring-action rule — gated in CI
# and in tests/test_runbooks.py, replacing the import-time assert that
# used to live here.


@dataclass(frozen=True)
class ActionRecord:
    ts: float
    action: str
    node: int
    row_id: str
    locus: str
    applied: bool
    detail: dict = field(default_factory=dict, compare=False)


class MitigationController:
    """Maps attributions -> engine actions with hysteresis + cooldown."""

    def __init__(self, engine: EngineControls,
                 min_confidence: float = 0.6,
                 confirmations: int = 2,
                 cooldown: float = 5.0) -> None:
        self.engine = engine
        self.min_confidence = min_confidence
        self.confirmations = confirmations
        self.cooldown = cooldown
        self._pending: dict[tuple[str, int], int] = {}
        self._last_applied: dict[tuple[str, int], float] = {}
        self.log: list[ActionRecord] = []

    def consider(self, attribution: Attribution) -> ActionRecord | None:
        f: Finding = attribution.primary
        entry = BY_ID.get(f.name)
        if entry is None or attribution.confidence < self.min_confidence:
            return None
        key = (entry.action, attribution.node)
        # hysteresis: require repeated confirmation before actuating
        hits = self._pending.get(key, 0) + 1
        self._pending[key] = hits
        needed = 1 if f.severity == "critical" else self.confirmations
        if hits < needed:
            return None
        last = self._last_applied.get(key, float("-inf"))
        if attribution.ts - last < self.cooldown:
            return None
        detail = {
            "row": f.name,
            "locus": attribution.locus,
            "score": f.score,
            "narrative": attribution.narrative,
            # instant topology: actuation time IS the attribution time
            # (actuators like ReplicaSet read wall time from here)
            "now": attribution.ts,
            **f.evidence,
        }
        applied = self.engine.apply_action(entry.action, attribution.node,
                                           detail)
        rec = ActionRecord(ts=attribution.ts, action=entry.action,
                           node=attribution.node, row_id=f.name,
                           locus=attribution.locus, applied=applied,
                           detail=detail)
        self.log.append(rec)
        if applied:
            self._last_applied[key] = attribution.ts
            self._pending[key] = 0
        return rec

    def consider_all(self, attributions: list[Attribution]
                     ) -> list[ActionRecord]:
        out = []
        for a in attributions:
            r = self.consider(a)
            if r is not None:
                out.append(r)
        return out


class NullEngine:
    """EngineControls that records but does nothing (detection-only mode)."""

    def __init__(self) -> None:
        self.calls: list[tuple[str, int, dict]] = []

    def apply_action(self, action: str, node: int, detail: dict) -> bool:
        self.calls.append((action, node, detail))
        return True
