"""Executable detectors — one per row of the paper's Tables 3(a), 3(b), 3(c).

Each detector consumes only DPU-observable events (``core.events``), keeps
O(1)-per-key streaming state (``core.sketch``), and yields ``Finding`` records
binding the paper's columns: signal -> lifecycle stage -> root cause ->
mitigation directive.

Detector contract:
    d.interested : frozenset[EventKind]   events it wants
    d.update(ev) : feed one event (line-rate path, must be cheap)
    d.poll(now)  : -> list[Finding]       periodic evaluation (control path)

Thresholds are deliberately self-calibrating (z-scores / CUSUM against learned
baselines) so the same detector works on simulated traces and on the live JAX
serving engine without per-workload tuning.  Absolute capacity thresholds
(link saturation) take the capacity from ``DetectorConfig``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.events import (
    COLL_EDGE_FINISH,
    COLL_GROUP_ALL_GATHER,
    COLL_GROUP_REDUCE_SCATTER,
    CollectiveOp,
    DOMAIN_GROUP_BASE,
    Event,
    EventBatch,
    EventKind,
    RAIL_GROUP_BASE,
)
from repro.core.sketch import (
    EWMA,
    BurstMeter,
    CUSUM,
    GapTracker,
    P2Quantile,
    RateMeter,
    SpreadTracker,
    Welford,
)

# meta-field conventions (documented in events.py docstring-level contract):
META_DIR_INGRESS = 0
META_DIR_EGRESS = 1
META_DIR_EW = 2          # east-west fabric retransmit
META_FIN = 1             # EGRESS_PKT meta flag: final packet of flow
META_P2P_INTRA = 0       # P2P_BURST inside one node (PCIe peer path)
META_P2P_INTER = 1       # P2P_BURST between nodes (PP handoff)
META_P2P_KV = 2          # P2P_BURST carrying KV-cache pages
META_KV_OCC = 3          # QUEUE_SAMPLE carrying KV-occupancy (% of pool)
META_TAP_DEBUG = 4       # QUEUE_SAMPLE from a verbose debug tap (payload
#                          noise for the telemetry plane; no detector keys
#                          on it — it only consumes DPU ingest budget)
META_DPU_RING = 5        # QUEUE_SAMPLE: DPU self-telemetry (ingest-ring
#                          occupancy % in depth, rows shed since the last
#                          sample in size; node = -1)
META_BATCH_OCC = 6       # QUEUE_SAMPLE: scheduler-exported active decode
#                          batch size per node (depth = active slots) — the
#                          NIC-side tap of the host scheduler's slot count,
#                          same vantage as the ingress-queue samples
META_MON_HEARTBEAT = 7   # QUEUE_SAMPLE: host-side watchdog heartbeat probe
#                          (size = 1 while the DPU is silent past the
#                          timeout, 0 while healthy; depth = silence ms;
#                          node = -1) — emitted into the STANDBY plane by
#                          the watchdog, never by the DPU itself
META_MON_INGEST = 8      # QUEUE_SAMPLE: DPU ingest-guard health (size =
#                          missing + corrupt rows latched since the last
#                          resync, depth = replays dropped; node = -1);
#                          emitted only while the guard is dirty
META_MON_BUS = 9         # QUEUE_SAMPLE: command-bus health (size =
#                          cumulative retry exhaustions, depth = cumulative
#                          retries; node = -1); emitted only between an
#                          exhaustion and the next successful ack
META_MON_STANDBY = 10    # QUEUE_SAMPLE: standby-shadow health probe (size =
#                          standby tap-clock lag behind the primary in ms,
#                          clamped at 0 — a dead *primary* is the outage
#                          row's business; depth = 1 while the standby is
#                          up, 0 while crashed; node = -1) — emitted by the
#                          watchdog every probe while a standby exists
META_MON_FENCE = 11      # QUEUE_SAMPLE: stale-term commands fenced by the
#                          host actuator since the last probe (size =
#                          fenced delta, depth = current granted term;
#                          node = -1); emitted only when the delta is > 0
META_MON_RETAIN = 12     # QUEUE_SAMPLE: watchdog retained-tap-window gauge
#                          (size = retained batch count, depth = payload
#                          span covered in ms; node = -1) — emitted every
#                          probe while the window is non-empty, so a
#                          count-cap-starved replay window (and with it a
#                          thin remirror_standby) is observable, not
#                          inferred.  No detector consumes it today.


def _ext_group(group: int) -> bool:
    """True for rows of the per-collective / rail / domain emission tier.

    The aggregate-tier 3c detectors skip these rows: the dedicated 3e rows
    (collective_straggler, rail_congestion) own those signals, and the much
    denser per-op cadence would otherwise poison the gap/spread baselines
    the aggregate detectors learn from the legacy group-0 bursts.
    """
    return (group == COLL_GROUP_ALL_GATHER
            or group == COLL_GROUP_REDUCE_SCATTER
            or group >= RAIL_GROUP_BASE)


@dataclass(frozen=True)
class Finding:
    """One detected pathological condition (a runbook row firing)."""

    name: str              # runbook row id, e.g. "tp_straggler"
    table: str             # "3a" | "3b" | "3c" | "3d"
    ts: float
    severity: str          # "warn" | "critical"
    node: int              # locus node (-1 = cluster-wide)
    device: int            # locus device (-1 = n/a)
    stage: str             # lifecycle stage affected (paper column 3)
    root_cause: str        # likely root cause (paper column 5)
    directive: str         # mitigation directive (paper column 6)
    score: float           # detector-specific magnitude (z-score / ratio)
    evidence: dict = field(default_factory=dict, compare=False)


@dataclass
class DetectorConfig:
    """Shared capacity constants + sensitivity knobs."""

    nic_gbps: float = 200.0          # NIC line rate (bytes/s derived below)
    pcie_gBps: float = 64.0          # PCIe gen5 x16-ish GB/s
    ici_gBps: float = 50.0           # per-link ICI GB/s (TPU v5e)
    saturation_frac: float = 0.90    # "near link capacity"
    z_warn: float = 3.0
    z_crit: float = 6.0
    skew_cv_warn: float = 0.35       # coefficient-of-variation skew threshold
    skew_cv_crit: float = 0.70
    jitter_warn: float = 1.5         # CV of inter-arrival gaps
    jitter_crit: float = 3.0
    starvation_factor: float = 8.0   # open gap vs learned p99 gap
    min_events: int = 32             # warmup before a detector may fire

    @property
    def nic_Bps(self) -> float:
        return self.nic_gbps * 1e9 / 8.0

    @property
    def pcie_Bps(self) -> float:
        return self.pcie_gBps * 1e9

    @property
    def ici_Bps(self) -> float:
        return self.ici_gBps * 1e9


class Detector:
    """Base class; subclasses fill the paper-row metadata and the logic."""

    name: str = "abstract"
    table: str = "?"
    stage: str = "?"
    root_cause: str = "?"
    directive: str = "?"
    interested: frozenset = frozenset()

    def __init__(self, cfg: DetectorConfig) -> None:
        self.cfg = cfg
        self.events_seen = 0

    def update(self, ev: Event) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def update_batch(self, batch: EventBatch) -> None:
        """Feed one columnar batch (already filtered to ``interested`` kinds).

        Subclasses on the per-packet-dominant rows override this with
        vectorized implementations that are bit-identical to the scalar
        path (the batch/scalar equivalence property test enforces it);
        this default replays the batch through ``update`` — correct for
        every detector, just not fast.

        Contract for overriders: the dispatcher may deliver any
        kind-partition of the wire order (e.g. one sub-batch per event
        kind), so a vectorized implementation must process each kind class
        independently — it may not depend on cross-kind interleaving.
        Detectors that pair events across kinds (dispatch->D2H latency and
        friends) must NOT override this; the scalar fallback preserves full
        wire order for them.
        """
        for ev in batch.iter_events():
            self.update(ev)

    def poll(self, now: float) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError

    def _mk(self, now: float, score: float, node: int = -1, device: int = -1,
            severity: str | None = None, **evidence) -> Finding:
        sev = severity or ("critical" if score >= self.cfg.z_crit else "warn")
        return Finding(
            name=self.name, table=self.table, ts=now, severity=sev,
            node=node, device=device, stage=self.stage,
            root_cause=self.root_cause, directive=self.directive,
            score=score, evidence=evidence,
        )


# ======================================================================
# Table 3(a) — North-South runbook
# ======================================================================


class BurstAdmissionBacklog(Detector):
    """3a.1 — sudden ingress spikes followed by queueing delay."""

    name = "burst_admission_backlog"
    table = "3a"
    stage = "ingress (prefill/start)"
    root_cause = "load spike from clients / front-end batching / NIC queue limits"
    directive = "smooth input batching; rate-limit clients; increase NIC queue depth"
    interested = frozenset({EventKind.INGRESS_PKT, EventKind.QUEUE_SAMPLE})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.burst = BurstMeter()
        self.queue = EWMA(0.05)
        # bursts are much shorter than the poll interval: latch the peaks
        # seen since the last poll (a DPU would export max-over-interval)
        self.peak_burst = 0.0
        self.peak_depth = 0

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.kind == EventKind.INGRESS_PKT:
            self.burst.update(ev.ts, ev.size)
            self.peak_burst = max(self.peak_burst,
                                  self.burst.byte_burstiness())
        elif ev.kind == EventKind.QUEUE_SAMPLE and ev.meta == META_DIR_INGRESS:
            self.peak_depth = max(self.peak_depth, ev.depth)
            self.queue.update(float(ev.depth))

    def update_batch(self, batch: EventBatch) -> None:
        self.events_seen += len(batch)
        kinds = batch.kind
        ing = kinds == EventKind.INGRESS_PKT
        if ing.any():
            # the peak latch samples burstiness after every meter step, so
            # the fold is sequential; both rate meters are inlined (same
            # float ops as RateMeter.update — bit-identical)
            fast, slow = self.burst.fast, self.burst.slow
            f_hl, s_hl = fast.halflife, slow.halflife
            f_last, f_rate, f_brate = fast._last_ts, fast._rate, fast._brate
            s_last, s_rate, s_brate = slow._last_ts, slow._rate, slow._brate
            peak = self.peak_burst
            for ts, sz in zip(batch.ts[ing].tolist(),
                              batch.size[ing].tolist()):
                if f_last is None:
                    f_last, f_rate, f_brate = ts, 0.0, 0.0
                    s_last, s_rate, s_brate = ts, 0.0, 0.0
                else:
                    dt = ts - f_last
                    if dt < 1e-9:
                        dt = 1e-9
                    decay = 0.5 ** (dt / f_hl)
                    one_m = 1.0 - decay
                    f_rate = f_rate * decay + one_m / dt
                    f_brate = f_brate * decay + one_m * sz / dt
                    f_last = ts
                    dt = ts - s_last
                    if dt < 1e-9:
                        dt = 1e-9
                    decay = 0.5 ** (dt / s_hl)
                    one_m = 1.0 - decay
                    s_rate = s_rate * decay + one_m / dt
                    s_brate = s_brate * decay + one_m * sz / dt
                    s_last = ts
                if s_brate > 1e-9:
                    b = f_brate / s_brate
                    if b > peak:
                        peak = b
            fast._last_ts, fast._rate, fast._brate = f_last, f_rate, f_brate
            slow._last_ts, slow._rate, slow._brate = s_last, s_rate, s_brate
            self.peak_burst = peak
        qs = (kinds == EventKind.QUEUE_SAMPLE) & (batch.meta
                                                  == META_DIR_INGRESS)
        if qs.any():
            depths = batch.depth[qs]
            d = int(depths.max())
            if d > self.peak_depth:
                self.peak_depth = d
            self.queue.update_many(depths.astype(np.float64).tolist())

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        b, depth = self.peak_burst, self.peak_depth
        self.peak_burst, self.peak_depth = 0.0, 0
        qz = self.queue.zscore(float(depth))
        # burst alone is normal traffic; burst + REAL backlog is the
        # pathology (absolute depth floor rejects transient 1-2 deep queues)
        if b > 4.0 and qz > self.cfg.z_warn and depth >= 24:
            return [self._mk(now, score=qz, burstiness=b, queue_depth=depth)]
        return []


class IngressStarvation(Detector):
    """3a.2 — long gaps between ingress packets for some flows."""

    name = "ingress_starvation"
    table = "3a"
    stage = "ingress -> PCIe feed"
    root_cause = "upstream service jitter / uneven client distribution"
    directive = "balance load-balancer hashing; check NIC RSS/flow steering"
    interested = frozenset({EventKind.INGRESS_PKT})

    # freeze the p99-gap reference after warmup: a slow drift toward
    # starvation must not teach the tracker that long gaps are normal,
    # and steady-state ingress stops paying the quantile sketch
    P99_FREEZE = 512

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.per_node: dict[int, GapTracker] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        self.per_node.setdefault(
            ev.node, GapTracker(p99_cap=self.P99_FREEZE)).update(ev.ts)

    def update_batch(self, batch: EventBatch) -> None:
        self.events_seen += len(batch)
        buckets: dict[int, list[float]] = {}
        for node, ts in zip(batch.node.tolist(), batch.ts.tolist()):
            b = buckets.get(node)
            if b is None:
                buckets[node] = [ts]
            else:
                b.append(ts)
        per_node = self.per_node
        for node, tss in buckets.items():
            gt = per_node.get(node)
            if gt is None:
                gt = per_node[node] = GapTracker(p99_cap=self.P99_FREEZE)
            gt.update_many(tss)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for node, gt in self.per_node.items():
            base = max(gt.p99.value, 1e-6)
            open_gap = gt.current_gap(now)
            if gt.gaps.n >= 16 and open_gap > self.cfg.starvation_factor * base:
                out.append(self._mk(now, score=open_gap / base, node=node,
                                    open_gap=open_gap, p99_gap=base))
        return out


class FlowSkewAcrossSessions(Detector):
    """3a.3 — some ingress flows high-volume, others sparse."""

    name = "flow_skew_across_sessions"
    table = "3a"
    stage = "ingress (per-request)"
    root_cause = "session-affinity mismatch / QUIC stream imbalance"
    directive = "verify flow hashing; rebalance RPC streams"
    interested = frozenset({EventKind.INGRESS_PKT})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.flow_bytes: dict[int, int] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.flow >= 0:
            self.flow_bytes[ev.flow] = self.flow_bytes.get(ev.flow, 0) + ev.size

    def update_batch(self, batch: EventBatch) -> None:
        self.events_seen += len(batch)
        flows = batch.flow
        m = flows >= 0
        if not m.any():
            return
        fb = self.flow_bytes
        get = fb.get
        for f, s in zip(flows[m].tolist(), batch.size[m].tolist()):
            fb[f] = get(f, 0) + s

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events or len(self.flow_bytes) < 4:
            return []
        w = Welford()
        for v in self.flow_bytes.values():
            w.update(float(v))
        cv = w.cv()
        if cv > self.cfg.skew_cv_crit:
            sev = "critical" if cv > 2 * self.cfg.skew_cv_crit else "warn"
            return [self._mk(now, score=cv, severity=sev, cv=cv,
                             n_flows=len(self.flow_bytes))]
        return []


class _RetransmitBase(Detector):
    """Shared logic for retransmit-rate rows (3a.4, 3a.7, 3c.6).

    Fires when the retransmit count exceeds a few percent of the matching
    traffic class's count over the recent window — the denominator is the
    traffic class the retransmits belong to, not the whole event stream.
    Both counters halve at every poll (exponential forgetting), the classic
    DPU counter idiom: two integer adds per event on the line-rate path, a
    division only on the control path.
    """

    direction = META_DIR_INGRESS
    traffic_kind = EventKind.INGRESS_PKT
    interested = frozenset({EventKind.RETRANSMIT, EventKind.INGRESS_PKT,
                            EventKind.EGRESS_PKT, EventKind.COLLECTIVE_BURST})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.retx_win = 0        # retransmits in the decaying window
        self.traffic_win = 0     # matching traffic in the window
        self.retrans = 0         # all-time retransmits (absolute floor)
        self.retrans_nodes: dict[int, int] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.kind == EventKind.RETRANSMIT and ev.meta == self.direction:
            self.retrans += 1
            self.retx_win += 1
            self.retrans_nodes[ev.node] = self.retrans_nodes.get(ev.node, 0) + 1
        elif ev.kind == self.traffic_kind:
            self.traffic_win += 1

    def update_batch(self, batch: EventBatch) -> None:
        self.events_seen += len(batch)
        kinds = batch.kind
        retx = (kinds == EventKind.RETRANSMIT) & (batch.meta
                                                  == self.direction)
        if retx.any():
            nodes = batch.node[retx].tolist()
            rn = self.retrans_nodes
            get = rn.get
            for node in nodes:
                rn[node] = get(node, 0) + 1
            self.retrans += len(nodes)
            self.retx_win += len(nodes)
        self.traffic_win += int((kinds == self.traffic_kind).sum())

    def poll(self, now: float) -> list[Finding]:
        retx_w = self.retx_win
        traffic_w = self.traffic_win
        # exponential forgetting on EVERY poll, including warmup/quiet ones:
        # a late-onset fault must be judged against the recent window, not
        # diluted by the whole undecayed healthy history
        self.retx_win //= 2
        self.traffic_win //= 2
        if self.events_seen < self.cfg.min_events or self.retrans < 8:
            return []
        ratio = retx_w / max(traffic_w, 1)
        if ratio > 0.02 and retx_w >= 4:
            node = max(self.retrans_nodes, key=self.retrans_nodes.__getitem__,
                       default=-1)
            sev = "critical" if ratio > 0.10 else "warn"
            return [self._mk(now, score=ratio * 100, node=node, severity=sev,
                             retransmit_ratio=ratio,
                             retransmits=self.retrans)]
        return []


class IngressDropRetransmit(_RetransmitBase):
    """3a.4 — missing/retransmitted initial packets."""

    name = "ingress_drop_retransmit"
    table = "3a"
    stage = "ingress (request birth)"
    root_cause = "congestion / MTU mismatch / link errors"
    directive = "enable NIC offloads (TSO/GRO); verify MTU; check cabling"
    direction = META_DIR_INGRESS
    traffic_kind = EventKind.INGRESS_PKT


class EgressBacklogQueueing(Detector):
    """3a.5 — responses accumulate in NIC queues before send."""

    name = "egress_backlog_queueing"
    table = "3a"
    stage = "egress (response flush)"
    root_cause = "CPU copy bottleneck / NIC buffer exhaustion"
    directive = "offload checksums; zero-copy send; increase NIC buffers"
    interested = frozenset({EventKind.QUEUE_SAMPLE})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.per_node: dict[int, CUSUM] = {}
        self.depths: dict[int, int] = {}

    def update(self, ev: Event) -> None:
        if ev.kind != EventKind.QUEUE_SAMPLE or ev.meta != META_DIR_EGRESS:
            return
        self.events_seen += 1
        self.per_node.setdefault(ev.node, CUSUM(threshold=4.0)).update(
            float(ev.depth))
        self.depths[ev.node] = ev.depth

    def update_batch(self, batch: EventBatch) -> None:
        m = (batch.kind == EventKind.QUEUE_SAMPLE) & (batch.meta
                                                      == META_DIR_EGRESS)
        cnt = int(m.sum())
        if cnt == 0:
            return
        self.events_seen += cnt
        per_node = self.per_node
        depths = self.depths
        for node, dep in zip(batch.node[m].tolist(), batch.depth[m].tolist()):
            cs = per_node.get(node)
            if cs is None:
                cs = per_node[node] = CUSUM(threshold=4.0)
            cs.update(float(dep))
            depths[node] = dep

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for node, cs in self.per_node.items():
            if cs.stat > cs.threshold:
                out.append(self._mk(now, score=cs.stat, node=node,
                                    queue_depth=self.depths.get(node, 0)))
        return out


class EgressJitter(Detector):
    """3a.6 — outgoing packets for a token stream spread unevenly."""

    name = "egress_jitter"
    table = "3a"
    stage = "egress (decode outputs)"
    root_cause = "scheduler variance / CPU<->NIC contention"
    directive = "isolate runtime threads; pin NIC IRQs; widen batching window"
    interested = frozenset({EventKind.EGRESS_PKT})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        # jitter is CV-of-gaps; the p99 sketch is never read, so don't pay
        # for it on the hottest per-flow path in the plane
        self.per_flow: dict[int, GapTracker] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        self.per_flow.setdefault(
            ev.flow, GapTracker(track_p99=False)).update(ev.ts)

    def update_batch(self, batch: EventBatch) -> None:
        self.events_seen += len(batch)
        buckets: dict[int, list[float]] = {}
        for f, ts in zip(batch.flow.tolist(), batch.ts.tolist()):
            b = buckets.get(f)
            if b is None:
                buckets[f] = [ts]
            else:
                b.append(ts)
        per_flow = self.per_flow
        for f, tss in buckets.items():
            gt = per_flow.get(f)
            if gt is None:
                gt = per_flow[f] = GapTracker(track_p99=False)
            gt.update_many(tss)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        jittery, n = [], 0
        for flow, gt in self.per_flow.items():
            if gt.gaps.n < 16:
                continue
            n += 1
            j = gt.jitter()
            if j > 1.2 * self.cfg.jitter_warn:
                jittery.append((flow, j))
        if n > 0 and len(jittery) >= max(1, n // 4):
            worst = max(j for _, j in jittery)
            return [self._mk(now, score=worst, jittery_flows=len(jittery),
                             flows_measured=n)]
        return []


class EgressDropRetransmit(_RetransmitBase):
    """3a.7 — retransmissions/gaps in final response streams."""

    name = "egress_drop_retransmit"
    table = "3a"
    stage = "egress"
    root_cause = "NIC offload misconfig / fabric congestion / buffer underrun"
    directive = "check offload settings; enable congestion control (ECN/PFC)"
    direction = META_DIR_EGRESS
    traffic_kind = EventKind.EGRESS_PKT


class EarlyCompletionSkew(Detector):
    """3a.8 — some egress flows terminate far earlier than peers."""

    name = "early_completion_skew"
    table = "3a"
    stage = "egress (multi-stream decode)"
    root_cause = "early-stop on short sequences; no remap of freed resources"
    directive = "enable inflight remapping / load stealing for decode"
    interested = frozenset({EventKind.EGRESS_PKT})

    WINDOW = 0.05           # seconds per activity window
    DECAY_WINDOWS = 6       # consecutive low windows before firing
    LOW_FRAC = 0.5          # "low" = active flows < this fraction of peak

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        # per group: (window_start, flows_this_window, peak, low_streak)
        self.state: dict[int, list] = {}
        self.pending: dict[int, tuple[float, int, int]] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        st = self.state.get(ev.group)
        if st is None:
            # [window_start, flows, decayed_peak, low_streak, abs_peak]
            st = [ev.ts, set(), 0.0, 0, 0]
            self.state[ev.group] = st
        if ev.ts - st[0] >= self.WINDOW:
            n = len(st[1])
            if n > 0:
                # a healthy engine keeps slots refilled: the number of
                # distinct streaming flows per window stays near its peak.
                # Early-completion skew shows as a *sustained* decay while
                # the group keeps emitting.
                st[2] = max(st[2] * 0.995, float(n))
                st[4] = max(st[4], n)
                if n < self.LOW_FRAC * st[2] and st[4] >= 4:
                    st[3] += 1
                else:
                    st[3] = 0
                if st[3] >= self.DECAY_WINDOWS:
                    self.pending[ev.group] = (ev.ts, n, st[4])
            st[0] = ev.ts
            st[1] = set()
        st[1].add(ev.flow)

    def update_batch(self, batch: EventBatch) -> None:
        self.events_seen += len(batch)
        state = self.state
        pending = self.pending
        window = self.WINDOW
        low = self.LOW_FRAC
        decay_windows = self.DECAY_WINDOWS
        for g, ts, f in zip(batch.group.tolist(), batch.ts.tolist(),
                            batch.flow.tolist()):
            st = state.get(g)
            if st is None:
                st = state[g] = [ts, set(), 0.0, 0, 0]
            if ts - st[0] >= window:
                n = len(st[1])
                if n > 0:
                    st[2] = max(st[2] * 0.995, float(n))
                    if n > st[4]:
                        st[4] = n
                    if n < low * st[2] and st[4] >= 4:
                        st[3] += 1
                    else:
                        st[3] = 0
                    if st[3] >= decay_windows:
                        pending[g] = (ts, n, st[4])
                st[0] = ts
                st[1] = set()
            st[1].add(f)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events or not self.pending:
            return []
        out = []
        for g, (ts, n, peak) in self.pending.items():
            done_frac = 1.0 - n / max(peak, 1)
            out.append(self._mk(
                now, score=done_frac * 10, node=-1,
                severity="critical" if done_frac >= 0.7 else "warn",
                group=g, active_flows=n, peak_flows=peak,
                done_frac=done_frac))
        self.pending.clear()
        return out


class BandwidthSaturation(Detector):
    """3a.9 — NIC RX/TX at or near link capacity with queue buildup."""

    name = "ingress_egress_bandwidth_saturation"
    table = "3a"
    stage = "ingress + egress"
    root_cause = "shared NIC with storage/other jobs; insufficient link"
    directive = "upgrade NIC; QoS partitioning; stagger workloads"
    interested = frozenset({EventKind.INGRESS_PKT, EventKind.EGRESS_PKT,
                            EventKind.QUEUE_SAMPLE})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        # NIC-style byte counters: utilization = counter delta / interval.
        # (Robust to interleaved event classes, unlike instantaneous rates.)
        self.bytes: dict[int, int] = {}
        self.depth: dict[int, int] = {}
        self.last_poll: float | None = None

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.kind == EventKind.QUEUE_SAMPLE:
            self.depth[ev.node] = max(self.depth.get(ev.node, 0), ev.depth)
        else:
            self.bytes[ev.node] = self.bytes.get(ev.node, 0) + ev.size

    def update_batch(self, batch: EventBatch) -> None:
        self.events_seen += len(batch)
        qs = batch.kind == EventKind.QUEUE_SAMPLE
        if qs.any():
            depth = self.depth
            get = depth.get
            nodes = batch.node[qs]
            depths = batch.depth[qs]
            for node in np.unique(nodes).tolist():
                dep = int(depths[nodes == node].max())
                cur = get(node, 0)
                depth[node] = dep if dep > cur else cur
        rest = ~qs
        if rest.any():
            byts = self.bytes
            get = byts.get
            nodes = batch.node[rest]
            sizes = batch.size[rest]
            # per-node int64 sums: exact (integer accumulator), and the
            # poll below iterates nodes in sorted order so the dict's
            # insertion order cannot diverge between scalar and batch paths
            for node in np.unique(nodes).tolist():
                byts[node] = get(node, 0) + int(sizes[nodes == node].sum())

    def poll(self, now: float) -> list[Finding]:
        out: list[Finding] = []
        if self.last_poll is not None and now > self.last_poll:
            dt = now - self.last_poll
            if self.events_seen >= self.cfg.min_events:
                for node, nbytes in sorted(self.bytes.items()):
                    frac = nbytes / dt / self.cfg.nic_Bps
                    if (frac > self.cfg.saturation_frac
                            and self.depth.get(node, 0) > 0):
                        out.append(self._mk(
                            now, score=frac * 10, node=node,
                            severity="critical" if frac > 1.0 else "warn",
                            link_utilization=frac,
                            queue_depth=self.depth.get(node, 0)))
        self.last_poll = now
        self.bytes.clear()
        self.depth.clear()
        return out


# ======================================================================
# Table 3(b) — PCIe observer runbook
# ======================================================================


class H2DDataStarvation(Detector):
    """3b.1 — clustered H2D DMAs then long gaps before dispatches."""

    name = "h2d_data_starvation"
    table = "3b"
    stage = "ingress -> PCIe (prefill & decode input feed)"
    root_cause = "PCIe BW cap / NUMA miss / pageable (unpinned) host buffers"
    directive = "pin memory; bind NUMA socket; verify PCIe link width/speed"
    interested = frozenset({EventKind.H2D_XFER, EventKind.INGRESS_PKT})

    REF_SAMPLES = 256    # freeze the healthy gap reference after this many

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.h2d_gap: dict[tuple[int, int], GapTracker] = {}
        self.ref: dict[tuple[int, int], float] = {}
        self.ingress_live: dict[int, float] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.kind == EventKind.INGRESS_PKT:
            self.ingress_live[ev.node] = ev.ts
        else:
            key = (ev.node, ev.device)
            # p99 is only read until the healthy reference freezes; cap the
            # quantile sketch there so steady-state DMAs stop paying for it
            gt = self.h2d_gap.setdefault(
                key, GapTracker(p99_cap=self.REF_SAMPLES))
            gt.update(ev.ts)
            if gt.gaps.n == self.REF_SAMPLES:
                # freeze a healthy reference so a sustained stall can't
                # teach the tracker that stalls are normal
                self.ref[key] = max(gt.p99.value, 1e-6)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for (node, dev), gt in self.h2d_gap.items():
            if gt.gaps.n < 16:
                continue
            base = self.ref.get((node, dev), max(gt.p99.value, 1e-6))
            gap = max(gt.current_gap(now), gt.gaps.mean)
            # "recent" on the ingress timescale (requests are sparser than
            # per-step DMAs), not the H2D timescale
            ingress_recent = now - self.ingress_live.get(node, -1e9) < 0.25
            # starving: requests keep arriving but the device feed went quiet
            if ingress_recent and gap > self.cfg.starvation_factor * base:
                out.append(self._mk(now, score=gap / base, node=node,
                                    device=dev, open_gap=gap, p99_gap=base))
        return out


class D2HReturnBottleneck(Detector):
    """3b.2 — D2H DMAs linger; backlog after dispatches."""

    name = "d2h_return_bottleneck"
    table = "3b"
    stage = "egress (logits/tokens back to host)"
    root_cause = "PCIe saturation / IOMMU contention / CPU copy hotspots"
    directive = "large pinned buffers; reduce copies; check IOMMU/ATS"
    interested = frozenset({EventKind.DISPATCH, EventKind.D2H_XFER})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        # dispatch->return latency per device
        self.pending: dict[tuple[int, int], list[float]] = {}
        self.lat: dict[tuple[int, int], CUSUM] = {}
        self.last_lat: dict[tuple[int, int], float] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        key = (ev.node, ev.device)
        if ev.kind == EventKind.DISPATCH:
            q = self.pending.setdefault(key, [])
            q.append(ev.ts)
            if len(q) > 64:           # bounded state (DPU constraint)
                del q[:32]
        else:
            q = self.pending.get(key)
            if q:
                lat = ev.ts - q.pop(0)
                self.last_lat[key] = lat
                self.lat.setdefault(
                    key, CUSUM(threshold=6.0, rel_slack=0.2)).update(lat)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for key, cs in self.lat.items():
            backlog = len(self.pending.get(key, []))
            if cs.stat > cs.threshold:
                out.append(self._mk(
                    now, score=cs.stat, node=key[0], device=key[1],
                    severity="critical" if backlog > 2 else "warn",
                    backlog=backlog,
                    last_latency=self.last_lat.get(key, 0.0)))
                cs.stat *= 0.5   # hysteresis: decay after reporting
        return out


class KernelLaunchLatency(Detector):
    """3b.3 — sporadic doorbells; idle gaps between H2D and next launch."""

    name = "kernel_launch_control_latency"
    table = "3b"
    stage = "compute (device underutilized across prefill/decode)"
    root_cause = "runtime overhead / CPU scheduler delays / too many tiny kernels"
    directive = "batch ops; fuse kernels; raise launch queues; isolate CPU cores"
    interested = frozenset({EventKind.DISPATCH, EventKind.H2D_XFER})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.dispatch_gap: dict[tuple[int, int], GapTracker] = {}
        self.h2d_last: dict[tuple[int, int], float] = {}
        self.h2d_to_dispatch: dict[tuple[int, int], EWMA] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        key = (ev.node, ev.device)
        if ev.kind == EventKind.H2D_XFER:
            self.h2d_last[key] = ev.ts
        else:
            self.dispatch_gap.setdefault(
                key, GapTracker(track_p99=False)).update(ev.ts)
            if key in self.h2d_last:
                self.h2d_to_dispatch.setdefault(key, EWMA(0.05)).update(
                    ev.ts - self.h2d_last[key])

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for key, gt in self.dispatch_gap.items():
            lag = self.h2d_to_dispatch.get(key)
            if gt.gaps.n < 16 or lag is None or lag.n < 8:
                continue
            # data arrived but launches are late & irregular
            z = lag.zscore(lag.mean + lag.std * 0)  # stable baseline measure
            if gt.jitter() > self.cfg.jitter_crit and lag.mean > 4 * max(
                    gt.gaps.mean, 1e-9):
                out.append(self._mk(now, score=gt.jitter(), node=key[0],
                                    device=key[1], dispatch_jitter=gt.jitter(),
                                    h2d_to_dispatch=lag.mean))
        return out


class IntraNodeGpuSkew(Detector):
    """3b.4 — one device shows thin/irregular DMA while peers are steady."""

    name = "intra_node_gpu_skew"
    table = "3b"
    stage = "compute (per-layer) -> propagates to internode"
    root_cause = "uneven microbatching / memory pressure on a single device"
    directive = "rebalance microbatches; unify stream priorities; check clocks"
    interested = frozenset({EventKind.H2D_XFER, EventKind.D2H_XFER})

    HALFLIFE = 1.0       # decay of per-device byte counters (seconds);
                         # long enough that Poisson prefill-placement noise
                         # averages out (~75 prefills/node per halflife)
    PERSIST = 4          # consecutive skewed polls before firing

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        # node -> dev -> (decayed_bytes, last_ts)
        self.bytes: dict[int, dict[int, list[float]]] = {}
        self.streak: dict[int, int] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        devs = self.bytes.setdefault(ev.node, {})
        cell = devs.get(ev.device)
        if cell is None:
            devs[ev.device] = [float(ev.size), ev.ts]
        else:
            decay = 0.5 ** ((ev.ts - cell[1]) / self.HALFLIFE)
            cell[0] = cell[0] * decay + ev.size
            cell[1] = ev.ts

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for node, devs in self.bytes.items():
            if len(devs) < 2:
                continue
            w = Welford()
            vals = {}
            for dev, (v, ts) in devs.items():
                decayed = v * 0.5 ** ((now - ts) / self.HALFLIFE)
                vals[dev] = decayed
                w.update(decayed)
            cv = w.cv()
            if cv > self.cfg.skew_cv_warn:
                self.streak[node] = self.streak.get(node, 0) + 1
            else:
                self.streak[node] = 0
            # transient skew (a prefill burst landing on one device) washes
            # out; persistent skew across polls is the pathology
            if self.streak[node] >= self.PERSIST:
                lagger = min(vals, key=vals.__getitem__)
                sev = "critical" if cv > self.cfg.skew_cv_crit else "warn"
                out.append(self._mk(now, score=cv * 10, node=node,
                                    device=lagger, severity=sev, cv=cv))
        return out


class PCIeLinkSaturation(Detector):
    """3b.5 — sustained near-peak PCIe throughput; periodic compute stalls."""

    name = "pcie_link_saturation"
    table = "3b"
    stage = "ingress -> PCIe, egress"
    root_cause = "oversubscribed PCIe switch / x8 link / competing DMAs"
    directive = "verify x16 lanes; move devices off shared switch; stagger I/O"
    interested = frozenset({EventKind.H2D_XFER, EventKind.D2H_XFER})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.bytes: dict[int, int] = {}
        self.sustained: dict[int, int] = {}
        self.last_poll: float | None = None

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        self.bytes[ev.node] = self.bytes.get(ev.node, 0) + ev.size

    def update_batch(self, batch: EventBatch) -> None:
        self.events_seen += len(batch)
        byts = self.bytes
        get = byts.get
        nodes = batch.node
        sizes = batch.size
        for node in np.unique(nodes).tolist():
            byts[node] = get(node, 0) + int(sizes[nodes == node].sum())

    def poll(self, now: float) -> list[Finding]:
        out: list[Finding] = []
        if self.last_poll is not None and now > self.last_poll:
            dt = now - self.last_poll
            if self.events_seen >= self.cfg.min_events:
                for node, nbytes in sorted(self.bytes.items()):
                    frac = nbytes / dt / self.cfg.pcie_Bps
                    if frac > self.cfg.saturation_frac:
                        self.sustained[node] = self.sustained.get(node, 0) + 1
                    else:
                        self.sustained[node] = 0
                    if self.sustained.get(node, 0) >= 3:  # sustained polls
                        out.append(self._mk(now, score=frac * 10, node=node,
                                            link_utilization=frac))
        self.last_poll = now
        self.bytes.clear()
        return out


class GpuP2PThrottling(Detector):
    """3b.6 — intra-node P2P DMAs slow/variable (no NVLink path)."""

    name = "gpu_p2p_throttling"
    table = "3b"
    stage = "compute (intra-box TP/PP)"
    root_cause = "shared uplink on PCIe switch; ACS/ATS settings"
    directive = "prefer NVLink/NVSwitch; same-switch placement; tune ACS/ATS"
    interested = frozenset({EventKind.P2P_BURST})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        # effective bandwidth per burst: size / duration(meta-encoded?) — the
        # sim reports burst durations via paired events; here we use the gap
        # between same-flow bursts vs size as a throughput proxy.
        self.tput: dict[int, EWMA] = {}
        self.last: dict[tuple[int, int], float] = {}
        self.baseline = EWMA(0.02)

    def update(self, ev: Event) -> None:
        if ev.meta != META_P2P_INTRA:
            return
        self.events_seen += 1
        key = (ev.node, ev.flow)
        if key in self.last:
            dt = max(ev.ts - self.last[key], 1e-9)
            tput = ev.size / dt
            self.tput.setdefault(ev.node, EWMA(0.1)).update(tput)
            self.baseline.update(tput)
        self.last[key] = ev.ts

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events or self.baseline.n < 16:
            return []
        out = []
        for node, ew in self.tput.items():
            if ew.n < 8:
                continue
            # a node sustaining < half the cluster-median p2p throughput
            if ew.mean < 0.5 * self.baseline.mean:
                ratio = self.baseline.mean / max(ew.mean, 1e-9)
                out.append(self._mk(now, score=ratio, node=node,
                                    node_tput=ew.mean,
                                    cluster_tput=self.baseline.mean))
        return out


class PinnedMemoryShortage(Detector):
    """3b.7 — many small DMAs instead of large coalesced ones."""

    name = "pinned_memory_shortage"
    table = "3b"
    stage = "ingress -> PCIe (feed) and egress (returns)"
    root_cause = "insufficient pinned pools; fallback to pageable buffers"
    directive = "pre-allocate larger pinned pools; coalesce transfers"
    interested = frozenset({EventKind.H2D_XFER, EventKind.D2H_XFER})

    LOG_SHRINK = 1.5   # fire when mean log-size drops this much (~4.5x)

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        # log-domain size tracking: the median-ish typical DMA size is what
        # matters; log-mean is robust to the huge prefill-vs-decode spread
        self.logsize: dict[int, EWMA] = {}
        self.ref: dict[int, float] = {}
        self.rate: dict[int, RateMeter] = {}

    def update(self, ev: Event) -> None:
        import math as _m
        self.events_seen += 1
        ew = self.logsize.setdefault(ev.node, EWMA(0.02))
        ew.update(_m.log(max(ev.size, 1)))
        if ew.n == 256:  # freeze a healthy-size reference after warmup
            self.ref[ev.node] = ew.mean
        self.rate.setdefault(ev.node, RateMeter(halflife=0.1)).update(ev.ts)

    def poll(self, now: float) -> list[Finding]:
        import math as _m
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for node, ew in self.logsize.items():
            ref = self.ref.get(node)
            if ref is None:
                continue
            drop = ref - ew.mean
            if drop > self.LOG_SHRINK:
                out.append(self._mk(
                    now, score=drop,
                    severity="critical" if drop > 2.5 else "warn",
                    node=node, typical_bytes=_m.exp(ew.mean),
                    baseline_bytes=_m.exp(ref),
                    dma_rate=self.rate[node].rate))
        return out


class HostCpuBottleneck(Detector):
    """3b.8 — low DMA rate despite available PCIe bandwidth; late doorbells."""

    name = "host_cpu_bottleneck"
    table = "3b"
    stage = "compute orchestration"
    root_cause = "CPU contention / IRQ affinity / polling disabled"
    directive = "isolate IRQs/threads; busy-poll; pin runtime threads"
    interested = frozenset({EventKind.H2D_XFER, EventKind.DISPATCH,
                            EventKind.INGRESS_PKT})

    REF_SAMPLES = 256

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.dma_bytes: dict[int, int] = {}
        self.dma_base: dict[int, EWMA] = {}
        self.disp_gap: dict[int, GapTracker] = {}
        self.disp_ref: dict[int, float] = {}
        self.last_poll: float | None = None

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.kind == EventKind.H2D_XFER:
            self.dma_bytes[ev.node] = self.dma_bytes.get(ev.node, 0) + ev.size
        elif ev.kind == EventKind.DISPATCH:
            gt = self.disp_gap.setdefault(
                ev.node, GapTracker(p99_cap=self.REF_SAMPLES))
            gt.update(ev.ts)
            if gt.gaps.n == self.REF_SAMPLES:
                self.disp_ref[ev.node] = max(gt.p99.value, 1e-6)

    def poll(self, now: float) -> list[Finding]:
        out: list[Finding] = []
        if self.last_poll is not None and now > self.last_poll:
            dt = now - self.last_poll
            for node, nbytes in self.dma_bytes.items():
                cur = nbytes / dt
                base = self.dma_base.setdefault(node, EWMA(0.2))
                gt = self.disp_gap.get(node)
                sagging = base.n >= 2 and cur < 0.4 * base.mean
                if (sagging and self.events_seen >= self.cfg.min_events
                        and gt is not None and gt.gaps.n > 8):
                    pcie_headroom = cur < 0.3 * self.cfg.pcie_Bps
                    ref = self.disp_ref.get(node, max(gt.p99.value, 1e-6))
                    starved_dispatch = (
                        max(gt.current_gap(now), gt.gaps.mean) > 3 * ref)
                    if pcie_headroom and starved_dispatch:
                        score = base.mean / max(cur, 1e-9)
                        out.append(self._mk(
                            now, score=min(score, 100.0), node=node,
                            dma_byte_rate=cur, baseline=base.mean))
                if base.n < 2 or not sagging:
                    # never learn the baseline from a sagging window — the
                    # pathology must not poison its own reference
                    base.update(cur)
        self.last_poll = now
        self.dma_bytes.clear()
        return out


class MemoryRegistrationChurn(Detector):
    """3b.9 — frequent map/unmap patterns around DMAs."""

    name = "memory_registration_churn"
    table = "3b"
    stage = "ingress -> PCIe"
    root_cause = "repeated registration of short-lived buffers"
    directive = "reuse registered buffers; GPUDirect with persistent MR"
    interested = frozenset({EventKind.MEM_REG, EventKind.H2D_XFER,
                            EventKind.D2H_XFER})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.reg: dict[int, int] = {}
        self.dma: dict[int, int] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.kind == EventKind.MEM_REG:
            self.reg[ev.node] = self.reg.get(ev.node, 0) + 1
        else:
            self.dma[ev.node] = self.dma.get(ev.node, 0) + 1

    def update_batch(self, batch: EventBatch) -> None:
        self.events_seen += len(batch)
        reg = batch.kind == EventKind.MEM_REG
        for target, m in ((self.reg, reg), (self.dma, ~reg)):
            if m.any():
                get = target.get
                for node in batch.node[m].tolist():
                    target[node] = get(node, 0) + 1

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for node, regs in list(self.reg.items()):
            dmas = self.dma.get(node, 0)
            if dmas < 16:
                continue
            ratio = regs / dmas
            if ratio > 0.5:  # healthy runtimes register once, DMA many times
                out.append(self._mk(
                    now, score=ratio * 10, node=node,
                    severity="critical" if ratio > 1.0 else "warn",
                    reg_per_dma=ratio, registrations=regs, dmas=dmas))
            # exponential forgetting: judge recent windows, not all history
            self.reg[node] = regs // 2
            self.dma[node] = dmas // 2
        return out


class DecodeEarlyStopSkew(Detector):
    """3b.10 — D2H drops off early on some streams/devices."""

    name = "decode_early_stop_skew"
    table = "3b"
    stage = "compute (decode) -> egress"
    root_cause = "sequence-length variance; scheduler not rebalancing"
    directive = "inflight request remapping/packing; speculative decode policies"
    interested = frozenset({EventKind.D2H_XFER})

    REF_SAMPLES = 128

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.last: dict[tuple[int, int], float] = {}
        self.gap: dict[tuple[int, int], GapTracker] = {}
        self.ref: dict[tuple[int, int], float] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        key = (ev.node, ev.device)
        self.last[key] = ev.ts
        gt = self.gap.setdefault(key, GapTracker(track_p99=False))
        gt.update(ev.ts)
        if gt.gaps.n == self.REF_SAMPLES:
            self.ref[key] = max(gt.gaps.mean, 1e-6)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events or len(self.last) < 2:
            return []
        out = []
        by_node: dict[int, list[tuple[int, float]]] = {}
        for (node, dev), ts in self.last.items():
            by_node.setdefault(node, []).append((dev, ts))
        for node, devs in by_node.items():
            if len(devs) < 2:
                continue
            tss = [t for _, t in devs]
            newest = max(tss)
            for dev, ts in devs:
                gt = self.gap[(node, dev)]
                if gt.gaps.n < 16:
                    continue
                typical = self.ref.get((node, dev), max(gt.gaps.mean, 1e-6))
                silence = newest - ts
                # device went silent many decode-steps ago while peers
                # stream; the absolute floor rejects transient slot dips
                # that continuous batching refills within a poll or two
                if silence > max(self.cfg.starvation_factor * typical, 0.25):
                    out.append(self._mk(now, score=silence / typical,
                                        node=node, device=dev,
                                        silence=silence, step_gap=typical))
        return out


# ======================================================================
# Table 3(c) — East-West sensing runbook
# ======================================================================


class TPStraggler(Detector):
    """3c.1 — wide arrival spread of collective bursts (max-min gap up)."""

    name = "tp_straggler"
    table = "3c"
    stage = "compute (tensor-parallel collectives)"
    root_cause = "skewed device load / PCIe starvation / memory imbalance on one node"
    directive = "rebalance shards; check per-node PCIe feeds; adjust affinity"
    interested = frozenset({EventKind.COLLECTIVE_BURST})

    def __init__(self, cfg: DetectorConfig, group_size: int = 0) -> None:
        super().__init__(cfg)
        self.spread: dict[int, SpreadTracker] = {}
        self.members: dict[int, set[int]] = {}
        self.group_size = group_size

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if _ext_group(ev.group):
            return
        members = self.members.setdefault(ev.group, set())
        members.add(ev.node)
        st = self.spread.get(ev.group)
        if st is None or st.expected != max(self.group_size, len(members)):
            st = SpreadTracker(expected=max(self.group_size, len(members)))
            self.spread[ev.group] = st
        st.update(ev.meta, ev.node, ev.ts)   # meta carries the round id

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for group, st in self.spread.items():
            counted = sum(st.late_counts.values())
            if st.rounds < 32 or counted < 16:
                continue
            worst = max(st.late_counts, key=st.late_counts.__getitem__)
            frac = st.late_counts[worst] / counted
            straggler = worst
            # one participant is consistently last AND the spread is a large
            # fraction of the inter-round period
            if frac > 0.6 and st.spread.mean > 0:
                z = st.spread.zscore(st.spread.mean + 2 * st.spread.std)
                out.append(self._mk(
                    now, score=frac * 10, node=straggler,
                    severity="critical" if frac > 0.85 else "warn",
                    group=group, straggler_frac=frac,
                    mean_spread=st.spread.mean))
        return out


class PPBubble(Detector):
    """3c.2 — large/growing gaps between stage-handoff bursts."""

    name = "pp_bubble_stage_stall"
    table = "3c"
    stage = "pipeline parallel"
    root_cause = "load imbalance across pipeline stages; early token-exit variance"
    directive = "adjust microbatch partitioning; reassign stages; speculative fill"
    interested = frozenset({EventKind.P2P_BURST})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.gap: dict[int, GapTracker] = {}     # stage-pair group -> gaps
        self.cusum: dict[int, CUSUM] = {}

    def update(self, ev: Event) -> None:
        if ev.meta != META_P2P_INTER:
            return
        self.events_seen += 1
        g = ev.group
        gap = self.gap.setdefault(g, GapTracker(track_p99=False)).gaps
        closed = self.gap[g].update(ev.ts)
        if closed > 0:
            self.cusum.setdefault(g, CUSUM(threshold=5.0)).update(closed)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for g, cs in self.cusum.items():
            if cs.stat > cs.threshold:
                gt = self.gap[g]
                out.append(self._mk(now, score=cs.stat, group=g,
                                    mean_gap=gt.gaps.mean,
                                    max_gap=gt.max_gap))
        return out


class CrossNodeLoadSkew(Detector):
    """3c.3 — uneven traffic volume per node for the same collective."""

    name = "cross_node_load_skew"
    table = "3c"
    stage = "TP/PP compute -> internode"
    root_cause = "shard imbalance; misaligned activation partitioning"
    directive = "validate shard sizes; rebalance across nodes"
    interested = frozenset({EventKind.COLLECTIVE_BURST})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.bytes: dict[int, dict[int, float]] = {}   # group -> node -> bytes

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if _ext_group(ev.group):
            return
        nodes = self.bytes.setdefault(ev.group, {})
        nodes[ev.node] = nodes.get(ev.node, 0.0) + ev.size

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for group, nodes in self.bytes.items():
            if len(nodes) < 2:
                continue
            w = Welford()
            for v in nodes.values():
                w.update(v)
            cv = w.cv()
            if cv > self.cfg.skew_cv_warn:
                heavy = max(nodes, key=nodes.__getitem__)
                sev = "critical" if cv > self.cfg.skew_cv_crit else "warn"
                out.append(self._mk(now, score=cv * 10, node=heavy,
                                    severity=sev, group=group, cv=cv))
        return out


class NetworkCongestion(Detector):
    """3c.4 — periodic latency+jitter spikes across many links."""

    name = "network_congestion_oversubscription"
    table = "3c"
    stage = "internode transfers (collectives & stage handoff)"
    root_cause = "fat-tree oversubscription; ToR link hot spot"
    directive = "check fabric counters; adaptive routing; spread ranks"
    interested = frozenset({EventKind.COLLECTIVE_BURST, EventKind.P2P_BURST,
                            EventKind.QUEUE_SAMPLE})

    FABRIC_QUEUE = 2   # QUEUE_SAMPLE.meta for fabric queues

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.gap: dict[int, GapTracker] = {}       # per node
        self.fabric_depth = EWMA(0.05)
        self.last_depth = 0

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.kind == EventKind.QUEUE_SAMPLE:
            if ev.meta == self.FABRIC_QUEUE:
                self.fabric_depth.update(float(ev.depth))
                self.last_depth = ev.depth
            return
        if _ext_group(ev.group):
            return
        self.gap.setdefault(
            ev.node, GapTracker(track_p99=False)).update(ev.ts)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        jittery = 0
        measured = 0
        for gt in self.gap.values():
            if gt.gaps.n < 16:
                continue
            measured += 1
            if gt.jitter() > self.cfg.jitter_warn:
                jittery += 1
        qz = self.fabric_depth.zscore(float(self.last_depth))
        # cluster-wide: more than half the measured nodes turn jittery together
        if measured >= 2 and jittery >= max(2, measured // 2 + 1):
            score = jittery / measured * 10 + max(qz, 0.0)
            return [self._mk(now, score=score, jittery_nodes=jittery,
                             measured_nodes=measured,
                             fabric_queue_z=qz)]
        return []


class HeadOfLineBlocking(Detector):
    """3c.5 — some streams stall while others flow; out-of-order bursts."""

    name = "head_of_line_blocking"
    table = "3c"
    stage = "collective streams / P2P flows"
    root_cause = "shared queue-depth exhaustion; RoCE/NIC queue imbalance"
    directive = "increase NIC queue depth; QoS/ECN; verify fair sharing"
    interested = frozenset({EventKind.P2P_BURST, EventKind.COLLECTIVE_BURST})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.flow_gap: dict[int, GapTracker] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        key = ev.flow if ev.flow >= 0 else ev.group
        self.flow_gap.setdefault(key, GapTracker()).update(ev.ts)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        stalled, flowing = [], 0
        for flow, gt in self.flow_gap.items():
            if gt.gaps.n < 8:
                continue
            base = max(gt.p99.value, 1e-6)
            if gt.current_gap(now) > self.cfg.starvation_factor * base:
                stalled.append(flow)
            else:
                flowing += 1
        # HoL signature: a strict subset stalls while the rest flows
        if stalled and flowing > 0:
            frac = len(stalled) / (len(stalled) + flowing)
            if 0.05 < frac < 0.9:
                return [self._mk(now, score=len(stalled),
                                 severity="warn" if frac < 0.5 else "critical",
                                 stalled_flows=len(stalled),
                                 flowing_flows=flowing)]
        return []


class EWRetransmitStorm(_RetransmitBase):
    """3c.6 — gaps + duplicate traffic or sudden retransmit storms."""

    name = "retransmissions_packet_loss"
    table = "3c"
    stage = "all distributed phases"
    root_cause = "fabric errors / congestion collapse / misconfigured PFC"
    directive = "verify lossless config; tune buffer thresholds; check optics"
    direction = META_DIR_EW
    traffic_kind = EventKind.COLLECTIVE_BURST


class CreditStarvation(Detector):
    """3c.7 — long silences until remote credit updates arrive."""

    name = "credit_starvation"
    table = "3c"
    stage = "internode (RDMA ops)"
    root_cause = "too-small RDMA window; NIC credit depletion"
    directive = "increase QP window; tune flow-control params"
    interested = frozenset({EventKind.CREDIT_UPDATE, EventKind.P2P_BURST,
                            EventKind.COLLECTIVE_BURST})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.credit_gap: dict[int, GapTracker] = {}
        self.traffic: dict[int, RateMeter] = {}
        self.credits: dict[int, int] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.kind == EventKind.CREDIT_UPDATE:
            self.credit_gap.setdefault(
                ev.node, GapTracker(track_p99=False)).update(ev.ts)
            self.credits[ev.node] = ev.depth
        else:
            self.traffic.setdefault(ev.node, RateMeter(0.1)).update(
                ev.ts, ev.size)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        out = []
        for node, gt in self.credit_gap.items():
            if gt.gaps.n < 8:
                continue
            base = max(gt.gaps.mean, 1e-6)
            open_gap = gt.current_gap(now)
            low_credit = self.credits.get(node, 1 << 30) <= 1
            tr = self.traffic.get(node)
            link_quiet = tr is None or tr.byte_rate < 0.1 * self.cfg.ici_Bps
            if low_credit and link_quiet and open_gap > 4 * base:
                out.append(self._mk(now, score=open_gap / base, node=node,
                                    credit_gap=open_gap,
                                    credits=self.credits.get(node, 0)))
        return out


class KVCacheTransferBottleneck(Detector):
    """3c.8 — repeated large KV bursts for some tokens, others silent."""

    name = "kv_cache_transfer_bottleneck"
    table = "3c"
    stage = "decode phase (PP handoff)"
    root_cause = "sharded KV too large for link budget; non-uniform lengths"
    directive = "compress KV; shard differently; apply caching policies"
    interested = frozenset({EventKind.P2P_BURST})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.flow_bytes: dict[int, float] = {}
        self.burst_size = EWMA(0.05)
        self.rate = RateMeter(0.1)

    def update(self, ev: Event) -> None:
        if ev.meta != META_P2P_KV:
            return
        self.events_seen += 1
        self.flow_bytes[ev.flow] = self.flow_bytes.get(ev.flow, 0.0) + ev.size
        self.burst_size.update(float(ev.size))
        self.rate.update(ev.ts, ev.size)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events or len(self.flow_bytes) < 4:
            return []
        w = Welford()
        for v in self.flow_bytes.values():
            w.update(v)
        cv = w.cv()
        link_frac = self.rate.byte_rate / self.cfg.ici_Bps
        if cv > self.cfg.skew_cv_crit and link_frac > 0.3:
            return [self._mk(now, score=cv * 10, cv=cv,
                             link_utilization=link_frac,
                             mean_burst=self.burst_size.mean)]
        return []


class EarlyStopSkewAcrossNodes(Detector):
    """3c.9 — some nodes stop sending mid-iteration while others continue."""

    name = "early_stop_skew_across_nodes"
    table = "3c"
    stage = "decode (multi-node)"
    root_cause = "sequence-length divergence; scheduler not masking early exits"
    directive = "enable dynamic remapping; mask early-stop ranks"
    # collective participation is the signal; a stopped rank may still move
    # unrelated P2P traffic, so only COLLECTIVE_BURST counts as "sending"
    interested = frozenset({EventKind.COLLECTIVE_BURST})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.last: dict[int, float] = {}
        self.gap: dict[int, GapTracker] = {}

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        self.last[ev.node] = ev.ts
        self.gap.setdefault(
            ev.node, GapTracker(track_p99=False)).update(ev.ts)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events or len(self.last) < 2:
            return []
        newest = max(self.last.values())
        out = []
        silent, active = [], 0
        for node, ts in self.last.items():
            gt = self.gap[node]
            if gt.gaps.n < 8:
                continue
            typical = max(gt.gaps.mean, 1e-6)
            if newest - ts > self.cfg.starvation_factor * typical:
                silent.append((node, (newest - ts) / typical))
            else:
                active += 1
        if silent and active > 0:
            worst = max(s for _, s in silent)
            node = max(silent, key=lambda x: x[1])[0]
            out.append(self._mk(now, score=worst, node=node,
                                silent_nodes=[n for n, _ in silent],
                                active_nodes=active))
        return out


# ======================================================================
# Table 3(d) — Data-parallel replica runbook (cross-replica router view)
# ======================================================================


class CrossReplicaSkew(Detector):
    """3d.1 — per-replica EGRESS-rate divergence + queue-depth imbalance.

    The DP-layer pathology: a router policy (or the affinity/staleness
    defeating it) concentrates load on a subset of replicas.  From the DPU
    vantage this is per-replica egress token rates drifting apart while the
    hot replica's ingress queue grows and its peers' queues drain — both
    signals the NIC-side observer already exports.  Node-level detectors
    cannot see it: each node looks locally healthy, just unevenly busy.
    """

    name = "cross_replica_skew"
    table = "3d"
    stage = "ingress routing -> decode (data-parallel replicas)"
    root_cause = "router policy imbalance / stale router view / degraded replica"
    directive = "rebalance replicas; refresh router view; drain hot replica"
    interested = frozenset({EventKind.EGRESS_PKT, EventKind.QUEUE_SAMPLE})

    PERSIST = 2          # consecutive skewed polls before firing
    MIN_QUEUE_GAP = 8    # absolute hot-vs-mean queue depth floor
    MIN_CONC_TOTAL = 32  # backlog floor for the concentration signal
    CONC_FRAC = 0.6      # one replica holds this share of the total backlog

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.egress: dict[int, RateMeter] = {}       # replica -> token rate
        self.depth: dict[int, dict[int, int]] = {}   # replica -> node -> depth
        self.streak = 0

    def update(self, ev: Event) -> None:
        if ev.replica < 0:
            return
        self.events_seen += 1
        if ev.kind == EventKind.EGRESS_PKT:
            self.egress.setdefault(
                ev.replica, RateMeter(halflife=0.15)).update(ev.ts, ev.size)
        elif ev.meta == META_DIR_INGRESS:
            self.depth.setdefault(ev.replica, {})[ev.node] = ev.depth

    def update_batch(self, batch: EventBatch) -> None:
        reps = batch.replica
        valid = reps >= 0
        n = int(valid.sum())
        if n == 0:
            return
        self.events_seen += n
        is_egress = batch.kind == EventKind.EGRESS_PKT
        eg = valid & is_egress
        if eg.any():
            buckets: dict[int, tuple[list, list]] = {}
            for r, ts, sz in zip(reps[eg].tolist(), batch.ts[eg].tolist(),
                                 batch.size[eg].tolist()):
                b = buckets.get(r)
                if b is None:
                    buckets[r] = ([ts], [sz])
                else:
                    b[0].append(ts)
                    b[1].append(sz)
            egress = self.egress
            for r, (tss, sizes) in buckets.items():
                m = egress.get(r)
                if m is None:
                    m = egress[r] = RateMeter(halflife=0.15)
                m.update_many(tss, sizes)
        qs = valid & ~is_egress & (batch.meta == META_DIR_INGRESS)
        if qs.any():
            depth = self.depth
            for r, node, dep in zip(reps[qs].tolist(),
                                    batch.node[qs].tolist(),
                                    batch.depth[qs].tolist()):
                d = depth.get(r)
                if d is None:
                    d = depth[r] = {}
                d[node] = dep

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events or len(self.egress) < 2:
            return []
        rates = {r: m.rate_at(now) for r, m in self.egress.items()}
        w = Welford()
        for v in rates.values():
            w.update(v)
        rate_cv = w.cv()
        depths = {r: sum(nodes.values())
                  for r, nodes in self.depth.items()} or {r: 0 for r in rates}
        for r in rates:
            depths.setdefault(r, 0)
        d_total = sum(depths.values())
        d_mean = d_total / len(depths)
        d_max = max(depths.values())
        queue_gap = d_max - d_mean
        # concentration: one replica holds most of the cluster backlog.
        # Catches the rotating hot spot a stale router view produces, where
        # the victim identity changes faster than rate divergence builds.
        concentrated = (d_total >= self.MIN_CONC_TOTAL
                        and d_max / d_total > self.CONC_FRAC)
        skewed = (rate_cv > self.cfg.skew_cv_warn
                  and queue_gap >= self.MIN_QUEUE_GAP) \
            or concentrated or rate_cv > 1.5 * self.cfg.skew_cv_crit
        self.streak = self.streak + 1 if skewed else 0
        if self.streak < self.PERSIST:
            return []
        # the pathological replica: deepest backlog, ties to slowest egress
        hot = max(depths, key=lambda r: (depths[r], -rates.get(r, 0.0)))
        sev = ("critical"
               if rate_cv > self.cfg.skew_cv_crit or concentrated
               or queue_gap > 3 * self.MIN_QUEUE_GAP else "warn")
        return [self._mk(
            now, score=rate_cv * 10 + queue_gap / self.MIN_QUEUE_GAP,
            node=hot, severity=sev, replica=hot, rate_cv=rate_cv,
            queue_gap=queue_gap, concentrated=concentrated,
            egress_rates={r: round(v, 1) for r, v in rates.items()},
            queue_depths=depths)]


class HierarchicalRoutingSkew(Detector):
    """3d.2 — intra-replica node skew the replica tier cannot see.

    The hierarchical routing pathology: request *placement* concentrates on
    one node inside a replica (replica-local scheduler affinity, a broken
    TP-group spread) while the replica totals stay balanced — so the
    replica-tier detector (3d.1) is blind to it and the flat router never
    compensates.  From the DPU vantage this is per-node ingress-rate
    concentration within a replica (one node receives most of the
    replica's request bytes) corroborated by that same node's ingress
    queue outgrowing its siblings.  Keying on ingress *placement* rather
    than queue depth alone is what separates this row from a slow node
    (3b): a starved/slow node drains slowly under an even feed; here the
    feed itself is skewed.

    Node -> replica membership is learned from the ingress QUEUE_SAMPLEs
    (which carry both coordinates), so the detector needs no topology
    configuration.
    """

    name = "hierarchical_routing_skew"
    table = "3d"
    stage = "ingress routing -> intra-replica node placement"
    root_cause = ("replica-local placement affinity / broken TP-group "
                  "spread concentrating requests on one node")
    directive = ("rebalance queued requests across the replica's nodes; "
                 "fix the intra-replica spread policy")
    interested = frozenset({EventKind.INGRESS_PKT, EventKind.QUEUE_SAMPLE})

    PERSIST = 2          # consecutive skewed polls before firing
    MIN_SHARE = 0.65     # one node's share of its replica's ingress packets
    CRIT_SHARE = 0.80
    MIN_QUEUE_GAP = 8    # hot-node vs replica-mean queue depth floor
    MIN_RATE = 40.0      # ingress packets/s floor (quiet != skewed)

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.rate: dict[int, RateMeter] = {}      # node -> ingress rate
        self.node_replica: dict[int, int] = {}    # learned membership
        self.depth: dict[int, int] = {}           # node -> ingress depth
        self.streak = 0

    def update(self, ev: Event) -> None:
        if ev.kind == EventKind.INGRESS_PKT:
            # flow < 0 is background/bulk traffic, not request placement
            if ev.node < 0 or ev.flow < 0:
                return
            self.events_seen += 1
            m = self.rate.get(ev.node)
            if m is None:
                m = self.rate[ev.node] = RateMeter(halflife=0.15)
            m.update(ev.ts, ev.size)
        elif (ev.kind == EventKind.QUEUE_SAMPLE
              and ev.meta == META_DIR_INGRESS
              and ev.replica >= 0 and ev.node >= 0):
            self.events_seen += 1
            self.node_replica[ev.node] = ev.replica
            self.depth[ev.node] = ev.depth

    def update_batch(self, batch: EventBatch) -> None:
        is_ing = batch.kind == EventKind.INGRESS_PKT
        ing = is_ing & (batch.node >= 0) & (batch.flow >= 0)
        if ing.any():
            self.events_seen += int(ing.sum())
            buckets: dict[int, tuple[list, list]] = {}
            for n, ts, sz in zip(batch.node[ing].tolist(),
                                 batch.ts[ing].tolist(),
                                 batch.size[ing].tolist()):
                b = buckets.get(n)
                if b is None:
                    buckets[n] = ([ts], [sz])
                else:
                    b[0].append(ts)
                    b[1].append(sz)
            rate = self.rate
            for n, (tss, sizes) in buckets.items():
                m = rate.get(n)
                if m is None:
                    m = rate[n] = RateMeter(halflife=0.15)
                m.update_many(tss, sizes)
        qs = (~is_ing & (batch.meta == META_DIR_INGRESS)
              & (batch.replica >= 0) & (batch.node >= 0))
        if qs.any():
            self.events_seen += int(qs.sum())
            nr, dep = self.node_replica, self.depth
            for n, r, d in zip(batch.node[qs].tolist(),
                               batch.replica[qs].tolist(),
                               batch.depth[qs].tolist()):
                nr[n] = r
                dep[n] = d

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        groups: dict[int, list[int]] = {}
        for n, r in self.node_replica.items():
            groups.setdefault(r, []).append(n)
        # the row is *hierarchical* by definition: it needs >= 2 multi-node
        # replicas so "replica tier balanced, node tier skewed" is even
        # expressible — a lone replica's node skew belongs to the 3b rows
        multi = {r: nodes for r, nodes in groups.items() if len(nodes) >= 2}
        if len(multi) < 2:
            self.streak = 0
            return []
        rates = {r: {n: (self.rate[n].rate_at(now) if n in self.rate
                         else 0.0) for n in nodes}
                 for r, nodes in multi.items()}
        totals = {r: sum(v.values()) for r, v in rates.items()}
        grand = sum(totals.values())
        if grand < self.MIN_RATE:
            self.streak = 0
            return []
        # replica tier must look *balanced* — a concentrated replica tier
        # is 3d.1's territory, not this row's
        if max(totals.values()) / grand >= self.MIN_SHARE:
            self.streak = 0
            return []
        worst = None
        for r, nodes in multi.items():
            total = totals[r]
            if total < self.MIN_RATE / len(multi):
                continue
            hot = max(nodes, key=lambda n: (rates[r][n],
                                            self.depth.get(n, 0)))
            share = rates[r][hot] / total
            depths = [self.depth.get(n, 0) for n in nodes]
            gap = self.depth.get(hot, 0) - sum(depths) / len(depths)
            if share >= self.MIN_SHARE and gap >= self.MIN_QUEUE_GAP:
                cand = (share, gap, r, hot,
                        {n: round(v, 1) for n, v in rates[r].items()},
                        {n: self.depth.get(n, 0) for n in nodes})
                if worst is None or cand[:2] > worst[:2]:
                    worst = cand
        self.streak = self.streak + 1 if worst is not None else 0
        if self.streak < self.PERSIST:
            return []
        share, gap, replica, hot, hot_rates, depths = worst
        sev = ("critical" if share >= self.CRIT_SHARE
               or gap > 3 * self.MIN_QUEUE_GAP else "warn")
        return [self._mk(
            now, score=share * 10 + gap / self.MIN_QUEUE_GAP,
            node=hot, severity=sev, replica=replica,
            ingress_share=round(share, 3), queue_gap=gap,
            node_rates=hot_rates, node_depths=depths)]


# ======================================================================
# Table 3(e) — per-collective / topology-tier runbook
# ======================================================================


class CollectiveStragglerLag(Detector):
    """3e.1 — one node's per-op finish edge lags the group median.

    Consumes only the per-collective finish rows (all-gather /
    reduce-scatter tier, ``COLL_EDGE_FINISH``): each op round is buffered
    until its round id rolls over, then the straggler lag is the worst
    node's finish timestamp against the round median.  The aggregate
    tp_straggler row (3c.1) sees one merged burst per round and is blind
    to which *op* a rank is late into; this row is the per-op refinement.
    """

    name = "collective_straggler"
    table = "3e"
    stage = "compute (per-collective ops: all-gather / reduce-scatter)"
    root_cause = ("one rank consistently late into its collectives "
                  "(device slowdown, local contention)")
    directive = "rebalance shards toward the lagging rank; check its feeds"
    interested = frozenset({EventKind.COLLECTIVE_BURST})

    PERSIST = 2          # consecutive qualifying polls before firing
    MIN_LAG = 1e-4       # healthy finish jitter is ~2e-5; fault lag ~1.5e-3
    MIN_ROUNDS = 24      # finalized op rounds before the row may fire
    MIN_COUNTED = 12     # rounds with a measurable laggard
    LATE_FRAC = 0.6      # one node must own this share of late rounds
    CRIT_FRAC = 0.85

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        # per op-group open round: group -> (round id, node -> finish ts)
        self.open: dict[int, tuple[int, dict[int, float]]] = {}
        self.rounds = 0
        self.late: dict[int, int] = {}
        self.counted = 0
        self.lag = EWMA(0.1)
        self.streak = 0

    def _finalize(self, fins: dict[int, float]) -> None:
        self.rounds += 1
        if len(fins) < 2:
            return
        ts = sorted(fins.values())
        median = ts[len(ts) // 2]
        worst = max(fins, key=fins.__getitem__)
        lag = fins[worst] - median
        self.lag.update(lag)
        if lag > self.MIN_LAG:
            self.late[worst] = self.late.get(worst, 0) + 1
            self.counted += 1

    def _ingest(self, group: int, rid: int, node: int, ts: float) -> None:
        cur = self.open.get(group)
        if cur is None or cur[0] != rid:
            if cur is not None:
                self._finalize(cur[1])
            self.open[group] = (rid, {node: ts})
        else:
            cur[1][node] = ts

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        g = ev.group
        if (g != COLL_GROUP_ALL_GATHER and g != COLL_GROUP_REDUCE_SCATTER) \
                or ev.depth != COLL_EDGE_FINISH:
            return
        self._ingest(g, ev.meta, ev.node, ev.ts)   # meta carries the round

    def update_batch(self, batch: EventBatch) -> None:
        # single-kind safe: only COLLECTIVE_BURST arrives; rows keep wire
        # order within the kind, so round rollovers finalize exactly like
        # the scalar path
        self.events_seen += len(batch)
        m = (((batch.group == COLL_GROUP_ALL_GATHER)
              | (batch.group == COLL_GROUP_REDUCE_SCATTER))
             & (batch.depth == COLL_EDGE_FINISH))
        if not m.any():
            return
        for g, rid, node, ts in zip(batch.group[m].tolist(),
                                    batch.meta[m].tolist(),
                                    batch.node[m].tolist(),
                                    batch.ts[m].tolist()):
            self._ingest(g, rid, node, ts)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        worst, frac = -1, 0.0
        if self.rounds >= self.MIN_ROUNDS and self.counted \
                >= self.MIN_COUNTED:
            worst = max(self.late, key=self.late.__getitem__)
            frac = self.late[worst] / self.counted
        qualifies = (worst >= 0 and frac > self.LATE_FRAC
                     and self.lag.mean > self.MIN_LAG)
        self.streak = self.streak + 1 if qualifies else 0
        if self.streak < self.PERSIST:
            return []
        return [self._mk(
            now, score=frac * 10, node=worst,
            severity="critical" if frac > self.CRIT_FRAC else "warn",
            late_frac=round(frac, 3), mean_finish_lag=self.lag.mean,
            op_rounds=self.rounds)]


class RailCongestion(Detector):
    """3e.2 — cross-domain op slowdown concentrated on one shared rail.

    Cross-domain collective legs ride per-rail groups
    (``RAIL_GROUP_BASE + r``).  Per round, the mean finish time of each
    rail's legs is compared against the fastest rail; a congested rail is
    consistently the slow one by more than the healthy jitter floor.  One
    slow *node* shifts only its own legs; a slow *rail* shifts every leg
    that shares it — which is what separates this row from 3e.1/3c.1.
    """

    name = "rail_congestion"
    table = "3e"
    stage = "internode transfers (cross-domain rail tier)"
    root_cause = ("oversubscribed / degraded rail shared by cross-domain "
                  "collective legs")
    directive = "reroute cross-domain legs off the hot rail; respread ranks"
    interested = frozenset({EventKind.COLLECTIVE_BURST})

    PERSIST = 2
    MIN_LAG = 5e-5       # healthy inter-rail mean spread is ~1e-5
    MIN_ROUNDS = 24
    MIN_COUNTED = 12
    DOM_FRAC = 0.65      # one rail must own this share of slow rounds

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.open_rid: int | None = None
        self.acc: dict[int, tuple[float, int]] = {}   # rail -> (sum_ts, n)
        self.rails: set[int] = set()
        self.rounds = 0
        self.late: dict[int, int] = {}
        self.counted = 0
        self.lag = EWMA(0.1)
        self.streak = 0

    def _finalize(self) -> None:
        self.rounds += 1
        if len(self.acc) >= 2:
            means = {r: s / n for r, (s, n) in self.acc.items()}
            fast = min(means.values())
            slow = max(means, key=means.__getitem__)
            lag = means[slow] - fast
            self.lag.update(lag)
            if lag > self.MIN_LAG:
                self.late[slow] = self.late.get(slow, 0) + 1
                self.counted += 1
        self.acc = {}

    def _ingest(self, rail: int, rid: int, ts: float) -> None:
        if self.open_rid != rid:
            if self.open_rid is not None:
                self._finalize()
            self.open_rid = rid
        self.rails.add(rail)
        cur = self.acc.get(rail)
        self.acc[rail] = (ts, 1) if cur is None else (cur[0] + ts,
                                                      cur[1] + 1)

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        g = ev.group
        if g < RAIL_GROUP_BASE or g >= DOMAIN_GROUP_BASE:
            return
        self._ingest(g - RAIL_GROUP_BASE, ev.meta, ev.ts)

    def update_batch(self, batch: EventBatch) -> None:
        # single-kind safe (COLLECTIVE_BURST only); wire order preserved
        self.events_seen += len(batch)
        m = (batch.group >= RAIL_GROUP_BASE) & (batch.group
                                                < DOMAIN_GROUP_BASE)
        if not m.any():
            return
        for g, rid, ts in zip(batch.group[m].tolist(),
                              batch.meta[m].tolist(),
                              batch.ts[m].tolist()):
            self._ingest(g - RAIL_GROUP_BASE, rid, ts)

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events:
            return []
        hot, frac = -1, 0.0
        if (len(self.rails) >= 2 and self.rounds >= self.MIN_ROUNDS
                and self.counted >= self.MIN_COUNTED):
            hot = max(self.late, key=self.late.__getitem__)
            frac = self.late[hot] / self.counted
        qualifies = (hot >= 0 and frac > self.DOM_FRAC
                     and self.lag.mean > self.MIN_LAG)
        self.streak = self.streak + 1 if qualifies else 0
        if self.streak < self.PERSIST:
            return []
        return [self._mk(
            now, score=frac * 10, node=-1,
            severity="critical" if frac > 0.85 else "warn",
            rail=hot, slow_frac=round(frac, 3),
            mean_rail_lag=self.lag.mean, rail_rounds=self.rounds)]


class HbmBandwidthCliff(Detector):
    """3e.3 — decode token-rate sag with flat queues at peak batch size.

    The memory-bandwidth cliff: past a batch-size knee the decode phase
    turns bandwidth-bound and per-node egress token rate sags, while the
    NIC-side ingress queues stay shallow — so every queue-keyed row stays
    silent.  The DPU-visible signature is the *conjunction*: egress rate
    well below its own learned peak, AND a flat ingress queue, AND the
    scheduler's exported batch occupancy at its observed maximum.  Batch
    occupancy at max is what attributes the sag to batch size rather than
    to upstream starvation (starved nodes run *small* batches).
    """

    name = "hbm_bandwidth_cliff"
    table = "3e"
    stage = "decode (device memory bandwidth)"
    root_cause = ("decode batch past the memory-bandwidth knee; token rate "
                  "saturates while queues stay flat")
    directive = "shrink the decode batch below the knee; re-spread slots"
    interested = frozenset({EventKind.QUEUE_SAMPLE, EventKind.EGRESS_PKT})

    PERSIST = 2
    SAG = 0.7            # rate below this fraction of the learned peak
    CRIT_SAG = 0.5
    MIN_PEAK = 500.0     # egress events/s floor (quiet nodes never "sag")
    FLAT_DEPTH = 10      # "flat queue" = ingress depth at/below this

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.rate: dict[int, RateMeter] = {}     # node -> egress event rate
        self.peak: dict[int, float] = {}         # node -> peak rate seen
        self.qdepth: dict[int, int] = {}         # node -> ingress depth
        self.batch: dict[int, int] = {}          # node -> active batch size
        self.bmax: dict[int, int] = {}           # node -> max batch seen
        self.streak = 0

    def update(self, ev: Event) -> None:
        self.events_seen += 1
        if ev.kind == EventKind.EGRESS_PKT:
            m = self.rate.get(ev.node)
            if m is None:
                m = self.rate[ev.node] = RateMeter(halflife=0.1)
            m.update(ev.ts, ev.size)
        elif ev.meta == META_BATCH_OCC:
            self.batch[ev.node] = ev.depth
            if ev.depth > self.bmax.get(ev.node, 0):
                self.bmax[ev.node] = ev.depth
        elif ev.meta == META_DIR_INGRESS:
            self.qdepth[ev.node] = ev.depth

    def update_batch(self, batch: EventBatch) -> None:
        # per-kind sub-batches: EGRESS_PKT and QUEUE_SAMPLE state are
        # disjoint, and decisions only happen at poll(), so kind-partition
        # delivery is order-safe
        self.events_seen += len(batch)
        kinds = batch.kind
        eg = kinds == EventKind.EGRESS_PKT
        if eg.any():
            buckets: dict[int, tuple[list, list]] = {}
            for n, ts, sz in zip(batch.node[eg].tolist(),
                                 batch.ts[eg].tolist(),
                                 batch.size[eg].tolist()):
                b = buckets.get(n)
                if b is None:
                    buckets[n] = ([ts], [sz])
                else:
                    b[0].append(ts)
                    b[1].append(sz)
            rate = self.rate
            for n, (tss, sizes) in buckets.items():
                m = rate.get(n)
                if m is None:
                    m = rate[n] = RateMeter(halflife=0.1)
                m.update_many(tss, sizes)
        occ = ~eg & (batch.meta == META_BATCH_OCC)
        if occ.any():
            bat, bmax = self.batch, self.bmax
            for n, d in zip(batch.node[occ].tolist(),
                            batch.depth[occ].tolist()):
                bat[n] = d
                if d > bmax.get(n, 0):
                    bmax[n] = d
        ing = ~eg & (batch.meta == META_DIR_INGRESS)
        if ing.any():
            qd = self.qdepth
            for n, d in zip(batch.node[ing].tolist(),
                            batch.depth[ing].tolist()):
                qd[n] = d

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.cfg.min_events or not self.batch:
            # structural gate: no scheduler batch-occupancy tap exported
            # means the attribution to batch size is inexpressible
            return []
        worst = None
        for node, meter in self.rate.items():
            r = meter.rate_at(now)
            peak = self.peak.get(node, 0.0)
            if r > peak:
                self.peak[node] = peak = r
            b = self.batch.get(node)
            if b is None or peak < self.MIN_PEAK:
                continue
            sag = r / peak
            depth = self.qdepth.get(node, 0)
            # the cliff conjunction: sagging rate + flat queue + batch
            # pinned at its observed max (a drained node fails the batch
            # gate, a backlogged node fails the flat-queue gate)
            if (sag < self.SAG and depth <= self.FLAT_DEPTH
                    and b >= self.bmax.get(node, b) - 1):
                if worst is None or sag < worst[0]:
                    worst = (sag, node, b, depth)
        self.streak = self.streak + 1 if worst is not None else 0
        if self.streak < self.PERSIST:
            return []
        sag, node, b, depth = worst
        return [self._mk(
            now, score=(1.0 - sag) * 10, node=node,
            severity="critical" if sag < self.CRIT_SAG else "warn",
            rate_vs_peak=round(sag, 3), batch_size=b,
            ingress_depth=depth)]


# ======================================================================
# DPU self-diagnosis — the telemetry plane watching itself
# ======================================================================


class DPUSaturation(Detector):
    """dpu.1 — the DPU's own ingest budget saturates and sheds load.

    Signal source is the sidecar's self-telemetry (``META_DPU_RING``
    QUEUE_SAMPLEs: ring occupancy percent in ``depth``, rows shed since the
    previous sample in ``size``).  Any shed is critical — findings are now
    provably incomplete; sustained high occupancy without shed is the
    warning precursor.  This row exists because a control plane that cannot
    notice its *own* overload silently degrades every other row.
    """

    name = "dpu_saturation"
    table = "dpu"
    stage = "telemetry plane (all vantages degraded)"
    root_cause = "event volume exceeds DPU ingest/compute budget " \
                 "(debug-tap storm, line-rate burst, undersized budget)"
    directive = "raise tap sampling stride; shed low-priority event " \
                "classes; bound per-class event rates"
    interested = frozenset({EventKind.QUEUE_SAMPLE})

    WARN_OCCUPANCY = 80      # ring percent considered "about to shed"
    MIN_SAMPLES = 4          # self-samples before the row may fire

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self.occ = 0             # latest ring occupancy percent
        self.occ_peak = 0        # peak since the last poll
        self.shed = 0            # rows shed since the last poll

    def update(self, ev: Event) -> None:
        if ev.kind != EventKind.QUEUE_SAMPLE or ev.meta != META_DPU_RING:
            return
        self.events_seen += 1
        self.occ = int(ev.depth)
        if self.occ > self.occ_peak:
            self.occ_peak = self.occ
        self.shed += int(ev.size)

    def update_batch(self, batch: EventBatch) -> None:
        # single-kind safe: only QUEUE_SAMPLE rows arrive; order within the
        # kind is wire order, so "latest occupancy" matches the scalar path
        m = batch.meta == META_DPU_RING
        if not m.any():
            return
        self.events_seen += int(m.sum())
        depths = batch.depth[m]
        self.occ = int(depths[-1])
        peak = int(depths.max())
        if peak > self.occ_peak:
            self.occ_peak = peak
        self.shed += int(batch.size[m].sum())

    def poll(self, now: float) -> list[Finding]:
        if self.events_seen < self.MIN_SAMPLES:
            # keep accumulating: sheds during warmup must surface in the
            # first eligible poll, not vanish
            return []
        shed, self.shed = self.shed, 0
        peak, self.occ_peak = self.occ_peak, self.occ
        if shed > 0:
            return [self._mk(now, score=10.0 + shed / 100.0,
                             severity="critical", shed_rows=shed,
                             ring_occupancy_pct=peak)]
        if peak >= self.WARN_OCCUPANCY:
            return [self._mk(now, score=peak / 10.0, severity="warn",
                             shed_rows=0, ring_occupancy_pct=peak)]
        return []


# ======================================================================
# Monitoring-plane robustness ("mon" table) — watching the watcher.
# Signal sources are self-telemetry rows (sidecar ingest guard, command
# bus) and the host watchdog's heartbeat probes; none of these rows exist
# on a healthy monitoring plane, so the detectors are structurally silent
# on every data-path scenario.
# ======================================================================


class DPUOutage(Detector):
    """mon.1 — the DPU itself went dark.

    Signal source is the host-side watchdog's heartbeat probe stream
    (``META_MON_HEARTBEAT``), emitted into the *standby* plane over the
    BlueField's out-of-band management port: ``size`` is 1 while the DPU
    has been silent past the watchdog timeout, ``depth`` carries the
    silence in milliseconds.  Two consecutive silent probes make the
    outage critical — one probe can race a slow scheduling round.
    """

    name = "dpu_outage"
    table = "mon"
    stage = "monitoring plane (all detection + actuation dark)"
    root_cause = "DPU crash/hang/power-cycle, or management-path loss " \
                 "of the telemetry sidecar"
    directive = "fail over to the degraded host-side controller; " \
                "fail back with hysteresis when heartbeats resume"
    interested = frozenset({EventKind.QUEUE_SAMPLE})

    MIN_SILENT = 2           # consecutive silent probes before firing

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self._silent_run = 0     # consecutive silent probes
        self._silence_ms = 0

    def update(self, ev: Event) -> None:
        if ev.kind != EventKind.QUEUE_SAMPLE or ev.meta != META_MON_HEARTBEAT:
            return
        self.events_seen += 1
        if int(ev.size) > 0:
            self._silent_run += 1
            self._silence_ms = int(ev.depth)
        else:
            self._silent_run = 0
            self._silence_ms = 0

    def poll(self, now: float) -> list[Finding]:
        if self._silent_run < self.MIN_SILENT:
            return []
        return [self._mk(now, score=10.0 + self._silence_ms / 100.0,
                         severity="critical",
                         silent_probes=self._silent_run,
                         silence_ms=self._silence_ms)]


class TelemetryBlackout(Detector):
    """mon.2 — the telemetry stream to the DPU tore.

    Signal source is the sidecar ingest guard's latched dirty rows
    (``META_MON_INGEST``): ``size`` counts sequence numbers missing plus
    batches dropped for checksum corruption since the last resync,
    ``depth`` counts replayed duplicates dropped.  The latch means the
    row keeps firing until a host-side ``resync_telemetry`` actuation
    lands — detection survives its own actuation quarantine.
    """

    name = "telemetry_blackout"
    table = "mon"
    stage = "telemetry ingest (detection blind for the gap window)"
    root_cause = "uplink partition/blackout, tap corruption, or replayed " \
                 "frames on the telemetry path"
    directive = "re-register the telemetry tap and resync the sequence " \
                "stream; quarantine actuation until detectors re-warm"
    interested = frozenset({EventKind.QUEUE_SAMPLE})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self._lost = 0           # latest latched missing+corrupt count
        self._replays = 0
        self._seen_this_poll = 0

    def update(self, ev: Event) -> None:
        if ev.kind != EventKind.QUEUE_SAMPLE or ev.meta != META_MON_INGEST:
            return
        self.events_seen += 1
        self._seen_this_poll += 1
        self._lost = int(ev.size)
        self._replays = int(ev.depth)

    def poll(self, now: float) -> list[Finding]:
        seen, self._seen_this_poll = self._seen_this_poll, 0
        if seen == 0 or self._lost <= 0:
            return []
        return [self._mk(now, score=8.0 + self._lost / 1000.0,
                         severity="critical", lost_batches=self._lost,
                         replays_dropped=self._replays)]


class CommandPartition(Detector):
    """mon.3 — the command/actuation channel is partitioned.

    Signal source is the bus-health self-telemetry (``META_MON_BUS``):
    ``size`` is the cumulative count of commands (including liveness
    pings) that burned every retry unacked.  A merely lossy channel lands
    most retries; repeated *exhaustion* with no intervening ack means
    nothing is getting through, which is a different failure class than
    ``lossy_command_channel`` and needs failover, not patience.
    """

    name = "command_partition"
    table = "mon"
    stage = "actuation path (detection intact, mitigation dark)"
    root_cause = "downlink/ack-channel partition between DPU and host " \
                 "actuator"
    directive = "fail actuation over to the host-side controller until " \
                "the command channel round-trips again"
    interested = frozenset({EventKind.QUEUE_SAMPLE})

    MIN_EXHAUSTED = 3        # a lossy-but-alive channel stays below this

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self._exhausted = 0
        self._retries = 0
        self._seen_this_poll = 0

    def update(self, ev: Event) -> None:
        if ev.kind != EventKind.QUEUE_SAMPLE or ev.meta != META_MON_BUS:
            return
        self.events_seen += 1
        self._seen_this_poll += 1
        self._exhausted = int(ev.size)
        self._retries = int(ev.depth)

    def poll(self, now: float) -> list[Finding]:
        seen, self._seen_this_poll = self._seen_this_poll, 0
        if seen == 0 or self._exhausted < self.MIN_EXHAUSTED:
            return []
        return [self._mk(now, score=9.0 + self._exhausted / 10.0,
                         severity="critical",
                         exhausted_commands=self._exhausted,
                         retries=self._retries)]


class StandbyLag(Detector):
    """mon.4 — the hot standby's detector state fell measurably behind.

    Signal source is the watchdog's standby-shadow probe
    (``META_MON_STANDBY``): ``size`` carries how far the standby
    sidecar's tap clock lags the primary's, in milliseconds.  A healthy
    mirrored tap keeps the two within one link delay of each other; a
    sustained lag means the standby leg of the fan-out is dropping or
    partitioned, and a failover right now would promote a sidecar whose
    detectors are warm on *stale* state.  Critical because the lag
    silently voids the hot-failover guarantee — the deployment is one
    primary fault away from a cold promotion.
    """

    name = "standby_lag"
    table = "mon"
    stage = "monitoring plane (redundancy silently degraded)"
    root_cause = "standby tap leg dropping/partitioned, or standby " \
                 "sidecar wedged while the primary stays healthy"
    directive = "re-mirror the standby from the watchdog's retained tap " \
                "history and resync its sequence stream"
    interested = frozenset({EventKind.QUEUE_SAMPLE})

    LAG_MS = 250             # one detector poll interval, with margin

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self._lag_ms = 0
        self._standby_up = 1
        self._seen_this_poll = 0

    def update(self, ev: Event) -> None:
        if ev.kind != EventKind.QUEUE_SAMPLE or ev.meta != META_MON_STANDBY:
            return
        self.events_seen += 1
        self._seen_this_poll += 1
        self._lag_ms = int(ev.size)
        self._standby_up = int(ev.depth)

    def poll(self, now: float) -> list[Finding]:
        seen, self._seen_this_poll = self._seen_this_poll, 0
        if seen == 0 or self._lag_ms < self.LAG_MS:
            return []
        return [self._mk(now, score=8.5 + self._lag_ms / 1000.0,
                         severity="critical", lag_ms=self._lag_ms,
                         standby_up=self._standby_up)]


class SplitBrainFenced(Detector):
    """mon.5 — a stale-term command reached the host actuator.

    Signal source is the watchdog's fencing probe (``META_MON_FENCE``):
    ``size`` counts commands the actuator rejected since the last probe
    because they carried a term older than the granted lease, ``depth``
    is the term currently in force.  One fenced command is already an
    incident: a deposed sidecar is alive, partitioned from the lease
    arbiter, and still trying to drive mitigation — only the fence stood
    between the cluster and double actuation.  Critical and immediate.
    """

    name = "split_brain_fenced"
    table = "mon"
    stage = "actuation path (double-actuation attempt blocked)"
    root_cause = "deposed sidecar still actuating: OOB partition hid its " \
                 "demotion while its command path stayed alive"
    directive = "deliver the current term to the stale sidecar " \
                "(quiesce it) and purge its outstanding commands"
    interested = frozenset({EventKind.QUEUE_SAMPLE})

    def __init__(self, cfg: DetectorConfig) -> None:
        super().__init__(cfg)
        self._fenced = 0
        self._term = 0
        self._seen_this_poll = 0

    def update(self, ev: Event) -> None:
        if ev.kind != EventKind.QUEUE_SAMPLE or ev.meta != META_MON_FENCE:
            return
        self.events_seen += 1
        self._seen_this_poll += 1
        self._fenced += int(ev.size)
        self._term = int(ev.depth)

    def poll(self, now: float) -> list[Finding]:
        seen, self._seen_this_poll = self._seen_this_poll, 0
        fenced, self._fenced = self._fenced, 0
        if seen == 0 or fenced <= 0:
            return []
        return [self._mk(now, score=9.5 + fenced / 10.0,
                         severity="critical", fenced_commands=fenced,
                         granted_term=self._term)]


ALL_DETECTORS: tuple[type[Detector], ...] = (
    # 3(a)
    BurstAdmissionBacklog, IngressStarvation, FlowSkewAcrossSessions,
    IngressDropRetransmit, EgressBacklogQueueing, EgressJitter,
    EgressDropRetransmit, EarlyCompletionSkew, BandwidthSaturation,
    # 3(b)
    H2DDataStarvation, D2HReturnBottleneck, KernelLaunchLatency,
    IntraNodeGpuSkew, PCIeLinkSaturation, GpuP2PThrottling,
    PinnedMemoryShortage, HostCpuBottleneck, MemoryRegistrationChurn,
    DecodeEarlyStopSkew,
    # 3(c)
    TPStraggler, PPBubble, CrossNodeLoadSkew, NetworkCongestion,
    HeadOfLineBlocking, EWRetransmitStorm, CreditStarvation,
    KVCacheTransferBottleneck, EarlyStopSkewAcrossNodes,
    # 3(d)
    CrossReplicaSkew, HierarchicalRoutingSkew,
    # 3(e)
    CollectiveStragglerLag, RailCongestion, HbmBandwidthCliff,
    # DPU self-diagnosis
    DPUSaturation,
    # monitoring-plane robustness
    DPUOutage, TelemetryBlackout, CommandPartition, StandbyLag,
    SplitBrainFenced,
)
