"""Cross-vantage root-cause attribution — the paper's §4.2 logic, executable.

The paper's key observation: a single vantage point sees a *symptom*; the
combination of North-South, PCIe, and East-West vantage points localizes the
*cause*:

  "if one GPU consistently exhibits delayed PCIe activity after ingress, the
   DPU can attribute the slowdown to local imbalance (CPU preprocessing lag,
   PCIe congestion) rather than network effects.  Conversely, if PCIe
   patterns are healthy but responses stall at egress, the issue is likely
   network-side."

We encode this as a small rule engine over the set of active findings within
a correlation window.  Output is an ``Attribution`` naming the *locus* (where
the skew is introduced) and the chain of findings supporting it — exactly the
"root-cause attribution: host-to-GPU transfers, GPU scheduling, or external
communication?" question the paper poses.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.detectors import Finding

# Loci ordered roughly along the request lifecycle.
LOCUS_INGRESS = "ingress_path"          # client -> NIC
LOCUS_HOST = "host_cpu"                 # tokenize/batch/launch on host
LOCUS_PCIE = "pcie_transfer"            # host <-> device feed/return
LOCUS_DEVICE = "device_scheduling"      # per-device load imbalance
LOCUS_NETWORK = "internode_network"     # E-W fabric
LOCUS_EGRESS = "egress_path"            # NIC -> client
LOCUS_WORKLOAD = "workload_shape"       # seq-length variance, early stop
LOCUS_ROUTER = "router_dispatch"        # DP-replica routing layer
LOCUS_DPU = "telemetry_plane"           # the observer itself is overloaded
LOCUS_UNKNOWN = "unknown"

#: finding name -> the locus that finding is *direct* evidence for
DIRECT_LOCUS: dict[str, str] = {
    # 3a
    "burst_admission_backlog": LOCUS_INGRESS,
    "ingress_starvation": LOCUS_INGRESS,
    "flow_skew_across_sessions": LOCUS_INGRESS,
    "ingress_drop_retransmit": LOCUS_INGRESS,
    "egress_backlog_queueing": LOCUS_EGRESS,
    "egress_jitter": LOCUS_EGRESS,
    "egress_drop_retransmit": LOCUS_EGRESS,
    "early_completion_skew": LOCUS_WORKLOAD,
    "ingress_egress_bandwidth_saturation": LOCUS_INGRESS,
    # 3b
    "h2d_data_starvation": LOCUS_PCIE,
    "d2h_return_bottleneck": LOCUS_PCIE,
    "kernel_launch_control_latency": LOCUS_HOST,
    "intra_node_gpu_skew": LOCUS_DEVICE,
    "pcie_link_saturation": LOCUS_PCIE,
    "gpu_p2p_throttling": LOCUS_PCIE,
    "pinned_memory_shortage": LOCUS_HOST,
    "host_cpu_bottleneck": LOCUS_HOST,
    "memory_registration_churn": LOCUS_HOST,
    "decode_early_stop_skew": LOCUS_WORKLOAD,
    # 3c
    "tp_straggler": LOCUS_NETWORK,        # symptom is E-W; cause often local
    "pp_bubble_stage_stall": LOCUS_NETWORK,
    "cross_node_load_skew": LOCUS_DEVICE,
    "network_congestion_oversubscription": LOCUS_NETWORK,
    "head_of_line_blocking": LOCUS_NETWORK,
    "retransmissions_packet_loss": LOCUS_NETWORK,
    "credit_starvation": LOCUS_NETWORK,
    "kv_cache_transfer_bottleneck": LOCUS_NETWORK,
    "early_stop_skew_across_nodes": LOCUS_WORKLOAD,
    # 3d
    "cross_replica_skew": LOCUS_ROUTER,
    "hierarchical_routing_skew": LOCUS_ROUTER,
    # 3e
    "collective_straggler": LOCUS_DEVICE,
    "rail_congestion": LOCUS_NETWORK,
    "hbm_bandwidth_cliff": LOCUS_DEVICE,
    # DPU self-diagnosis
    "dpu_saturation": LOCUS_DPU,
    # monitoring-plane robustness (mon)
    "dpu_outage": LOCUS_DPU,
    "telemetry_blackout": LOCUS_DPU,
    "command_partition": LOCUS_DPU,
    "standby_lag": LOCUS_DPU,
    "split_brain_fenced": LOCUS_DPU,
}


@dataclass(frozen=True)
class Attribution:
    """Root-cause verdict for one correlated incident."""

    ts: float
    locus: str                      # one of the LOCUS_* constants
    node: int                       # offending node, -1 = cluster-wide
    confidence: float               # 0..1
    primary: Finding                # the symptom that triggered correlation
    supporting: tuple[Finding, ...] # co-occurring evidence
    narrative: str                  # human-readable §4.2-style explanation


class Attributor:
    """Correlates findings within a sliding window and applies §4.2 rules.

    Rule order matters: the most specific cross-vantage patterns first, the
    direct single-vantage mapping as fallback.
    """

    def __init__(self, window: float = 2.0) -> None:
        self.window = window
        self._recent: list[Finding] = []
        self.attributions: list[Attribution] = []

    # -- feeding ---------------------------------------------------------

    def observe(self, findings: list[Finding]) -> list[Attribution]:
        out = []
        for f in findings:
            self._recent.append(f)
            a = self._attribute(f)
            if a is not None:
                self.attributions.append(a)
                out.append(a)
        if self._recent:
            horizon = self._recent[-1].ts - self.window
            self._recent = [f for f in self._recent if f.ts >= horizon]
        return out

    # -- rules -----------------------------------------------------------

    def _within(self, f: Finding, names: set[str],
                same_node: bool = False) -> list[Finding]:
        return [
            g for g in self._recent
            if g.name in names and abs(g.ts - f.ts) <= self.window
            and (not same_node or g.node == f.node or g.node < 0 or f.node < 0)
        ]

    def _attribute(self, f: Finding) -> Attribution | None:
        # Rule 1 (§4.2 verbatim): E-W straggler symptom + delayed/unhealthy
        # PCIe on the same node => LOCAL imbalance, not network.
        if f.name in ("tp_straggler", "pp_bubble_stage_stall",
                      "cross_node_load_skew"):
            local = self._within(f, {
                "h2d_data_starvation", "d2h_return_bottleneck",
                "pcie_link_saturation", "intra_node_gpu_skew",
                "host_cpu_bottleneck", "kernel_launch_control_latency",
                "pinned_memory_shortage", "memory_registration_churn",
            }, same_node=True)
            if local:
                locus = DIRECT_LOCUS[local[0].name]
                return Attribution(
                    f.ts, locus, node=max(f.node, local[0].node),
                    confidence=0.9, primary=f, supporting=tuple(local),
                    narrative=(
                        f"E-W symptom '{f.name}' co-occurs with local "
                        f"'{local[0].name}' on node {local[0].node}: skew is "
                        f"introduced host-side ({locus}), not by the fabric."))
            # straggler with *healthy* PCIe on all nodes => fabric or device
            fabric = self._within(f, {
                "network_congestion_oversubscription",
                "retransmissions_packet_loss", "head_of_line_blocking",
                "credit_starvation"})
            if fabric:
                return Attribution(
                    f.ts, LOCUS_NETWORK, node=-1, confidence=0.85,
                    primary=f, supporting=tuple(fabric),
                    narrative=(
                        f"E-W symptom '{f.name}' coincides with fabric "
                        f"pathology '{fabric[0].name}': network-side cause."))
            workload = self._within(f, {
                "early_completion_skew", "decode_early_stop_skew",
                "early_stop_skew_across_nodes"})
            if workload:
                return Attribution(
                    f.ts, LOCUS_WORKLOAD, node=f.node, confidence=0.8,
                    primary=f, supporting=tuple(workload),
                    narrative=(
                        f"Collective stall '{f.name}' explained by sequence-"
                        "length divergence (early-stop) — scheduler issue, "
                        "not infrastructure."))
            return Attribution(
                f.ts, LOCUS_DEVICE, node=f.node, confidence=0.5,
                primary=f, supporting=(),
                narrative=(
                    f"'{f.name}' with healthy PCIe and quiet fabric: "
                    "attribute to device-level load imbalance (default)."))

        # Rule 2 (§4.2 verbatim): egress stalls with healthy PCIe => network.
        if f.name in ("egress_backlog_queueing", "egress_jitter",
                      "egress_drop_retransmit"):
            pcie_sick = self._within(f, {
                "d2h_return_bottleneck", "pcie_link_saturation",
                "host_cpu_bottleneck"}, same_node=True)
            if pcie_sick:
                locus = DIRECT_LOCUS[pcie_sick[0].name]
                return Attribution(
                    f.ts, locus, node=f.node, confidence=0.85, primary=f,
                    supporting=tuple(pcie_sick),
                    narrative=(
                        f"Egress symptom '{f.name}' with sick return path "
                        f"'{pcie_sick[0].name}': host/PCIe-side cause."))
            return Attribution(
                f.ts, LOCUS_EGRESS, node=f.node, confidence=0.75, primary=f,
                supporting=(),
                narrative=(
                    f"Egress symptom '{f.name}' with healthy PCIe patterns: "
                    "issue is likely network/NIC-side (paper §4.2)."))

        # Rule 3: H2D starvation — distinguish upstream (thin ingress) from
        # host-side (ingress fine, feed broken).
        if f.name == "h2d_data_starvation":
            thin = self._within(f, {"ingress_starvation",
                                    "burst_admission_backlog"},
                                same_node=True)
            if thin:
                return Attribution(
                    f.ts, LOCUS_INGRESS, node=f.node, confidence=0.85,
                    primary=f, supporting=tuple(thin),
                    narrative=(
                        "Device feed starves because ingress itself is "
                        f"pathological ('{thin[0].name}'): upstream cause."))
            host = self._within(f, {"host_cpu_bottleneck",
                                    "pinned_memory_shortage",
                                    "memory_registration_churn"},
                                same_node=True)
            if host:
                return Attribution(
                    f.ts, LOCUS_HOST, node=f.node, confidence=0.85,
                    primary=f, supporting=tuple(host),
                    narrative=(
                        "Ingress healthy but device feed starves alongside "
                        f"'{host[0].name}': host-side preprocessing/feed "
                        "bottleneck (CPU tokenization/batching lag)."))
            return Attribution(
                f.ts, LOCUS_PCIE, node=f.node, confidence=0.6, primary=f,
                supporting=(),
                narrative="Isolated H2D starvation: PCIe transfer path.")

        # Rule 4: early-stop family is always a workload/scheduler issue.
        if f.name in ("early_completion_skew", "decode_early_stop_skew",
                      "early_stop_skew_across_nodes"):
            return Attribution(
                f.ts, LOCUS_WORKLOAD, node=f.node, confidence=0.9, primary=f,
                supporting=(),
                narrative=(
                    "Early-stop skew: sequence-length variance leaves shards "
                    "idle; mitigation is scheduler-side (inflight remap)."))

        # Rule 5: cross-replica skew — if ingress itself is pathological the
        # imbalance is upstream; with clean ingress it is the router's doing
        # (bad policy, stale view, or a degraded replica the router keeps
        # feeding).
        if f.name == "cross_replica_skew":
            upstream = self._within(f, {
                "ingress_starvation", "flow_skew_across_sessions",
                "burst_admission_backlog"})
            if upstream:
                return Attribution(
                    f.ts, LOCUS_INGRESS, node=f.node, confidence=0.8,
                    primary=f, supporting=tuple(upstream),
                    narrative=(
                        f"Replica skew co-occurs with '{upstream[0].name}': "
                        "the imbalance originates upstream of the router."))
            return Attribution(
                f.ts, LOCUS_ROUTER, node=f.node, confidence=0.85, primary=f,
                supporting=(),
                narrative=(
                    "Ingress healthy but per-replica egress rates diverge "
                    f"and replica {f.node}'s queue grows: the DP routing "
                    "layer is concentrating load (policy/staleness/affinity)."))

        # Rule 5b: intra-replica node skew with replica-balanced ingress is
        # the placement layer's doing by construction — unless the hot node
        # itself is locally sick (then the router is feeding a degraded
        # node, which is a device/host problem wearing routing clothes).
        if f.name == "hierarchical_routing_skew":
            local = self._within(f, {
                "h2d_data_starvation", "host_cpu_bottleneck",
                "intra_node_gpu_skew", "pcie_link_saturation"},
                same_node=True)
            if local:
                locus = DIRECT_LOCUS[local[0].name]
                return Attribution(
                    f.ts, locus, node=f.node, confidence=0.8, primary=f,
                    supporting=tuple(local),
                    narrative=(
                        f"Node {f.node} hoards its replica's requests AND "
                        f"shows local '{local[0].name}': the node is "
                        "degraded; placement skew is a symptom."))
            return Attribution(
                f.ts, LOCUS_ROUTER, node=f.node, confidence=0.85, primary=f,
                supporting=(),
                narrative=(
                    f"Replica totals balanced but node {f.node} receives "
                    f"{f.evidence.get('ingress_share', '?')} of its "
                    "replica's ingress and its queue outgrows its "
                    "siblings: intra-replica placement skew — the routing "
                    "layer is blind below the replica tier."))

        # Rule 5c: the per-collective tier (3e) carries its locus in the
        # signal's construction.  An op-level straggler names a rank;
        # rail congestion names a shared link, never a node; the memory-
        # bandwidth cliff is the only row whose evidence *includes* the
        # batch size that explains the sag, so the narrative says so.
        if f.name == "collective_straggler":
            return Attribution(
                f.ts, LOCUS_DEVICE, node=f.node, confidence=0.75, primary=f,
                supporting=(),
                narrative=(
                    f"Node {f.node} is last into "
                    f"{f.evidence.get('late_frac', '?')} of its per-op "
                    "collective rounds: rank-local slowdown visible only at "
                    "per-op granularity."))
        if f.name == "rail_congestion":
            return Attribution(
                f.ts, LOCUS_NETWORK, node=-1, confidence=0.8, primary=f,
                supporting=(),
                narrative=(
                    f"Rail {f.evidence.get('rail', '?')} is the slow rail in "
                    f"{f.evidence.get('slow_frac', '?')} of cross-domain "
                    "rounds while intra-domain traffic stays fast: a shared-"
                    "rail fabric problem, not any single rank."))
        if f.name == "hbm_bandwidth_cliff":
            return Attribution(
                f.ts, LOCUS_DEVICE, node=f.node, confidence=0.8, primary=f,
                supporting=(),
                narrative=(
                    f"Node {f.node}'s egress rate sags to "
                    f"{f.evidence.get('rate_vs_peak', '?')} of its peak with "
                    "a flat ingress queue and batch occupancy of "
                    f"{f.evidence.get('batch_size', '?')} at its observed "
                    "max: decode batch size is past the device's memory-"
                    "bandwidth knee — shrink the batch, nothing upstream "
                    "will help."))

        # Rule 6: the observer itself saturating is always self-attributed —
        # and it taints confidence in everything else this window, so it
        # carries high confidence of its own locus.
        if f.name == "dpu_saturation":
            return Attribution(
                f.ts, LOCUS_DPU, node=-1, confidence=0.9, primary=f,
                supporting=(),
                narrative=(
                    "DPU ingest budget saturated (ring "
                    f"{f.evidence.get('ring_occupancy_pct', '?')}%, "
                    f"{f.evidence.get('shed_rows', 0)} rows shed): the "
                    "telemetry plane is degraded; concurrent findings may "
                    "be late or missing — shed load at the tap."))

        # Rule 7: monitoring-plane failures self-attribute like Rule 6 —
        # the signal sources (watchdog probes, ingest-guard latch, bus
        # exhaustion counters) exist only on the monitoring path, so no
        # cross-vantage correlation can sharpen or overturn them.  They
        # also taint everything else this window: findings spanning the
        # blind interval ride stale baselines.
        if f.name == "dpu_outage":
            return Attribution(
                f.ts, LOCUS_DPU, node=-1, confidence=0.9, primary=f,
                supporting=(),
                narrative=(
                    "DPU heartbeats silent for "
                    f"{f.evidence.get('silence_ms', '?')} ms across "
                    f"{f.evidence.get('silent_probes', '?')} probes: the "
                    "monitoring plane itself is down — fail over to the "
                    "degraded host-side controller."))
        if f.name == "telemetry_blackout":
            return Attribution(
                f.ts, LOCUS_DPU, node=-1, confidence=0.85, primary=f,
                supporting=(),
                narrative=(
                    "Telemetry stream tore: "
                    f"{f.evidence.get('lost_batches', '?')} batches "
                    "missing or corrupt since the last resync "
                    f"({f.evidence.get('replays_dropped', 0)} replays "
                    "dropped).  Detector baselines span a hole — resync "
                    "the tap; actuation stays quarantined meanwhile."))
        if f.name == "command_partition":
            return Attribution(
                f.ts, LOCUS_DPU, node=-1, confidence=0.9, primary=f,
                supporting=(),
                narrative=(
                    "Command channel partitioned: "
                    f"{f.evidence.get('exhausted_commands', '?')} commands "
                    "burned every retry unacked "
                    f"({f.evidence.get('retries', '?')} resends total). "
                    "Detection is intact but mitigation is dark — fail "
                    "actuation over host-side."))
        if f.name == "standby_lag":
            return Attribution(
                f.ts, LOCUS_DPU, node=-1, confidence=0.85, primary=f,
                supporting=(),
                narrative=(
                    "Hot standby lagging the primary by "
                    f"{f.evidence.get('lag_ms', '?')} ms of tap time: the "
                    "mirrored fan-out leg is degraded and a failover now "
                    "would promote stale detector state — re-mirror the "
                    "standby from retained tap history."))
        if f.name == "split_brain_fenced":
            return Attribution(
                f.ts, LOCUS_DPU, node=-1, confidence=0.9, primary=f,
                supporting=(),
                narrative=(
                    f"{f.evidence.get('fenced_commands', '?')} stale-term "
                    "command(s) fenced at the host actuator under term "
                    f"{f.evidence.get('granted_term', '?')}: a deposed "
                    "sidecar is alive and still actuating — quiesce it "
                    "with the current term and purge its outstanding "
                    "commands."))

        # Fallback: direct single-vantage mapping.
        locus = DIRECT_LOCUS.get(f.name, LOCUS_UNKNOWN)
        return Attribution(
            f.ts, locus, node=f.node, confidence=0.6, primary=f,
            supporting=(),
            narrative=f"Direct mapping: '{f.name}' -> {locus}.")
