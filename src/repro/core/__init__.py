"""The paper's primary contribution: a DPU-analog telemetry, detection,
attribution, and mitigation plane for distributed LLM inference/training.

Public surface:
  events       — DPU-observable event schema (the §4.3 boundary, enforced)
  sketch       — O(1) streaming statistics (line-rate processing)
  detectors    — 34 executable detectors, one per runbook row (the paper's
                 28 + the 3d data-parallel routing extensions + the DPU
                 self-diagnosis row + the 3e collective/rail/memory tier)
  runbooks     — Tables 3(a)/(b)/(c)/(d)/(e) as a declarative registry
  attribution  — §4.2 cross-vantage root-cause attribution
  mitigation   — §5 closed-loop controller
  telemetry    — DPUAgent / TelemetryPlane tying it together
"""

from repro.core.attribution import Attribution, Attributor
from repro.core.detectors import ALL_DETECTORS, Detector, DetectorConfig, Finding
from repro.core.events import (
    CollectiveOp,
    Event,
    EventBatch,
    EventBatchBuilder,
    EventKind,
    EventStream,
)
from repro.core.mitigation import (
    ACTIONS,
    ActionRecord,
    EngineControls,
    MitigationController,
    NullEngine,
)
from repro.core.runbooks import (
    ALL_RUNBOOKS,
    BY_ID,
    BY_TABLE,
    DEFAULT_TABLES,
    RUNBOOK_3A,
    RUNBOOK_3B,
    RUNBOOK_3C,
    RUNBOOK_DPU,
    RunbookEntry,
    build_detectors,
)
from repro.core.telemetry import DPUAgent, TelemetryPlane, TelemetryStats

__all__ = [
    "ACTIONS", "ALL_DETECTORS", "ALL_RUNBOOKS", "Attribution", "Attributor",
    "BY_ID", "BY_TABLE", "CollectiveOp", "DEFAULT_TABLES", "Detector",
    "DetectorConfig",
    "DPUAgent", "EngineControls", "Event", "EventBatch",
    "EventBatchBuilder", "EventKind", "EventStream",
    "Finding", "ActionRecord", "MitigationController", "NullEngine",
    "RUNBOOK_3A", "RUNBOOK_3B", "RUNBOOK_3C", "RUNBOOK_DPU", "RunbookEntry",
    "TelemetryPlane", "TelemetryStats", "build_detectors",
]
