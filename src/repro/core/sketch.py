"""Line-rate streaming statistics for the DPU-analog telemetry plane.

A DPU processing packets at line rate cannot buffer traces; it keeps O(1)
per-flow state.  Every statistic detectors rely on is therefore implemented
as a constant-memory streaming sketch:

  EWMA          — exponentially weighted mean (+variance, Welford-style)
  P2Quantile    — Jain & Chlamtac's P² algorithm: quantile without storage
  CUSUM         — one-sided cumulative-sum change-point detector
  RateMeter     — events/bytes per second over a sliding decay window
  GapTracker    — inter-arrival gap stats (starvation / jitter signals)
  SpreadTracker — max-min arrival spread within tagged groups (straggler signal)
  BurstMeter    — short-window burst magnitude vs long-window baseline

All pure Python / float math — no JAX — because these run on the host telemetry
path, off the accelerator critical path (the paper's "offload to the DPU").
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


class EWMA:
    """Exponentially weighted moving average and variance."""

    __slots__ = ("alpha", "mean", "var", "n")

    def __init__(self, alpha: float = 0.05) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.mean = 0.0
        self.var = 0.0
        self.n = 0

    def update(self, x: float) -> float:
        self.n += 1
        if self.n == 1:
            self.mean = x
            self.var = 0.0
        else:
            delta = x - self.mean
            self.mean += self.alpha * delta
            # EW variance (West 1979): decays old variance, adds new deviation.
            self.var = (1.0 - self.alpha) * (self.var + self.alpha * delta * delta)
        return self.mean

    @property
    def std(self) -> float:
        return math.sqrt(max(self.var, 0.0))

    def zscore(self, x: float) -> float:
        """How anomalous is x against the learned baseline."""
        if self.n < 2 or self.std == 0.0:
            return 0.0
        return (x - self.mean) / self.std


class P2Quantile:
    """P² algorithm (Jain & Chlamtac 1985): streaming quantile in O(1) memory.

    Tracks a single quantile q with five markers; no sample storage.  Accuracy
    is within a few percent for smooth distributions — exactly the trade a DPU
    makes.
    """

    __slots__ = ("q", "n", "heights", "pos", "desired", "incr", "count")

    def __init__(self, q: float = 0.99) -> None:
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0,1), got {q}")
        self.q = q
        self.heights: list[float] = []
        self.pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self.desired = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0]
        self.incr = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]
        self.count = 0

    def update(self, x: float) -> None:
        self.count += 1
        if len(self.heights) < 5:
            self.heights.append(x)
            self.heights.sort()
            return
        h = self.heights
        # locate cell k
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            for i in range(1, 4):
                if x < h[i]:
                    k = i - 1
                    break
            else:
                k = 3
        for i in range(k + 1, 5):
            self.pos[i] += 1.0
        for i in range(5):
            self.desired[i] += self.incr[i]
        # adjust interior markers with parabolic interpolation
        for i in range(1, 4):
            d = self.desired[i] - self.pos[i]
            if (d >= 1.0 and self.pos[i + 1] - self.pos[i] > 1.0) or (
                d <= -1.0 and self.pos[i - 1] - self.pos[i] < -1.0
            ):
                s = 1.0 if d >= 0 else -1.0
                hp = self._parabolic(i, s)
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:
                    h[i] = self._linear(i, s)
                self.pos[i] += s

    def _parabolic(self, i: int, s: float) -> float:
        h, p = self.heights, self.pos
        return h[i] + s / (p[i + 1] - p[i - 1]) * (
            (p[i] - p[i - 1] + s) * (h[i + 1] - h[i]) / (p[i + 1] - p[i])
            + (p[i + 1] - p[i] - s) * (h[i] - h[i - 1]) / (p[i] - p[i - 1])
        )

    def _linear(self, i: int, s: float) -> float:
        h, p = self.heights, self.pos
        j = i + int(s)
        return h[i] + s * (h[j] - h[i]) / (p[j] - p[i])

    @property
    def value(self) -> float:
        if not self.heights:
            return 0.0
        if len(self.heights) < 5:
            # exact small-sample quantile
            idx = min(int(self.q * len(self.heights)), len(self.heights) - 1)
            return sorted(self.heights)[idx]
        return self.heights[2]


class CUSUM:
    """One-sided cumulative-sum change detector on a drifting baseline.

    Fires when the cumulative positive deviation from (baseline + slack)
    exceeds ``threshold`` standard-ish units.  Self-calibrating: the baseline
    is an EWMA of the input, so detectors need no per-workload tuning.
    """

    __slots__ = ("baseline", "slack", "rel_slack", "threshold", "stat",
                 "fired_at", "n")

    def __init__(self, slack: float = 0.5, threshold: float = 5.0,
                 alpha: float = 0.02, rel_slack: float = 0.05) -> None:
        self.baseline = EWMA(alpha)
        self.slack = slack
        # floor the deviation scale at rel_slack * |mean| so near-constant
        # streams (std -> 0) don't turn numeric noise into huge z-scores
        self.rel_slack = rel_slack
        self.threshold = threshold
        self.stat = 0.0
        self.fired_at: int | None = None
        self.n = 0

    def update(self, x: float) -> bool:
        self.n += 1
        if self.baseline.n >= 8:  # need a warm baseline before accumulating
            scale = max(self.baseline.std,
                        self.rel_slack * abs(self.baseline.mean), 1e-9)
            dev = (x - self.baseline.mean) / scale - self.slack
            self.stat = max(0.0, self.stat + dev)
        self.baseline.update(x)
        fired = self.stat > self.threshold
        if fired and self.fired_at is None:
            self.fired_at = self.n
        return fired

    def reset(self) -> None:
        self.stat = 0.0
        self.fired_at = None


class RateMeter:
    """Decayed events/sec and bytes/sec meter (token-bucket style)."""

    __slots__ = ("halflife", "_rate", "_brate", "_last_ts")

    def __init__(self, halflife: float = 0.1) -> None:
        self.halflife = halflife
        self._rate = 0.0
        self._brate = 0.0
        self._last_ts: float | None = None

    def update(self, ts: float, nbytes: int = 0) -> None:
        if self._last_ts is None:
            self._last_ts = ts
            self._rate = 0.0
            self._brate = 0.0
            return
        dt = max(ts - self._last_ts, 1e-9)
        decay = 0.5 ** (dt / self.halflife)
        self._rate = self._rate * decay + (1.0 - decay) / dt
        self._brate = self._brate * decay + (1.0 - decay) * nbytes / dt
        self._last_ts = ts

    @property
    def rate(self) -> float:
        return self._rate

    @property
    def byte_rate(self) -> float:
        return self._brate

    def rate_at(self, now: float) -> float:
        """Event rate with decay applied up to ``now`` (for stale reads)."""
        if self._last_ts is None:
            return 0.0
        return self._rate * 0.5 ** (max(now - self._last_ts, 0.0)
                                    / self.halflife)

    def byte_rate_at(self, now: float) -> float:
        if self._last_ts is None:
            return 0.0
        return self._brate * 0.5 ** (max(now - self._last_ts, 0.0)
                                     / self.halflife)


class GapTracker:
    """Inter-arrival gap statistics: mean/EW-variance + running max gap.

    Starvation red flags ("long gaps between ingress packets", Table 3a row 2;
    "doorbells sporadic", 3b row 3) and jitter ("packets spread unevenly over
    time", 3a row 6) both reduce to gap statistics.
    """

    __slots__ = ("gaps", "last_ts", "max_gap", "p99")

    def __init__(self, alpha: float = 0.05) -> None:
        self.gaps = EWMA(alpha)
        self.p99 = P2Quantile(0.99)
        self.last_ts: float | None = None
        self.max_gap = 0.0

    def update(self, ts: float) -> float:
        """Returns the gap that just closed (0.0 for the first event)."""
        if self.last_ts is None:
            self.last_ts = ts
            return 0.0
        gap = ts - self.last_ts
        self.last_ts = ts
        self.gaps.update(gap)
        self.p99.update(gap)
        self.max_gap = max(self.max_gap, gap)
        return gap

    def current_gap(self, now: float) -> float:
        """Open gap since the last event — the live starvation signal."""
        if self.last_ts is None:
            return 0.0
        return now - self.last_ts

    def jitter(self) -> float:
        """Coefficient of variation of inter-arrival gaps."""
        if self.gaps.n < 2 or self.gaps.mean <= 0.0:
            return 0.0
        return self.gaps.std / self.gaps.mean


class SpreadTracker:
    """Max-min arrival spread within tagged rounds (the straggler statistic).

    Table 3c row 1 (TP straggler): "wide arrival spread of collective bursts
    (max-min arrival gap up)".  Each collective round r collects one arrival
    timestamp per participant; spread(r) = max - min.  We keep an EWMA of the
    spread plus the worst offender identity counts.
    """

    __slots__ = ("spread", "arrivals", "late_counts", "expected", "rounds")

    def __init__(self, expected: int, alpha: float = 0.1) -> None:
        self.expected = expected
        self.spread = EWMA(alpha)
        self.arrivals: dict[int, dict[int, float]] = {}
        self.late_counts: dict[int, int] = {}
        self.rounds = 0

    MIN_SPREAD = 1e-6   # ignore tie rounds: a zero/near-zero spread has no
                        # meaningful "slowest" participant

    def update(self, round_id: int, participant: int, ts: float) -> float | None:
        """Record an arrival; returns the spread when the round completes."""
        arr = self.arrivals.setdefault(round_id, {})
        arr[participant] = ts
        if len(arr) < self.expected:
            return None
        self.rounds += 1
        tss = arr.values()
        spread = max(tss) - min(tss)
        if spread > self.MIN_SPREAD:
            slowest = max(arr, key=arr.__getitem__)
            self.late_counts[slowest] = self.late_counts.get(slowest, 0) + 1
        self.spread.update(spread)
        del self.arrivals[round_id]
        return spread

    def dominant_straggler(self) -> tuple[int, float]:
        """(participant, fraction of rounds it was slowest)."""
        if not self.late_counts or self.rounds == 0:
            return (-1, 0.0)
        worst = max(self.late_counts, key=self.late_counts.__getitem__)
        return worst, self.late_counts[worst] / self.rounds


class BurstMeter:
    """Short-window rate vs long-window baseline — the microburst statistic.

    Table 3a row 1 (burst admission backlog) and §4.1 "early detection of
    microbursts".  burstiness() >> 1 means a short spike well above sustained
    load.
    """

    __slots__ = ("fast", "slow")

    def __init__(self, fast_halflife: float = 0.005,
                 slow_halflife: float = 0.5) -> None:
        self.fast = RateMeter(fast_halflife)
        self.slow = RateMeter(slow_halflife)

    def update(self, ts: float, nbytes: int = 0) -> None:
        self.fast.update(ts, nbytes)
        self.slow.update(ts, nbytes)

    def burstiness(self) -> float:
        if self.slow.rate <= 1e-9:
            return 0.0
        return self.fast.rate / self.slow.rate

    def byte_burstiness(self) -> float:
        if self.slow.byte_rate <= 1e-9:
            return 0.0
        return self.fast.byte_rate / self.slow.byte_rate


@dataclass
class Welford:
    """Exact running mean/variance (for finite populations, e.g. per-node
    volume skew where the population is the node set, not a stream)."""

    n: int = 0
    mean: float = 0.0
    m2: float = 0.0

    def update(self, x: float) -> None:
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    @property
    def var(self) -> float:
        return self.m2 / self.n if self.n > 1 else 0.0

    @property
    def std(self) -> float:
        return math.sqrt(max(self.var, 0.0))

    def cv(self) -> float:
        """Coefficient of variation — the load-skew statistic (3c row 3)."""
        return self.std / self.mean if self.mean > 0 else 0.0
