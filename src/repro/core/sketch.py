"""Line-rate streaming statistics for the DPU-analog telemetry plane.

A DPU processing packets at line rate cannot buffer traces; it keeps O(1)
per-flow state.  Every statistic detectors rely on is therefore implemented
as a constant-memory streaming sketch:

  EWMA          — exponentially weighted mean (+variance, Welford-style)
  P2Quantile    — Jain & Chlamtac's P² algorithm: quantile without storage
  CUSUM         — one-sided cumulative-sum change-point detector
  RateMeter     — events/bytes per second over a sliding decay window
  GapTracker    — inter-arrival gap stats (starvation / jitter signals)
  SpreadTracker — max-min arrival spread within tagged groups (straggler signal)
  BurstMeter    — short-window burst magnitude vs long-window baseline

All pure Python / float math — no JAX — because these run on the host telemetry
path, off the accelerator critical path (the paper's "offload to the DPU").
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


class EWMA:
    """Exponentially weighted moving average and variance."""

    __slots__ = ("alpha", "mean", "var", "n")

    def __init__(self, alpha: float = 0.05) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.mean = 0.0
        self.var = 0.0
        self.n = 0

    def update(self, x: float) -> float:
        self.n += 1
        if self.n == 1:
            self.mean = x
            self.var = 0.0
        else:
            delta = x - self.mean
            self.mean += self.alpha * delta
            # EW variance (West 1979): decays old variance, adds new deviation.
            self.var = (1.0 - self.alpha) * (self.var + self.alpha * delta * delta)
        return self.mean

    def update_many(self, xs) -> float:
        """Batch update — bit-identical to calling ``update`` per element.

        The recurrence is inherently sequential (mean_i depends on mean_i-1)
        so the batch form cannot reorder the float math; the win is purely
        mechanical: one call, locals-bound loop, no per-element dispatch.
        """
        a = self.alpha
        one_m = 1.0 - a
        mean = self.mean
        var = self.var
        n = self.n
        for x in xs:
            n += 1
            if n == 1:
                mean = x
                var = 0.0
            else:
                delta = x - mean
                mean += a * delta
                var = one_m * (var + a * delta * delta)
        self.mean = mean
        self.var = var
        self.n = n
        return mean

    @property
    def std(self) -> float:
        return math.sqrt(max(self.var, 0.0))

    def zscore(self, x: float) -> float:
        """How anomalous is x against the learned baseline."""
        if self.n < 2 or self.std == 0.0:
            return 0.0
        return (x - self.mean) / self.std


class P2Quantile:
    """P² algorithm (Jain & Chlamtac 1985): streaming quantile in O(1) memory.

    Tracks a single quantile q with five markers; no sample storage.  Accuracy
    is within a few percent for smooth distributions — exactly the trade a DPU
    makes.
    """

    __slots__ = ("q", "n", "heights", "pos", "desired", "incr", "count")

    def __init__(self, q: float = 0.99) -> None:
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0,1), got {q}")
        self.q = q
        self.heights: list[float] = []
        self.pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self.desired = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0]
        self.incr = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]
        self.count = 0

    def update(self, x: float) -> None:
        self.count += 1
        if len(self.heights) < 5:
            self.heights.append(x)
            self.heights.sort()
            return
        h = self.heights
        # locate cell k
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            for i in range(1, 4):
                if x < h[i]:
                    k = i - 1
                    break
            else:
                k = 3
        for i in range(k + 1, 5):
            self.pos[i] += 1.0
        for i in range(5):
            self.desired[i] += self.incr[i]
        # adjust interior markers with parabolic interpolation
        for i in range(1, 4):
            d = self.desired[i] - self.pos[i]
            if (d >= 1.0 and self.pos[i + 1] - self.pos[i] > 1.0) or (
                d <= -1.0 and self.pos[i - 1] - self.pos[i] < -1.0
            ):
                s = 1.0 if d >= 0 else -1.0
                hp = self._parabolic(i, s)
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:
                    h[i] = self._linear(i, s)
                self.pos[i] += s

    def update_many(self, xs) -> None:
        """Batch update — bit-identical to per-element ``update`` calls.

        P² marker motion is strictly sequential, so this is the same
        algorithm with the interpreter overhead stripped: bound locals,
        branch-ladder cell location, and the marker-adjustment loop inlined.
        """
        h = self.heights
        pos = self.pos
        desired = self.desired
        incr = self.incr
        count = self.count
        n = len(xs)
        j0 = 0
        while len(h) < 5 and j0 < n:
            h.append(xs[j0])
            h.sort()
            count += 1
            j0 += 1
        inc1, inc2, inc3, inc4 = incr[1], incr[2], incr[3], incr[4]
        parabolic = self._parabolic
        linear = self._linear
        for j in range(j0, n):
            x = xs[j]
            count += 1
            if x < h[0]:
                h[0] = x
                k = 0
            elif x >= h[4]:
                h[4] = x
                k = 3
            elif x < h[1]:
                k = 0
            elif x < h[2]:
                k = 1
            elif x < h[3]:
                k = 2
            else:
                k = 3
            for i in range(k + 1, 5):
                pos[i] += 1.0
            desired[1] += inc1
            desired[2] += inc2
            desired[3] += inc3
            desired[4] += inc4
            for i in (1, 2, 3):
                d = desired[i] - pos[i]
                if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or (
                        d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                    s = 1.0 if d >= 0 else -1.0
                    hp = parabolic(i, s)
                    if h[i - 1] < hp < h[i + 1]:
                        h[i] = hp
                    else:
                        h[i] = linear(i, s)
                    pos[i] += s
        self.count = count

    def _parabolic(self, i: int, s: float) -> float:
        h, p = self.heights, self.pos
        return h[i] + s / (p[i + 1] - p[i - 1]) * (
            (p[i] - p[i - 1] + s) * (h[i + 1] - h[i]) / (p[i + 1] - p[i])
            + (p[i + 1] - p[i] - s) * (h[i] - h[i - 1]) / (p[i] - p[i - 1])
        )

    def _linear(self, i: int, s: float) -> float:
        h, p = self.heights, self.pos
        j = i + int(s)
        return h[i] + s * (h[j] - h[i]) / (p[j] - p[i])

    @property
    def value(self) -> float:
        if not self.heights:
            return 0.0
        if len(self.heights) < 5:
            # exact small-sample quantile
            idx = min(int(self.q * len(self.heights)), len(self.heights) - 1)
            return sorted(self.heights)[idx]
        return self.heights[2]


class CUSUM:
    """One-sided cumulative-sum change detector on a drifting baseline.

    Fires when the cumulative positive deviation from (baseline + slack)
    exceeds ``threshold`` standard-ish units.  Self-calibrating: the baseline
    is an EWMA of the input, so detectors need no per-workload tuning.
    """

    __slots__ = ("baseline", "slack", "rel_slack", "threshold", "stat",
                 "fired_at", "n")

    def __init__(self, slack: float = 0.5, threshold: float = 5.0,
                 alpha: float = 0.02, rel_slack: float = 0.05) -> None:
        self.baseline = EWMA(alpha)
        self.slack = slack
        # floor the deviation scale at rel_slack * |mean| so near-constant
        # streams (std -> 0) don't turn numeric noise into huge z-scores
        self.rel_slack = rel_slack
        self.threshold = threshold
        self.stat = 0.0
        self.fired_at: int | None = None
        self.n = 0

    def update(self, x: float) -> bool:
        self.n += 1
        if self.baseline.n >= 8:  # need a warm baseline before accumulating
            scale = max(self.baseline.std,
                        self.rel_slack * abs(self.baseline.mean), 1e-9)
            dev = (x - self.baseline.mean) / scale - self.slack
            self.stat = max(0.0, self.stat + dev)
        self.baseline.update(x)
        fired = self.stat > self.threshold
        if fired and self.fired_at is None:
            self.fired_at = self.n
        return fired

    def update_many(self, xs) -> bool:
        """Batch update — bit-identical to per-element ``update`` calls."""
        fired = False
        for x in xs:
            fired = self.update(x)
        return fired

    def reset(self) -> None:
        self.stat = 0.0
        self.fired_at = None


class RateMeter:
    """Decayed events/sec and bytes/sec meter (token-bucket style)."""

    __slots__ = ("halflife", "_rate", "_brate", "_last_ts")

    def __init__(self, halflife: float = 0.1) -> None:
        self.halflife = halflife
        self._rate = 0.0
        self._brate = 0.0
        self._last_ts: float | None = None

    def update(self, ts: float, nbytes: int = 0) -> None:
        if self._last_ts is None:
            self._last_ts = ts
            self._rate = 0.0
            self._brate = 0.0
            return
        dt = max(ts - self._last_ts, 1e-9)
        decay = 0.5 ** (dt / self.halflife)
        self._rate = self._rate * decay + (1.0 - decay) / dt
        self._brate = self._brate * decay + (1.0 - decay) * nbytes / dt
        self._last_ts = ts

    def update_many(self, tss, sizes=None) -> None:
        """Batch update — bit-identical to per-element ``update`` calls.

        ``tss`` is an ascending timestamp sequence; ``sizes`` an optional
        same-length byte sequence (None = all zero).  The decay recurrence is
        sequential (and ``0.5 ** x`` must stay the interpreter's pow — numpy's
        vectorized pow rounds differently), so this is a locals-bound loop.
        """
        n = len(tss)
        if n == 0:
            return
        hl = self.halflife
        last = self._last_ts
        rate = self._rate
        brate = self._brate
        i = 0
        if last is None:
            last = tss[0]
            rate = 0.0
            brate = 0.0
            i = 1
        if sizes is None:
            # scalar adds (1-decay)*0/dt == +0.0 to brate; brate >= 0.0
            # always, so dropping the term is bit-exact
            for j in range(i, n):
                ts = tss[j]
                dt = ts - last
                if dt < 1e-9:
                    dt = 1e-9
                decay = 0.5 ** (dt / hl)
                rate = rate * decay + (1.0 - decay) / dt
                brate = brate * decay
                last = ts
        else:
            for j in range(i, n):
                ts = tss[j]
                dt = ts - last
                if dt < 1e-9:
                    dt = 1e-9
                decay = 0.5 ** (dt / hl)
                one_m = 1.0 - decay
                rate = rate * decay + one_m / dt
                brate = brate * decay + one_m * sizes[j] / dt
                last = ts
        self._last_ts = last
        self._rate = rate
        self._brate = brate

    @property
    def rate(self) -> float:
        return self._rate

    @property
    def byte_rate(self) -> float:
        return self._brate

    def rate_at(self, now: float) -> float:
        """Event rate with decay applied up to ``now`` (for stale reads)."""
        if self._last_ts is None:
            return 0.0
        return self._rate * 0.5 ** (max(now - self._last_ts, 0.0)
                                    / self.halflife)

    def byte_rate_at(self, now: float) -> float:
        if self._last_ts is None:
            return 0.0
        return self._brate * 0.5 ** (max(now - self._last_ts, 0.0)
                                     / self.halflife)


class GapTracker:
    """Inter-arrival gap statistics: mean/EW-variance + running max gap.

    Starvation red flags ("long gaps between ingress packets", Table 3a row 2;
    "doorbells sporadic", 3b row 3) and jitter ("packets spread unevenly over
    time", 3a row 6) both reduce to gap statistics.

    The P² p99 sketch is by far the most expensive per-gap work, and most
    consumers never read it (jitter/mean-only detectors), or stop reading it
    once they freeze a warmup reference.  ``track_p99=False`` drops it;
    ``p99_cap=N`` stops feeding it after N gaps (the reference-freeze
    pattern: the value is only consulted while ``gaps.n <= N``).
    """

    __slots__ = ("gaps", "last_ts", "max_gap", "p99", "p99_cap")

    def __init__(self, alpha: float = 0.05, track_p99: bool = True,
                 p99_cap: int | None = None) -> None:
        self.gaps = EWMA(alpha)
        self.p99: P2Quantile | None = P2Quantile(0.99) if track_p99 else None
        self.p99_cap = p99_cap
        self.last_ts: float | None = None
        self.max_gap = 0.0

    def update(self, ts: float) -> float:
        """Returns the gap that just closed (0.0 for the first event)."""
        if self.last_ts is None:
            self.last_ts = ts
            return 0.0
        gap = ts - self.last_ts
        self.last_ts = ts
        self.gaps.update(gap)
        if self.p99 is not None and (self.p99_cap is None
                                     or self.gaps.n <= self.p99_cap):
            self.p99.update(gap)
        if gap > self.max_gap:
            self.max_gap = gap
        return gap

    def update_many(self, tss) -> None:
        """Batch update — bit-identical to per-element ``update`` calls.

        ``tss`` is an ascending timestamp sequence.  Gap extraction is a
        plain successive subtraction (exactly the scalar op); the EW/max
        fold is inlined into the same pass, and the P² fold (when tracked)
        reuses the quantile sketch's batch form.
        """
        n = len(tss)
        if n == 0:
            return
        last = self.last_ts
        i = 0
        if last is None:
            last = tss[0]
            i = 1
        if i >= n:
            self.last_ts = last
            return
        ew = self.gaps
        a = ew.alpha
        one_m = 1.0 - a
        mean = ew.mean
        var = ew.var
        ew_n = ew.n
        max_gap = self.max_gap
        p99 = self.p99
        cap = self.p99_cap
        want_p99 = p99 is not None and (cap is None or ew_n < cap)
        gaps = [] if want_p99 else None
        for j in range(i, n):
            ts = tss[j]
            gap = ts - last
            last = ts
            if want_p99:
                gaps.append(gap)
            ew_n += 1
            if ew_n == 1:
                mean = gap
                var = 0.0
            else:
                delta = gap - mean
                mean += a * delta
                var = one_m * (var + a * delta * delta)
            if gap > max_gap:
                max_gap = gap
        self.last_ts = last
        ew.mean = mean
        ew.var = var
        ew.n = ew_n
        self.max_gap = max_gap
        if want_p99:
            p99.update_many(gaps if cap is None
                            else gaps[:cap - (ew_n - len(gaps))])

    def current_gap(self, now: float) -> float:
        """Open gap since the last event — the live starvation signal."""
        if self.last_ts is None:
            return 0.0
        return now - self.last_ts

    def jitter(self) -> float:
        """Coefficient of variation of inter-arrival gaps."""
        if self.gaps.n < 2 or self.gaps.mean <= 0.0:
            return 0.0
        return self.gaps.std / self.gaps.mean


class SpreadTracker:
    """Max-min arrival spread within tagged rounds (the straggler statistic).

    Table 3c row 1 (TP straggler): "wide arrival spread of collective bursts
    (max-min arrival gap up)".  Each collective round r collects one arrival
    timestamp per participant; spread(r) = max - min.  We keep an EWMA of the
    spread plus the worst offender identity counts.
    """

    __slots__ = ("spread", "arrivals", "late_counts", "expected", "rounds")

    def __init__(self, expected: int, alpha: float = 0.1) -> None:
        self.expected = expected
        self.spread = EWMA(alpha)
        self.arrivals: dict[int, dict[int, float]] = {}
        self.late_counts: dict[int, int] = {}
        self.rounds = 0

    MIN_SPREAD = 1e-6   # ignore tie rounds: a zero/near-zero spread has no
                        # meaningful "slowest" participant

    def update(self, round_id: int, participant: int, ts: float) -> float | None:
        """Record an arrival; returns the spread when the round completes."""
        arr = self.arrivals.setdefault(round_id, {})
        arr[participant] = ts
        if len(arr) < self.expected:
            return None
        self.rounds += 1
        tss = arr.values()
        spread = max(tss) - min(tss)
        if spread > self.MIN_SPREAD:
            slowest = max(arr, key=arr.__getitem__)
            self.late_counts[slowest] = self.late_counts.get(slowest, 0) + 1
        self.spread.update(spread)
        del self.arrivals[round_id]
        return spread

    def dominant_straggler(self) -> tuple[int, float]:
        """(participant, fraction of rounds it was slowest)."""
        if not self.late_counts or self.rounds == 0:
            return (-1, 0.0)
        worst = max(self.late_counts, key=self.late_counts.__getitem__)
        return worst, self.late_counts[worst] / self.rounds


class BurstMeter:
    """Short-window rate vs long-window baseline — the microburst statistic.

    Table 3a row 1 (burst admission backlog) and §4.1 "early detection of
    microbursts".  burstiness() >> 1 means a short spike well above sustained
    load.
    """

    __slots__ = ("fast", "slow")

    def __init__(self, fast_halflife: float = 0.005,
                 slow_halflife: float = 0.5) -> None:
        self.fast = RateMeter(fast_halflife)
        self.slow = RateMeter(slow_halflife)

    def update(self, ts: float, nbytes: int = 0) -> None:
        self.fast.update(ts, nbytes)
        self.slow.update(ts, nbytes)

    def burstiness(self) -> float:
        if self.slow.rate <= 1e-9:
            return 0.0
        return self.fast.rate / self.slow.rate

    def byte_burstiness(self) -> float:
        if self.slow.byte_rate <= 1e-9:
            return 0.0
        return self.fast.byte_rate / self.slow.byte_rate


@dataclass
class Welford:
    """Exact running mean/variance (for finite populations, e.g. per-node
    volume skew where the population is the node set, not a stream)."""

    n: int = 0
    mean: float = 0.0
    m2: float = 0.0

    def update(self, x: float) -> None:
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    @property
    def var(self) -> float:
        return self.m2 / self.n if self.n > 1 else 0.0

    @property
    def std(self) -> float:
        return math.sqrt(max(self.var, 0.0))

    def cv(self) -> float:
        """Coefficient of variation — the load-skew statistic (3c row 3)."""
        return self.std / self.mean if self.mean > 0 else 0.0
