"""DPU-visible event schema — the paper's observability boundary, enforced.

The paper (§4.1-4.3) is precise about what an out-of-band observer (a DPU
inline with the NIC and sitting as a PCIe peer) can and cannot see:

CAN see   : every ingress/egress packet (sub-microsecond timestamps, sizes,
            retransmit flags), every host<->device DMA transaction, doorbell
            writes (timing only), RDMA/collective bursts on the wire, NIC and
            queue depths.
CANNOT see: intra-device compute (matmuls, attention math, kernel utilization,
            HBM traffic), NVLink-only collectives, CPU-only work (§4.3).

This module encodes that boundary in the type system: there is deliberately NO
event kind that carries intra-device compute information.  Detectors consume
only these events; tests assert the enum stays closed.

On TPU the vantage points map as (see DESIGN.md §2):
  N-S  -> serving front-end request taps,
  PCIe -> host<->device transfer taps around the JAX runtime boundary,
  E-W  -> ICI collective bursts (sizes statically exact from compiled HLO,
          timing from per-host step beacons).
"""

from __future__ import annotations

import enum
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Sequence

import numpy as np


class EventKind(enum.IntEnum):
    """Closed set of DPU-observable event kinds.

    Order groups the three vantage points of the paper's three runbooks.
    """

    # --- North-South (NIC inline; Table 3a) ---
    INGRESS_PKT = 0       # request bytes arriving from clients
    EGRESS_PKT = 1        # response/token bytes leaving toward clients
    RETRANSMIT = 2        # observed retransmission / duplicate ACK
    QUEUE_SAMPLE = 3      # periodic NIC / scheduler queue-depth sample

    # --- PCIe peer (host<->device path; Table 3b) ---
    H2D_XFER = 4          # host-to-device DMA (bytes, device, flow)
    D2H_XFER = 5          # device-to-host DMA (bytes, device, flow)
    DISPATCH = 6          # doorbell-analog: a launch happened (timing ONLY)
    MEM_REG = 7           # memory map/unmap (registration churn)

    # --- East-West (inter-node wire; Table 3c) ---
    COLLECTIVE_BURST = 8  # collective traffic burst (op kind, bytes, group)
    P2P_BURST = 9         # point-to-point transfer (PP handoff, KV migration)
    CREDIT_UPDATE = 10    # RDMA flow-control credit grant observed


#: Kinds belonging to each vantage point (used by the attribution engine).
NORTH_SOUTH = frozenset(
    {EventKind.INGRESS_PKT, EventKind.EGRESS_PKT, EventKind.RETRANSMIT,
     EventKind.QUEUE_SAMPLE}
)
PCIE = frozenset(
    {EventKind.H2D_XFER, EventKind.D2H_XFER, EventKind.DISPATCH,
     EventKind.MEM_REG}
)
EAST_WEST = frozenset(
    {EventKind.COLLECTIVE_BURST, EventKind.P2P_BURST, EventKind.CREDIT_UPDATE}
)


class CollectiveOp(enum.IntEnum):
    ALL_REDUCE = 0
    ALL_GATHER = 1
    REDUCE_SCATTER = 2
    ALL_TO_ALL = 3
    PERMUTE = 4


#: Group-id conventions for the per-collective emission tier.  The aggregate
#: TP all-reduce keeps its legacy id (group 0); the split per-op phases and
#: the rail/domain topology tier use dedicated ranges so consumers can
#: separate the tiers without any new event kinds (the enum stays closed):
#:
#:   group 0                    — aggregate TP all-reduce (legacy rows)
#:   COLL_GROUP_ALL_GATHER      — per-op all-gather rows
#:   COLL_GROUP_REDUCE_SCATTER  — per-op reduce-scatter rows
#:   RAIL_GROUP_BASE + r        — cross-domain traffic sharing rail ``r``
#:   DOMAIN_GROUP_BASE + d      — intra-domain fast-tier bursts in domain ``d``
#:
#: Per-op rows use ``depth`` as the edge marker (COLL_EDGE_*): the start row
#: carries the op's wire bytes in ``size``; the finish row is a zero-byte
#: timing edge — both are wire-visible burst boundaries, not device state.
COLL_GROUP_ALL_GATHER = 1
COLL_GROUP_REDUCE_SCATTER = 2
RAIL_GROUP_BASE = 200
DOMAIN_GROUP_BASE = 300
COLL_EDGE_START = 0
COLL_EDGE_FINISH = 1


@dataclass(frozen=True, slots=True)
class Event:
    """One observation at the DPU vantage point.

    Fields are the superset a BlueField-class observer exports; unused fields
    default to neutral values so the record stays a flat, cheap struct.
    """

    ts: float                 # seconds; sub-microsecond resolution in the sim
    kind: EventKind
    node: int                 # host/node id where observed
    device: int = -1          # local device id (PCIe events), -1 = n/a
    flow: int = -1            # request/flow/session id, -1 = n/a
    size: int = 0             # bytes on the wire / DMA transaction size
    depth: int = 0            # queue depth (QUEUE_SAMPLE) or credit count
    op: int = -1              # CollectiveOp for COLLECTIVE_BURST, -1 otherwise
    group: int = -1           # collective/TP/PP group id
    meta: int = 0             # small free int (e.g. stage id, retry count)
    replica: int = -1         # data-parallel replica the node belongs to

    def vantage(self) -> str:
        if self.kind in NORTH_SOUTH:
            return "north-south"
        if self.kind in PCIE:
            return "pcie"
        return "east-west"


# Forbidden concepts: the schema must never grow fields/kinds that expose
# intra-device compute.  Tests grep these names against the module source.
FORBIDDEN_OBSERVABLES = (
    "flops", "kernel_name", "hbm_bytes", "sm_util", "mxu_util",
    "arithmetic_intensity", "register", "warp", "occupancy",
)


#: Column order of the columnar event representation — mirrors Event's fields.
BATCH_COLUMNS = ("ts", "kind", "node", "device", "flow", "size", "depth",
                 "op", "group", "meta", "replica")


class EventBatch:
    """Structure-of-arrays view of many Events — the line-rate wire format.

    A DPU exports telemetry as ring-buffer DMA of fixed-width records, not as
    per-packet host callbacks; ``EventBatch`` is that ring in memory: one
    float64 array of timestamps plus int64 arrays for every other column,
    time-sorted.  Producers (the simulator, the serving engine, the router)
    fill an ``EventBatchBuilder`` per phase and hand the built batch to
    ``TelemetryPlane.observe_batch``; vectorized detectors consume the columns
    directly and never materialize per-event records.

    ``iter_events()`` materializes ``Event`` objects for the scalar fallback
    path and caches them, so several non-vectorized detectors sharing a batch
    pay the (expensive) materialization once.
    """

    __slots__ = BATCH_COLUMNS + ("_events", "batch_seq", "checksum")

    def __init__(self, ts: np.ndarray, kind: np.ndarray, node: np.ndarray,
                 device: np.ndarray, flow: np.ndarray, size: np.ndarray,
                 depth: np.ndarray, op: np.ndarray, group: np.ndarray,
                 meta: np.ndarray, replica: np.ndarray) -> None:
        self.ts = ts
        self.kind = kind
        self.node = node
        self.device = device
        self.flow = flow
        self.size = size
        self.depth = depth
        self.op = op
        self.group = group
        self.meta = meta
        self.replica = replica
        self._events: list[Event] | None = None
        # wire metadata, stamped by the sender (tap) side; -1/None = unset.
        # Derived batches (slice/compress) intentionally do NOT inherit
        # either field: they are new in-memory objects, not wire frames.
        self.batch_seq: int = -1
        self.checksum: int | None = None

    # -- wire integrity ---------------------------------------------------

    def content_checksum(self) -> int:
        """Cheap order-sensitive content digest for the modeled wire.

        Not cryptographic — it only needs to catch the simulated bit-rot a
        ``ModeledLink`` corruptor injects.  Computed lazily (only when a
        link's corruption knob is on), so the zero-knob hot path never pays
        for it.
        """
        acc = int(np.int64(len(self)))
        for i, col in enumerate(self.columns(), start=1):
            if col.dtype == np.float64:
                view = col.view(np.int64)
            else:
                view = col
            # wrap-around int64 sum, position-salted so column swaps and
            # row reorders change the digest
            s = int(np.bitwise_xor.reduce(
                view * np.int64(0x9E3779B1 * i))) if len(view) else 0
            acc ^= (s + i) & 0xFFFFFFFFFFFFFFFF
        return acc & 0xFFFFFFFFFFFFFFFF

    # -- construction ----------------------------------------------------

    @classmethod
    def from_events(cls, events: Sequence[Event],
                    sort: bool = True) -> "EventBatch":
        b = EventBatchBuilder()
        for ev in events:
            b.add_event(ev)
        return b.build(sort=sort)

    @classmethod
    def empty(cls) -> "EventBatch":
        z = np.empty(0, np.int64)
        return cls(np.empty(0, np.float64), z, z, z, z, z, z, z, z, z, z)

    # -- container protocol ---------------------------------------------

    def __len__(self) -> int:
        return self.ts.shape[0]

    def columns(self) -> tuple[np.ndarray, ...]:
        return tuple(getattr(self, c) for c in BATCH_COLUMNS)

    # -- derived batches (views / copies; caches are never shared) -------

    def slice(self, a: int, b: int) -> "EventBatch":
        """Contiguous sub-batch [a, b) — array views, O(1)."""
        return EventBatch(*(col[a:b] for col in self.columns()))

    def compress(self, mask: np.ndarray) -> "EventBatch":
        """Sub-batch of rows where ``mask`` is True (order preserved)."""
        idx = np.flatnonzero(mask)   # take() beats boolean-indexing 11 cols
        return EventBatch(*(col.take(idx) for col in self.columns()))

    # -- scalar interop --------------------------------------------------

    def iter_events(self) -> Iterator[Event]:
        """Materialize Events (cached) — the scalar-fallback bridge."""
        if self._events is None:
            kinds = [EventKind(k) for k in self.kind.tolist()]
            self._events = [
                Event(ts=t, kind=k, node=n, device=d, flow=f, size=s,
                      depth=q, op=o, group=g, meta=m, replica=r)
                for t, k, n, d, f, s, q, o, g, m, r in zip(
                    self.ts.tolist(), kinds, self.node.tolist(),
                    self.device.tolist(), self.flow.tolist(),
                    self.size.tolist(), self.depth.tolist(),
                    self.op.tolist(), self.group.tolist(),
                    self.meta.tolist(), self.replica.tolist())
            ]
        return iter(self._events)

    def to_events(self) -> list[Event]:
        return list(self.iter_events())


class EventBatchBuilder:
    """Columnar accumulator for one emission phase.

    Three append granularities, freely mixable (insertion order preserved):

      ``add``/``add_event`` — one row (the scalar compatibility path);
      ``add_many``          — row-staged bulk append: ``ts`` plus per-column
                              sequences/arrays or scalar broadcast;
      ``add_columns``       — the line-rate path: whole numpy column arrays
                              are appended as a chunk with no per-row Python
                              work (a simulator phase that synthesizes N
                              egress packets hands over N-row arrays once).

    ``build`` freezes everything into a time-sorted :class:`EventBatch`.
    Arrays passed to ``add_columns`` are adopted by the builder and must not
    be mutated by the caller afterwards.
    """

    __slots__ = ("_cols", "_chunk_cols", "_chunk_sizes")

    def __init__(self) -> None:
        # row staging (scalar adds) + sealed column chunks, in insertion
        # order: staged rows are sealed into a chunk whenever a column
        # chunk arrives, so build() sees one ordered chunk list
        self._cols: list[list] = [[] for _ in BATCH_COLUMNS]
        self._chunk_cols: list[list] = [[] for _ in BATCH_COLUMNS]
        self._chunk_sizes: list[int] = []

    def __len__(self) -> int:
        return sum(self._chunk_sizes) + len(self._cols[0])

    def clear(self) -> None:
        for c in self._cols:
            c.clear()
        for c in self._chunk_cols:
            c.clear()
        self._chunk_sizes.clear()

    def add(self, ts: float, kind: int, node: int, device: int = -1,
            flow: int = -1, size: int = 0, depth: int = 0, op: int = -1,
            group: int = -1, meta: int = 0, replica: int = -1) -> None:
        c = self._cols
        c[0].append(ts)
        c[1].append(int(kind))
        c[2].append(node)
        c[3].append(device)
        c[4].append(flow)
        c[5].append(size)
        c[6].append(depth)
        c[7].append(op)
        c[8].append(group)
        c[9].append(meta)
        c[10].append(replica)

    def add_event(self, ev: Event) -> None:
        self.add(ev.ts, int(ev.kind), ev.node, ev.device, ev.flow, ev.size,
                 ev.depth, ev.op, ev.group, ev.meta, ev.replica)

    def add_many(self, ts: Sequence[float], kind: int, node=0, device=-1,
                 flow=-1, size=0, depth=0, op=-1, group=-1, meta=0,
                 replica=-1) -> None:
        """Bulk append: ``ts`` is a sequence (list/tuple/ndarray); every
        other column is a same-length sequence/array or a scalar broadcast
        across the rows.  Lengths are validated; mismatches raise."""
        n = len(ts)
        if n == 0:
            return
        vals = (kind, node, device, flow, size, depth, op, group, meta,
                replica)
        # validate every column length BEFORE extending any row staging,
        # so a raised error cannot leave ragged partial rows behind
        for i, v in enumerate(vals, start=1):
            if isinstance(v, np.ndarray):
                if v.shape != (n,):
                    raise ValueError(
                        f"add_many: column {BATCH_COLUMNS[i]} has shape "
                        f"{v.shape}, expected ({n},)")
            elif isinstance(v, (list, tuple)) and len(v) != n:
                raise ValueError(
                    f"add_many: column {BATCH_COLUMNS[i]} has length "
                    f"{len(v)}, expected {n}")
        c = self._cols
        c[0].extend(ts.tolist() if isinstance(ts, np.ndarray) else ts)
        for i, v in enumerate(vals, start=1):
            if isinstance(v, np.ndarray):
                c[i].extend(v.tolist())
            elif isinstance(v, (list, tuple)):
                c[i].extend(v)
            else:
                c[i].extend(itertools.repeat(int(v), n))

    def add_columns(self, ts, kind, node=0, device=-1, flow=-1, size=0,
                    depth=0, op=-1, group=-1, meta=0, replica=-1) -> None:
        """Append whole column arrays as one chunk — zero per-row work.

        ``ts`` is a 1-D float array (or sequence); every other column is a
        same-length integer array or a scalar, broadcast lazily at
        ``build`` time (scalars are stored as-is, so an N-row chunk with
        ten scalar columns costs one array, not eleven).  Dtypes are
        validated: integer columns reject float arrays rather than
        silently truncating.
        """
        if type(ts) is not np.ndarray or ts.dtype != np.float64:
            ts = np.asarray(ts, np.float64)
        if ts.ndim != 1:
            raise ValueError(f"add_columns: ts must be 1-D, got {ts.shape}")
        n = ts.shape[0]
        if n == 0:
            return
        # validate/cook every column BEFORE touching builder state, so a
        # raised error cannot leave orphaned column fragments behind
        cooked = [ts]
        i = 1
        for v in (kind, node, device, flow, size, depth, op, group, meta,
                  replica):
            if isinstance(v, np.ndarray):
                if v.shape != (n,):
                    raise ValueError(
                        f"add_columns: column {BATCH_COLUMNS[i]} has shape "
                        f"{v.shape}, expected ({n},)")
                if v.dtype != np.int64:
                    if not np.issubdtype(v.dtype, np.integer):
                        raise TypeError(
                            f"add_columns: column {BATCH_COLUMNS[i]} has "
                            f"dtype {v.dtype}; integer required")
                    v = v.astype(np.int64)
                cooked.append(v)
            else:
                cooked.append(int(v))
            i += 1
        if self._cols[0]:
            self._seal_rows()
        chunk_cols = self._chunk_cols
        for i, v in enumerate(cooked):
            chunk_cols[i].append(v)
        self._chunk_sizes.append(n)

    def _seal_rows(self) -> None:
        if not self._cols[0]:
            return
        self._chunk_sizes.append(len(self._cols[0]))
        self._chunk_cols[0].append(np.asarray(self._cols[0], np.float64))
        for i in range(1, len(BATCH_COLUMNS)):
            self._chunk_cols[i].append(np.asarray(self._cols[i], np.int64))
        for c in self._cols:
            c.clear()

    def build(self, sort: bool = True) -> EventBatch:
        self._seal_rows()
        sizes = self._chunk_sizes
        if not sizes:
            return EventBatch.empty()
        if len(sizes) == 1:
            n = sizes[0]
            cols = [self._chunk_cols[0][0]]
            for col in self._chunk_cols[1:]:
                v = col[0]
                cols.append(v if isinstance(v, np.ndarray)
                            else np.full(n, v, np.int64))
        else:
            # preallocate + slice-fill: scalar chunks become C-level fills
            # instead of materialized broadcast arrays
            total = sum(sizes)
            cols = [np.concatenate(self._chunk_cols[0])]
            for col in self._chunk_cols[1:]:
                out = np.empty(total, np.int64)
                pos = 0
                for v, n in zip(col, sizes):
                    out[pos:pos + n] = v
                    pos += n
                cols.append(out)
        ts = cols[0]
        if sort and ts.shape[0] > 1 and np.any(ts[1:] < ts[:-1]):
            order = np.argsort(ts, kind="stable")
            cols = [col[order] for col in cols]
        return EventBatch(*cols)


class EventTraceRecorder:
    """Minimal observe_batch-protocol sink: records every emitted batch.

    Duck-type-compatible with the slot a ``TelemetryPlane`` occupies on a
    producer (``observe_batch`` + a falsy ``findings``), so benchmarks, the
    batch/scalar equivalence tests, and offline trace capture can tap the
    columnar wire format without running any detectors.
    """

    findings: tuple = ()

    def __init__(self) -> None:
        self.batches: list[EventBatch] = []

    def observe_batch(self, batch: "EventBatch") -> None:
        self.batches.append(batch)


class EventStream:
    """Bounded ring buffer of recent telemetry with batch fan-out.

    The simulator and the live engine both write here (per-event ``emit`` or
    columnar ``emit_batch``); detectors read.  Retention is bounded: the
    stream keeps at most ``capacity`` recent events (evicting whole chunks,
    oldest first) so a long sweep's memory stays flat — line-rate constraints
    on *state* are modeled by the sketches (O(1) memory); this container is
    the replay/debug window a DPU would hold in its ring.  Tests that need
    the complete trace pass ``full_trace=True``.

    Subscribers receive :class:`EventBatch` chunks (batch fan-out); a scalar
    ``emit`` wraps the event into a one-row batch only when subscribers
    exist, so the hot path pays nothing for an unused hook.
    """

    __slots__ = ("capacity", "full_trace", "_chunks", "_retained",
                 "_tail", "_total", "_subscribers")

    DEFAULT_CAPACITY = 1 << 16

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 full_trace: bool = False) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.full_trace = full_trace
        # chunks are either list[Event] (scalar emits) or EventBatch
        self._chunks: deque = deque()
        self._tail: list[Event] = []
        self._retained = 0      # events currently held
        self._total = 0         # events ever emitted
        self._subscribers: list[Callable[["EventBatch"], None]] = []

    # -- ingestion -------------------------------------------------------

    def emit(self, event: Event) -> None:
        self._tail.append(event)
        self._retained += 1
        self._total += 1
        if self._subscribers:
            batch = EventBatch.from_events([event], sort=False)
            for sub in self._subscribers:
                sub(batch)
        if len(self._tail) >= 1024:
            self._seal_tail()

    def emit_batch(self, batch: "EventBatch") -> None:
        n = len(batch)
        if n == 0:
            return
        self._seal_tail()
        self._chunks.append(batch)
        self._retained += n
        self._total += n
        for sub in self._subscribers:
            sub(batch)
        self._trim()

    def extend(self, events: Iterable[Event]) -> None:
        for e in events:
            self.emit(e)

    def subscribe(self, fn: Callable[["EventBatch"], None]) -> None:
        """Register a batch consumer: called with every emitted EventBatch
        (scalar emits arrive as one-row batches)."""
        self._subscribers.append(fn)

    def _seal_tail(self) -> None:
        if self._tail:
            self._chunks.append(self._tail)
            self._tail = []
            self._trim()

    def _trim(self) -> None:
        if self.full_trace:
            return
        # evict oldest whole chunks; retention is approximate at chunk
        # granularity, which keeps eviction O(1) amortized
        while self._retained > self.capacity and len(self._chunks) > 1:
            old = self._chunks.popleft()
            self._retained -= len(old)

    # -- reading ---------------------------------------------------------

    def __len__(self) -> int:
        return self._retained

    @property
    def total_events(self) -> int:
        """Events ever emitted (retention-independent counter)."""
        return self._total

    def __iter__(self) -> Iterator[Event]:
        for chunk in list(self._chunks):
            if isinstance(chunk, EventBatch):
                yield from chunk.iter_events()
            else:
                yield from chunk
        yield from list(self._tail)

    def select(
        self,
        kind: EventKind | None = None,
        node: int | None = None,
        device: int | None = None,
        flow: int | None = None,
        t0: float = float("-inf"),
        t1: float = float("inf"),
    ) -> list[Event]:
        out = []
        for e in self:
            if kind is not None and e.kind != kind:
                continue
            if node is not None and e.node != node:
                continue
            if device is not None and e.device != device:
                continue
            if flow is not None and e.flow != flow:
                continue
            if not (t0 <= e.ts <= t1):
                continue
            out.append(e)
        return out

    def merged(*streams: "EventStream") -> list[Event]:
        """Time-ordered merge of several per-node streams (cluster view)."""
        return sorted(
            itertools.chain.from_iterable(streams),
            key=lambda e: e.ts,
        )
