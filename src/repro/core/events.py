"""DPU-visible event schema — the paper's observability boundary, enforced.

The paper (§4.1-4.3) is precise about what an out-of-band observer (a DPU
inline with the NIC and sitting as a PCIe peer) can and cannot see:

CAN see   : every ingress/egress packet (sub-microsecond timestamps, sizes,
            retransmit flags), every host<->device DMA transaction, doorbell
            writes (timing only), RDMA/collective bursts on the wire, NIC and
            queue depths.
CANNOT see: intra-device compute (matmuls, attention math, kernel utilization,
            HBM traffic), NVLink-only collectives, CPU-only work (§4.3).

This module encodes that boundary in the type system: there is deliberately NO
event kind that carries intra-device compute information.  Detectors consume
only these events; tests assert the enum stays closed.

On TPU the vantage points map as (see DESIGN.md §2):
  N-S  -> serving front-end request taps,
  PCIe -> host<->device transfer taps around the JAX runtime boundary,
  E-W  -> ICI collective bursts (sizes statically exact from compiled HLO,
          timing from per-host step beacons).
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator


class EventKind(enum.IntEnum):
    """Closed set of DPU-observable event kinds.

    Order groups the three vantage points of the paper's three runbooks.
    """

    # --- North-South (NIC inline; Table 3a) ---
    INGRESS_PKT = 0       # request bytes arriving from clients
    EGRESS_PKT = 1        # response/token bytes leaving toward clients
    RETRANSMIT = 2        # observed retransmission / duplicate ACK
    QUEUE_SAMPLE = 3      # periodic NIC / scheduler queue-depth sample

    # --- PCIe peer (host<->device path; Table 3b) ---
    H2D_XFER = 4          # host-to-device DMA (bytes, device, flow)
    D2H_XFER = 5          # device-to-host DMA (bytes, device, flow)
    DISPATCH = 6          # doorbell-analog: a launch happened (timing ONLY)
    MEM_REG = 7           # memory map/unmap (registration churn)

    # --- East-West (inter-node wire; Table 3c) ---
    COLLECTIVE_BURST = 8  # collective traffic burst (op kind, bytes, group)
    P2P_BURST = 9         # point-to-point transfer (PP handoff, KV migration)
    CREDIT_UPDATE = 10    # RDMA flow-control credit grant observed


#: Kinds belonging to each vantage point (used by the attribution engine).
NORTH_SOUTH = frozenset(
    {EventKind.INGRESS_PKT, EventKind.EGRESS_PKT, EventKind.RETRANSMIT,
     EventKind.QUEUE_SAMPLE}
)
PCIE = frozenset(
    {EventKind.H2D_XFER, EventKind.D2H_XFER, EventKind.DISPATCH,
     EventKind.MEM_REG}
)
EAST_WEST = frozenset(
    {EventKind.COLLECTIVE_BURST, EventKind.P2P_BURST, EventKind.CREDIT_UPDATE}
)


class CollectiveOp(enum.IntEnum):
    ALL_REDUCE = 0
    ALL_GATHER = 1
    REDUCE_SCATTER = 2
    ALL_TO_ALL = 3
    PERMUTE = 4


@dataclass(frozen=True, slots=True)
class Event:
    """One observation at the DPU vantage point.

    Fields are the superset a BlueField-class observer exports; unused fields
    default to neutral values so the record stays a flat, cheap struct.
    """

    ts: float                 # seconds; sub-microsecond resolution in the sim
    kind: EventKind
    node: int                 # host/node id where observed
    device: int = -1          # local device id (PCIe events), -1 = n/a
    flow: int = -1            # request/flow/session id, -1 = n/a
    size: int = 0             # bytes on the wire / DMA transaction size
    depth: int = 0            # queue depth (QUEUE_SAMPLE) or credit count
    op: int = -1              # CollectiveOp for COLLECTIVE_BURST, -1 otherwise
    group: int = -1           # collective/TP/PP group id
    meta: int = 0             # small free int (e.g. stage id, retry count)
    replica: int = -1         # data-parallel replica the node belongs to

    def vantage(self) -> str:
        if self.kind in NORTH_SOUTH:
            return "north-south"
        if self.kind in PCIE:
            return "pcie"
        return "east-west"


# Forbidden concepts: the schema must never grow fields/kinds that expose
# intra-device compute.  Tests grep these names against the module source.
FORBIDDEN_OBSERVABLES = (
    "flops", "kernel_name", "hbm_bytes", "sm_util", "mxu_util",
    "arithmetic_intensity", "register", "warp", "occupancy",
)


class EventStream:
    """Append-only event buffer with cheap filtered iteration.

    The simulator and the live engine both write Events here; detectors read.
    Kept deliberately simple (list-backed) — line-rate constraints are modeled
    by the *sketches* (O(1) memory), not by this container, which exists so
    tests/benchmarks can replay and slice traces.
    """

    __slots__ = ("_events", "_subscribers")

    def __init__(self) -> None:
        self._events: list[Event] = []
        self._subscribers: list[Callable[[Event], None]] = []

    def emit(self, event: Event) -> None:
        self._events.append(event)
        for sub in self._subscribers:
            sub(event)

    def subscribe(self, fn: Callable[[Event], None]) -> None:
        """Register a line-rate consumer (a detector's update hook)."""
        self._subscribers.append(fn)

    def extend(self, events: Iterable[Event]) -> None:
        for e in events:
            self.emit(e)

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self) -> Iterator[Event]:
        return iter(self._events)

    def select(
        self,
        kind: EventKind | None = None,
        node: int | None = None,
        device: int | None = None,
        flow: int | None = None,
        t0: float = float("-inf"),
        t1: float = float("inf"),
    ) -> list[Event]:
        out = []
        for e in self._events:
            if kind is not None and e.kind != kind:
                continue
            if node is not None and e.node != node:
                continue
            if device is not None and e.device != device:
                continue
            if flow is not None and e.flow != flow:
                continue
            if not (t0 <= e.ts <= t1):
                continue
            out.append(e)
        return out

    def merged(*streams: "EventStream") -> list[Event]:
        """Time-ordered merge of several per-node streams (cluster view)."""
        return sorted(
            itertools.chain.from_iterable(s._events for s in streams),
            key=lambda e: e.ts,
        )
