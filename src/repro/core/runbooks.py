"""Declarative runbook registry — the paper's Tables 3(a)/(b)/(c) as data.

Each ``RunbookEntry`` carries the paper's row verbatim (signal, lifecycle
stages, effect on node<->node traffic, likely root cause, mitigation
directives) plus the executable detector class bound to it and the mitigation
*action* key the controller understands.

The registry is the single source of truth: detectors, the attribution
engine, the mitigation controller, the simulator's fault injectors, tests,
and the per-table benchmarks all iterate over it, so a row cannot silently
lose coverage.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core import detectors as D
from repro.core.detectors import Detector, DetectorConfig


@dataclass(frozen=True)
class RunbookEntry:
    row_id: str                 # stable id == Detector.name
    table: str                  # "3a" | "3b" | "3c" | "3d" | "3e"
    title: str                  # paper's "Skew/Imbalance" column
    signal: str                 # paper's "Signal (Red Flag)" column
    stages: str                 # paper's "Lifecycle Stages Affected"
    effect: str                 # paper's "Effect on Node<->Node Traffic"
    root_cause: str             # paper's "Likely Root Cause"
    mitigation: str             # paper's "Mitigation Directives"
    detector_cls: type[Detector]
    action: str                 # mitigation-controller action key
    scenario: str               # sim fault-injection scenario name
    #: rows that observe the same underlying pathology from another vantage
    #: (e.g. decode early-stop seen PCIe-side vs egress-side).  A recovery
    #: attributed to a sibling row counts as this row's recovery in the
    #: control-loop gates — see ``row_hit``.
    sibling_rows: tuple[str, ...] = ()


RUNBOOK_3A: tuple[RunbookEntry, ...] = (
    RunbookEntry(
        "burst_admission_backlog", "3a", "Burst admission backlog",
        "Sudden spikes of ingress requests followed by queueing delay",
        "Ingress (prefill/start)",
        "Downstream GPU sees uneven load; internode bursts clump",
        "Load spike from clients, front-end batching, NIC queue limits",
        "Smooth input batching, rate-limit clients, increase NIC queue depth",
        D.BurstAdmissionBacklog, action="smooth_admission",
        scenario="burst_admission"),
    RunbookEntry(
        "ingress_starvation", "3a", "Ingress starvation / thin traffic",
        "Long gaps between ingress packets for some tokens",
        "Ingress -> PCIe feed",
        "Token stalls; fewer collective ops downstream",
        "Upstream service jitter, uneven client distribution",
        "Balance load-balancer hashing, check NIC RSS/flow steering",
        D.IngressStarvation, action="rebalance_frontend",
        scenario="ingress_starvation"),
    RunbookEntry(
        "flow_skew_across_sessions", "3a", "Flow skew across sessions",
        "Some ingress flows high-volume, others sparse",
        "Ingress (per-request)",
        "Imbalanced TP/PP participation across tokens",
        "Session affinity mismatch, QUIC stream imbalance",
        "Verify flow hashing, rebalance RPC streams",
        D.FlowSkewAcrossSessions, action="rebalance_frontend",
        scenario="flow_skew"),
    RunbookEntry(
        "ingress_drop_retransmit", "3a", "Ingress drop/retransmit",
        "Missing or retransmitted initial packets (handshake retries)",
        "Ingress (request birth)",
        "Token ID not consistently assigned; lifecycle gaps",
        "Congestion, MTU mismatch, link errors",
        "Enable NIC offloads (TSO/GRO), verify MTU, check cabling",
        D.IngressDropRetransmit, action="tune_transport",
        scenario="ingress_retransmit"),
    RunbookEntry(
        "egress_backlog_queueing", "3a", "Egress backlog / queueing",
        "Responses accumulate in NIC queues before send",
        "Egress (response flush)",
        "Downstream clients see latency spikes",
        "CPU copy bottleneck, NIC buffer exhaustion",
        "Offload checksums, zero-copy send, increase NIC buffer size",
        D.EgressBacklogQueueing, action="enlarge_egress_buffers",
        scenario="egress_backlog"),
    RunbookEntry(
        "egress_jitter", "3a", "Egress jitter",
        "Outgoing packets for a token spread unevenly over time",
        "Egress (decode outputs)",
        "Clients see irregular token cadence",
        "Scheduler variance, CPU<->NIC contention",
        "Isolate runtime threads, pin NIC IRQs, increase batching window",
        D.EgressJitter, action="widen_batch_window",
        scenario="egress_jitter"),
    RunbookEntry(
        "egress_drop_retransmit", "3a", "Egress drop/retransmit",
        "Retransmissions or gaps in final response streams",
        "Egress",
        "Client-visible stalls; retries inflate latency",
        "NIC offload misconfig, fabric congestion, buffer underrun",
        "Check offload settings, enable congestion control (ECN/PFC)",
        D.EgressDropRetransmit, action="tune_transport",
        scenario="egress_retransmit"),
    RunbookEntry(
        "early_completion_skew", "3a", "Early completion skew",
        "Some egress flows terminate far earlier than peers",
        "Egress (multi-stream decode)",
        "Internode peers still busy; imbalance in final stages",
        "Early-stop on short sequences; no remap of freed resources",
        "Enable inflight remapping / load stealing for decode",
        D.EarlyCompletionSkew, action="inflight_remap",
        scenario="early_completion"),
    RunbookEntry(
        "ingress_egress_bandwidth_saturation", "3a",
        "Ingress/Egress bandwidth saturation",
        "NIC RX/TX at or near link capacity; queue buildup",
        "Ingress + Egress",
        "All internode phases elongated; cluster-level slowdown",
        "Shared NIC with storage/other jobs; insufficient link",
        "Upgrade NIC, QoS partitioning, stagger workloads",
        D.BandwidthSaturation, action="admission_control",
        scenario="nic_saturation"),
)

RUNBOOK_3B: tuple[RunbookEntry, ...] = (
    RunbookEntry(
        "h2d_data_starvation", "3b", "H2D data starvation",
        "Large/clustered H2D DMAs followed by long gaps before "
        "doorbells/kernels",
        "Ingress -> PCIe (prefill & decode input feed)",
        "Fewer/late internode bursts; downstream TP/PP idles",
        "PCIe BW cap, NUMA miss, pageable (unpinned) host buffers",
        "Pin memory, bind to correct NUMA socket, verify PCIe link "
        "width/speed",
        D.H2DDataStarvation, action="pin_and_coalesce",
        scenario="h2d_starvation"),
    RunbookEntry(
        "d2h_return_bottleneck", "3b", "D2H return-path bottleneck",
        "D2H DMAs linger / complete slowly; backlog after kernels",
        "Egress (logits/tokens back to host)",
        "Late responses; backpressure into next token step",
        "PCIe saturation, IOMMU contention, CPU copy hotspots",
        "Enable large pinned buffers, reduce copies, check IOMMU/ATS config",
        D.D2HReturnBottleneck, action="pin_and_coalesce",
        scenario="d2h_bottleneck"),
    RunbookEntry(
        "kernel_launch_control_latency", "3b", "Kernel launch/control latency",
        "Doorbells sporadic; long idle gaps between small H2D bursts and "
        "next launch",
        "Compute (GPU underutilized across prefill/decode)",
        "TP collectives delayed, PP handoffs drift",
        "Runtime overhead, CPU scheduler delays, too many tiny kernels",
        "Batch ops, fuse kernels, raise runtime launch queues, isolate CPU "
        "cores",
        D.KernelLaunchLatency, action="batch_launches",
        scenario="launch_latency"),
    RunbookEntry(
        "intra_node_gpu_skew", "3b", "Intra-node GPU skew",
        "One GPU shows thin/irregular DMA; peers steady",
        "Compute (per-layer) -> propagates to internode",
        "TP collectives widen (straggler), PP stage misalignment",
        "Uneven microbatching, memory pressure on a single GPU",
        "Rebalance microbatches, unify stream priorities, check that GPU's "
        "memory and clocks",
        D.IntraNodeGpuSkew, action="rebalance_microbatches",
        scenario="intra_node_skew"),
    RunbookEntry(
        "pcie_link_saturation", "3b", "PCIe link saturation",
        "Sustained near-peak PCIe throughput; compute stalls periodically",
        "Ingress -> PCIe, Egress",
        "Burstiness in internode waves; elongates token step",
        "Oversubscribed PCIe switch / x8 link, competing DMAs (storage/NIC)",
        "Verify x16 Gen/lanes, move devices off shared switch, stagger I/O",
        D.PCIeLinkSaturation, action="stagger_io",
        scenario="pcie_saturation"),
    RunbookEntry(
        "gpu_p2p_throttling", "3b", "GPU P2P throttling (PCIe)",
        "P2P DMAs slow/variable; no NVLink path",
        "Compute (intra-box TP/PP)",
        "Internode timing jitter (collectives wait on slow intra-box move)",
        "Shared uplink on PCIe switch; ACS/ATS settings",
        "Prefer NVLink/NVSwitch; if PCIe, place GPUs under same switch, "
        "tune ACS/ATS",
        D.GpuP2PThrottling, action="replace_topology",
        scenario="p2p_throttling"),
    RunbookEntry(
        "pinned_memory_shortage", "3b",
        "Pinned-memory shortage / fragmentation",
        "Many small DMAs vs large coalesced; rising DMA count",
        "Ingress -> PCIe (feed) and Egress (returns)",
        "Micro-jitter; uneven stage timing",
        "Insufficient pinned pools; fallback to pageable",
        "Pre-allocate larger pinned pools; coalesce transfers",
        D.PinnedMemoryShortage, action="pin_and_coalesce",
        scenario="pinned_shortage"),
    RunbookEntry(
        "host_cpu_bottleneck", "3b", "Host CPU bottleneck",
        "Low DMA rate despite available PCIe BW; delayed doorbells",
        "Compute orchestration",
        "Irregular TP cadence; PP bubbles",
        "CPU contention, IRQ affinity, polling disabled",
        "Isolate IRQs/threads, enable busy-poll where appropriate, pin "
        "runtime threads",
        D.HostCpuBottleneck, action="isolate_host_threads",
        scenario="host_cpu_bottleneck"),
    RunbookEntry(
        "memory_registration_churn", "3b", "Memory registration churn",
        "Frequent map/unmap patterns around DMAs",
        "Ingress -> PCIe",
        "Small timing gaps accumulating per token",
        "Repeated registration due to short-lived buffers",
        "Reuse registered buffers; RDMA/GPUDirect with persistent MR",
        D.MemoryRegistrationChurn, action="pin_and_coalesce",
        scenario="registration_churn"),
    RunbookEntry(
        "decode_early_stop_skew", "3b", "Decode early-stop skew",
        "D2H drops off early on some streams/GPUs",
        "Compute (decode) -> Egress",
        "Some peers go silent; collectives wait for remaining peers",
        "Sequence length variance; scheduler not rebalancing",
        "Enable inflight request remapping/packing; speculative decode "
        "policies",
        D.DecodeEarlyStopSkew, action="inflight_remap",
        scenario="decode_early_stop",
        # the same early-stop pathology seen at the N-S vantage; whichever
        # row confirms first drives the identical inflight_remap actuation,
        # so recovery credited to the sibling is this row's recovery too
        sibling_rows=("early_completion_skew",)),
)

RUNBOOK_3C: tuple[RunbookEntry, ...] = (
    RunbookEntry(
        "tp_straggler", "3c", "TP straggler",
        "Wide arrival spread of collective bursts (max-min arrival gap up)",
        "Compute (tensor-parallel collectives)",
        "Collective ops stall waiting for slowest peer",
        "Skewed GPU load, PCIe starvation, memory imbalance on one node",
        "Rebalance shards, check PCIe feeds per node, adjust affinity",
        D.TPStraggler, action="rebalance_shards",
        scenario="tp_straggler"),
    RunbookEntry(
        "pp_bubble_stage_stall", "3c", "PP bubble / stage stall",
        "Large or growing gaps between stage handoff bursts",
        "Pipeline parallel",
        "Downstream stage idles; upstream builds backlog",
        "Load imbalance across pipeline stages, early token exit variance",
        "Adjust microbatch partitioning, reassign stages, speculative fill",
        D.PPBubble, action="repartition_stages",
        scenario="pp_bubble"),
    RunbookEntry(
        "cross_node_load_skew", "3c", "Cross-node load skew",
        "Uneven traffic volume per node for same collective",
        "TP/PP compute -> Internode",
        "Some nodes oversend/undersend; throughput uneven",
        "Shard imbalance, misaligned activation partitioning",
        "Validate shard sizes, rebalance across nodes",
        D.CrossNodeLoadSkew, action="rebalance_shards",
        scenario="cross_node_skew"),
    RunbookEntry(
        "network_congestion_oversubscription", "3c",
        "Network congestion / oversubscription",
        "Periodic spikes in latency + jitter across many links",
        "Internode transfers (collectives & stage handoff)",
        "Token step elongates cluster-wide",
        "Fat-tree oversubscription, ToR link hot spot",
        "Check fabric counters, enable adaptive routing, spread ranks",
        D.NetworkCongestion, action="reroute_traffic",
        scenario="network_congestion"),
    RunbookEntry(
        "head_of_line_blocking", "3c", "Head-of-line blocking",
        "Some streams stall while others flow; out-of-order bursts",
        "Collective streams / P2P flows",
        "Latency-sensitive ops delayed",
        "Shared queue depth exhaustion, RoCE/NIC queue imbalance",
        "Increase NIC queue depth, enable QoS/ECN, verify fair sharing",
        D.HeadOfLineBlocking, action="qos_partition",
        scenario="hol_blocking"),
    RunbookEntry(
        "retransmissions_packet_loss", "3c", "Retransmissions / packet loss",
        "Gaps + duplicate traffic or sudden retransmit storms",
        "All distributed phases",
        "Bursty latency; collectives jitter",
        "Fabric errors, congestion collapse, misconfigured PFC",
        "Verify lossless config, tune buffer thresholds, check "
        "optics/cabling",
        D.EWRetransmitStorm, action="tune_transport",
        scenario="ew_retransmit"),
    RunbookEntry(
        "credit_starvation", "3c", "Credit starvation (RDMA/flow control)",
        "Long silence periods until remote credit update",
        "Internode (RDMA ops)",
        "Under-utilized links; token latency grows",
        "Too-small RDMA window, NIC credit depletion",
        "Increase QP window, tune flow control params",
        D.CreditStarvation, action="widen_rdma_window",
        scenario="credit_starvation"),
    RunbookEntry(
        "kv_cache_transfer_bottleneck", "3c", "KV-cache transfer bottleneck",
        "Repeated large bursts for some tokens, others silent",
        "Decode phase (PP handoff)",
        "Uneven memory pressure per stage; downstream skew",
        "Sharded KV too large for link budget; non-uniform length",
        "Compress KV, shard differently, apply caching policies",
        D.KVCacheTransferBottleneck, action="compress_kv",
        scenario="kv_bottleneck"),
    RunbookEntry(
        "early_stop_skew_across_nodes", "3c", "Early-stop skew across nodes",
        "Some nodes stop sending mid-iteration while others continue",
        "Decode (multi-node)",
        "Collectives/pipeline hang waiting for peers",
        "Sequence length divergence; scheduler not masking early exits",
        "Enable dynamic remapping, mask early-stop ranks",
        D.EarlyStopSkewAcrossNodes, action="inflight_remap",
        scenario="node_early_stop"),
)

RUNBOOK_3D: tuple[RunbookEntry, ...] = (
    RunbookEntry(
        "cross_replica_skew", "3d", "Cross-replica load skew (DP routing)",
        "Per-replica egress token rates diverge; one replica's ingress "
        "queue grows while peers drain",
        "Ingress routing -> decode (data-parallel replicas)",
        "Hot replica saturates; cold replicas idle; cluster p99 TTFT "
        "inflates while aggregate utilization looks normal",
        "Router policy imbalance (static round-robin under skewed flows), "
        "stale router view, session affinity pinning, degraded replica",
        "Rebalance queued requests across replicas; switch to queue/KV-aware "
        "routing; refresh or bound router view staleness",
        D.CrossReplicaSkew, action="rebalance_replicas",
        scenario="hot_replica"),
    RunbookEntry(
        "hierarchical_routing_skew", "3d",
        "Hierarchical routing skew (intra-replica node placement)",
        "One node inside a replica receives most of the replica's ingress "
        "request volume and its queue outgrows its siblings, while "
        "replica-level totals stay balanced",
        "Ingress routing -> intra-replica node placement (decode)",
        "The replica's other nodes idle while one saturates; TP-group "
        "throughput halves with no replica-tier signal",
        "Replica-local placement affinity (sticky session hashing, broken "
        "TP-group spread), node-granularity-blind router view",
        "Rebalance queued requests across the replica's nodes; restore the "
        "intra-replica spread; route at node granularity",
        D.HierarchicalRoutingSkew, action="rebalance_nodes",
        scenario="hierarchical_routing_skew"),
)

RUNBOOK_3E: tuple[RunbookEntry, ...] = (
    RunbookEntry(
        "collective_straggler", "3e",
        "Per-collective straggler (op-level finish lag)",
        "One node's per-op finish edge (all-gather / reduce-scatter) "
        "trails the group median round after round",
        "Compute (per-collective ops within the token step)",
        "Every op in the lagging rank's groups stretches to its finish; "
        "the aggregate round cadence hides which op pays",
        "Device slowdown or local contention on one rank, visible only at "
        "per-op granularity (the merged round burst averages it away)",
        "Rebalance shards toward the lagging rank; verify its local feeds "
        "and clocks",
        D.CollectiveStragglerLag, action="rebalance_shards",
        scenario="collective_straggler"),
    RunbookEntry(
        "rail_congestion", "3e",
        "Rail congestion (cross-domain tier)",
        "Cross-domain collective legs sharing one rail finish consistently "
        "later than legs on sibling rails",
        "Internode transfers (cross-domain rail tier)",
        "Ops spanning NVLink-class domains serialize on the hot rail; "
        "intra-domain traffic stays fast, so node-keyed rows stay quiet",
        "Oversubscribed or degraded rail shared by all cross-domain legs "
        "(DWDP-style rail-aligned topology)",
        "Reroute cross-domain legs off the hot rail; respread ranks over "
        "rails",
        D.RailCongestion, action="reroute_rail",
        scenario="rail_congestion"),
    RunbookEntry(
        "hbm_bandwidth_cliff", "3e",
        "Memory-bandwidth cliff (decode batch knee)",
        "Per-node egress token rate sags well below its own peak while "
        "ingress queues stay flat and batch occupancy sits at max",
        "Decode (device memory bandwidth)",
        "Throughput sags cluster-wide with no queue growth anywhere — "
        "every queue- and gap-keyed row stays silent",
        "Decode batch size past the device's memory-bandwidth knee; token "
        "rate saturates at the bandwidth ceiling",
        "Shrink the decode batch below the knee; re-spread slots across "
        "nodes",
        D.HbmBandwidthCliff, action="shrink_batch",
        scenario="hbm_bandwidth_cliff"),
)

RUNBOOK_DPU: tuple[RunbookEntry, ...] = (
    RunbookEntry(
        "dpu_saturation", "dpu", "DPU telemetry-plane saturation",
        "On-DPU ingest ring fills; event batches shed; ring occupancy "
        "pinned high while shed counters climb",
        "Telemetry plane (all vantages degraded)",
        "Findings arrive late or never, cluster-wide; the mitigation loop "
        "reacts to a stale picture",
        "Event volume exceeds the DPU's ingest/compute budget (verbose "
        "debug tap, line-rate burst, undersized budget)",
        "Raise tap sampling stride; shed low-priority event classes; "
        "bound per-class event rates at the source",
        D.DPUSaturation, action="throttle_telemetry",
        scenario="dpu_saturation"),
)

RUNBOOK_MON: tuple[RunbookEntry, ...] = (
    RunbookEntry(
        "dpu_outage", "mon", "DPU outage (monitoring plane dark)",
        "Watchdog heartbeat probes to the DPU go silent past the timeout "
        "(no self-telemetry cadence, no command-bus acks) over the "
        "out-of-band management port",
        "Monitoring plane (all detection and actuation dark)",
        "Every runbook row is blind for the outage; faults progress "
        "unmitigated until the plane returns or a fallback takes over",
        "DPU crash, hang, or power-cycle; firmware fault; management-path "
        "loss of the telemetry sidecar",
        "Fail over to the degraded host-side controller (high-confidence "
        "rows only); fail back with hysteresis when heartbeats resume; "
        "quarantine the restarted DPU until its detectors re-warm",
        D.DPUOutage, action="failover_controller",
        scenario="dpu_outage"),
    RunbookEntry(
        "telemetry_blackout", "mon", "Telemetry blackout (ingest gap)",
        "The DPU's ingest guard sees a jump in the tap's batch sequence "
        "numbers (or checksum-corrupt/replayed frames) after an uplink "
        "partition window",
        "Telemetry ingest (detection blind for the gap window)",
        "Detector state spans a hole in the stream; rate/gap baselines "
        "are stale and any actuation off them risks a false command",
        "Uplink partition or blackout between the host tap and the DPU; "
        "frame corruption or replay on the telemetry path",
        "Re-register the tap and resync the sequence stream; quarantine "
        "actuation until detectors re-warm over fresh events",
        D.TelemetryBlackout, action="resync_telemetry",
        scenario="telemetry_blackout"),
    RunbookEntry(
        "command_partition", "mon", "Command-channel partition",
        "Commands and liveness pings burn every retry unacked while "
        "telemetry ingest stays healthy — the loop can see but not act",
        "Actuation path (detection intact, mitigation dark)",
        "Confirmed pathologies accumulate without mitigation; retry "
        "exhaustion climbs with zero intervening acks",
        "Downlink/ack-channel partition between the DPU and the host "
        "actuator (control fabric shares the data fabric's failure domain)",
        "Fail actuation over to the host-side controller until the "
        "command channel round-trips again",
        D.CommandPartition, action="failover_controller",
        scenario="command_partition"),
    RunbookEntry(
        "standby_lag", "mon", "Standby shadow lag (redundancy degraded)",
        "The standby sidecar's tap clock falls a sustained quarter-second "
        "or more behind the primary's while the primary stays healthy — "
        "the mirrored tap leg is dropping or partitioned",
        "Monitoring plane (hot-failover guarantee silently void)",
        "Detection continues on the primary, but a failover right now "
        "would promote detectors warm on stale state; the deployment is "
        "one primary fault away from a cold promotion",
        "Standby uplink partition/blackout on the fan-out leg, or a "
        "wedged standby sidecar with a live primary",
        "Re-mirror the standby from the watchdog's retained tap history "
        "and resync its sequence stream; alert if lag recurs",
        D.StandbyLag, action="remirror_standby",
        scenario="standby_lag"),
    RunbookEntry(
        "split_brain_fenced", "mon", "Split-brain fenced (stale-term "
        "command rejected)",
        "The host actuator rejects commands stamped with a lease term "
        "older than the granted one — a deposed sidecar is alive and "
        "still trying to actuate",
        "Actuation path (double-actuation attempt blocked at the fence)",
        "Two controllers believe they lead; only the term fence prevents "
        "conflicting mitigations racing each other on the same nodes",
        "OOB management-port partition hid the demotion from the old "
        "leader while its command downlink stayed alive",
        "Deliver the current term to the stale sidecar (quiesce it) and "
        "purge its outstanding commands; audit the fencing log",
        D.SplitBrainFenced, action="fence_stale_controller",
        scenario="split_brain_fenced"),
)

#: every table the full DPU agent runs (the paper's three runbooks, the
#: 3d data-parallel extension, the 3e per-collective/topology tier, the
#: plane's self-diagnosis row, and the monitoring-plane robustness rows)
DEFAULT_TABLES: tuple[str, ...] = ("3a", "3b", "3c", "3d", "3e", "dpu",
                                   "mon")

ALL_RUNBOOKS: tuple[RunbookEntry, ...] = (
    RUNBOOK_3A + RUNBOOK_3B + RUNBOOK_3C + RUNBOOK_3D + RUNBOOK_3E
    + RUNBOOK_DPU + RUNBOOK_MON)

BY_ID: dict[str, RunbookEntry] = {e.row_id: e for e in ALL_RUNBOOKS}
BY_TABLE: dict[str, tuple[RunbookEntry, ...]] = {
    "3a": RUNBOOK_3A, "3b": RUNBOOK_3B, "3c": RUNBOOK_3C, "3d": RUNBOOK_3D,
    "3e": RUNBOOK_3E, "dpu": RUNBOOK_DPU, "mon": RUNBOOK_MON,
}


def row_hit(row_id: str, fired: set[str]) -> bool:
    """Did this row's pathology get caught — by the row itself or by one of
    its declared ``sibling_rows``?  The control-loop gates use this: when
    two rows watch one pathology from different vantages, whichever
    confirms first drives the (shared) actuation, and demanding the
    canonical row's own name would fail a loop that in fact recovered."""
    if row_id in fired:
        return True
    entry = BY_ID.get(row_id)
    return entry is not None and bool(set(entry.sibling_rows) & fired)


def build_detectors(cfg: DetectorConfig | None = None,
                    tables: tuple[str, ...] = DEFAULT_TABLES,
                    ) -> dict[str, Detector]:
    """Instantiate one detector per runbook row (the full DPU agent)."""
    cfg = cfg or DetectorConfig()
    return {
        e.row_id: e.detector_cls(cfg)
        for t in tables
        for e in BY_TABLE[t]
    }
