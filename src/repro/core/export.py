"""Render the paper's Tables 3(a)/(b)/(c) back out of the executable
registry — documentation stays generated from the single source of truth.

  PYTHONPATH=src python -m repro.core.export > RUNBOOKS.md
"""

from __future__ import annotations

from repro.core.mitigation import ACTIONS
from repro.core.runbooks import DEFAULT_TABLES, BY_TABLE

TITLES = {
    "3a": "Table 3(a) — North-South Runbook",
    "3b": "Table 3(b) — PCIe Observer Runbook",
    "3c": "Table 3(c) — East-West Sensing Runbook",
    "3d": "Table 3(d) — Data-Parallel Replica Runbook (extension)",
    "3e": "Table 3(e) — Collective/Rail/Memory Runbook (extension)",
    "dpu": "Table (dpu) — DPU Self-Diagnosis Runbook (extension)",
    "mon": "Table (mon) — Monitoring-Plane Outage Runbook (extension)",
}


def render_incident(report: dict) -> str:
    """Render one incident report (see ``repro.obs.trace``) as a markdown
    timeline table — the human-facing face of the flight recorder.

    The table is phase-ordered causally within equal timestamps (detect
    before decide before bus before apply), and a TTM decomposition
    footer shows where the time-to-mitigate went.
    """
    ttm = report.get("ttm", {})
    ms = report.get("milestones", {})
    out = [f"## Incident {report['incident_id']} — row "
           f"`{report['row']}`" + (" (recovered)" if report.get("closed")
                                   else " (open)"), ""]
    fs = ms.get("fault_start")
    if fs is not None:
        out.append(f"Fault injected at t={fs:.3f}s; first finding at "
                   f"t={report['opened_ts']:.3f}s.")
        out.append("")
    out.append("| t (s) | phase | event | source | detail |")
    out.append("|---|---|---|---|---|")
    for ev in report.get("timeline", []):
        detail = ", ".join(f"{k}={v}" for k, v in ev["detail"].items())
        out.append(f"| {ev['ts']:.4f} | {ev['phase']} | {ev['name']} "
                   f"| {ev['source']} | {detail} |")
    phases = [(k, ttm.get(k)) for k in
              ("t_detect", "t_attribute", "t_decide", "t_bus_rtt",
               "t_apply", "t_recover")]
    if any(v is not None for _, v in phases):
        out.append("")
        out.append("TTM decomposition: " + "  ".join(
            f"{k}={v * 1000.0:.1f}ms" for k, v in phases
            if v is not None))
    return "\n".join(out) + "\n"


def render() -> str:
    out = ["# Runbooks (generated from repro.core.runbooks)\n"]
    for table in DEFAULT_TABLES:
        out.append(f"\n## {TITLES[table]}\n")
        out.append("| Skew/Imbalance | Signal (Red Flag) | Lifecycle "
                   "Stages | Likely Root Cause | Mitigation Directives | "
                   "Detector | Controller Action |")
        out.append("|---|---|---|---|---|---|---|")
        for e in BY_TABLE[table]:
            out.append(
                f"| {e.title} | {e.signal} | {e.stages} | {e.root_cause} "
                f"| {e.mitigation} | `{e.detector_cls.__name__}` "
                f"| `{e.action}`: {ACTIONS[e.action]} |")
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    print(render(), end="")
