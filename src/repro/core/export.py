"""Render the paper's Tables 3(a)/(b)/(c) back out of the executable
registry — documentation stays generated from the single source of truth.

  PYTHONPATH=src python -m repro.core.export > RUNBOOKS.md
"""

from __future__ import annotations

from repro.core.mitigation import ACTIONS
from repro.core.runbooks import DEFAULT_TABLES, BY_TABLE

TITLES = {
    "3a": "Table 3(a) — North-South Runbook",
    "3b": "Table 3(b) — PCIe Observer Runbook",
    "3c": "Table 3(c) — East-West Sensing Runbook",
    "3d": "Table 3(d) — Data-Parallel Replica Runbook (extension)",
    "3e": "Table 3(e) — Collective/Rail/Memory Runbook (extension)",
    "dpu": "Table (dpu) — DPU Self-Diagnosis Runbook (extension)",
    "mon": "Table (mon) — Monitoring-Plane Outage Runbook (extension)",
}


def render() -> str:
    out = ["# Runbooks (generated from repro.core.runbooks)\n"]
    for table in DEFAULT_TABLES:
        out.append(f"\n## {TITLES[table]}\n")
        out.append("| Skew/Imbalance | Signal (Red Flag) | Lifecycle "
                   "Stages | Likely Root Cause | Mitigation Directives | "
                   "Detector | Controller Action |")
        out.append("|---|---|---|---|---|---|---|")
        for e in BY_TABLE[table]:
            out.append(
                f"| {e.title} | {e.signal} | {e.stages} | {e.root_cause} "
                f"| {e.mitigation} | `{e.detector_cls.__name__}` "
                f"| `{e.action}`: {ACTIONS[e.action]} |")
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    print(render(), end="")
