"""--arch config module (exact published spec; see registry.py)."""
from repro.configs.registry import SEAMLESS as CONFIG

__all__ = ["CONFIG"]
