"""--arch config module (exact published spec; see registry.py)."""
from repro.configs.registry import MISTRAL_LARGE as CONFIG

__all__ = ["CONFIG"]
