"""Architecture registry: the 10 assigned configs (exact published specs)
plus the paper-family baseline.  Select with ``--arch <id>``.

Sources are noted per config ([hf:...] / [arXiv:...] as assigned).
"""

from __future__ import annotations

from repro.models.config import ModelConfig
from repro.models.model import ShapeSpec

# ----------------------------------------------------------------------
# shape cells (identical across LM archs, per the assignment)
# ----------------------------------------------------------------------

SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# reduced shapes for CPU smoke tests
SMOKE_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 64, 2),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 64, 2),
    "decode_32k": ShapeSpec("decode_32k", "decode", 64, 2),
    "long_500k": ShapeSpec("long_500k", "decode", 128, 1),
}


ARCHS: dict[str, ModelConfig] = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- dense -------------------------------------------------------------

# [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
MISTRAL_LARGE = _reg(ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab=32768, head_dim=128, rope_theta=1e6,
))

# llama+mistral mix, SWA [arXiv:2401.16818; unverified]
H2O_DANUBE = _reg(ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, swa_window=4096, rope_theta=1e4,
    subquadratic=True,   # SWA bounds the KV cache -> long_500k runnable
))

# qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]
QWEN3 = _reg(ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6, tie_embeddings=True,
))

# small llama3 [hf:meta-llama/Llama-3.2-1B; unverified]
LLAMA32 = _reg(ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=128256, rope_theta=5e5,
))

# --- MoE ---------------------------------------------------------------

# 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
QWEN2_MOE = _reg(ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936,
    n_experts=60, top_k=4, n_shared_experts=4, expert_d_ff=1408,
))

# 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
GRANITE_MOE = _reg(ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    n_experts=40, top_k=8, n_shared_experts=0, expert_d_ff=512,
))

# --- audio encoder-decoder ----------------------------------------------

# enc-dec, multimodal [arXiv:2308.11596; hf]
SEAMLESS = _reg(ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, frontend="audio",
))

# --- VLM -----------------------------------------------------------------

# anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
LLAVA = _reg(ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, rope_theta=1e6,
    frontend="vision", frontend_tokens=576,   # one 24x24 patch grid tile
))

# --- hybrid (Mamba2 + shared attention) ----------------------------------

# Mamba2 + shared attn blocks [arXiv:2411.15242; unverified]
ZAMBA2 = _reg(ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, ssm_state=64, ssm_head_dim=64,
    attn_every=6, subquadratic=True,
))

# --- xLSTM ---------------------------------------------------------------

# sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]
XLSTM = _reg(ModelConfig(
    name="xlstm-125m", family="ssm", xlstm=True,
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, subquadratic=True,
))

# --- paper-family baseline (Table 1: LLaMA-2 7B class) -------------------

PAPER_BASELINE = _reg(ModelConfig(
    name="paper-llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=32000, rope_theta=1e4,
))


ASSIGNED: tuple[str, ...] = (
    "mistral-large-123b", "h2o-danube-3-4b", "qwen3-0.6b", "llama3.2-3b",
    "qwen2-moe-a2.7b", "granite-moe-3b-a800m", "seamless-m4t-large-v2",
    "llava-next-mistral-7b", "zamba2-7b", "xlstm-125m",
)


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skipped: bool = True) -> list[tuple[str, str, str]]:
    """All (arch, shape, status) dry-run cells.

    status: 'run' or 'skip:<reason>'. long_500k runs only for bounded-state
    archs (SSM/hybrid/SWA); full-attention archs are skipped per assignment.
    """
    out = []
    for arch in ASSIGNED:
        cfg = ARCHS[arch]
        for shape in SHAPES:
            status = "run"
            if shape == "long_500k" and not cfg.subquadratic:
                status = ("skip:full-attention arch; 512k dense KV per "
                          "sequence is itself the paper's kv-transfer "
                          "pathology")
            if shape in ("decode_32k", "long_500k") \
                    and not cfg.supports_decode:
                status = "skip:no decode step"
            if status == "run" or include_skipped:
                out.append((arch, shape, status))
    return out
