"""--arch config module (exact published spec; see registry.py)."""
from repro.configs.registry import ZAMBA2 as CONFIG

__all__ = ["CONFIG"]
