"""--arch config module (exact published spec; see registry.py)."""
from repro.configs.registry import H2O_DANUBE as CONFIG

__all__ = ["CONFIG"]
