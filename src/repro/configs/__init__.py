"""Config registry: assigned architectures + shapes. See registry.py."""
from repro.configs.registry import ARCHS, ASSIGNED, SHAPES, SMOKE_SHAPES, cells, get
__all__ = ["ARCHS", "ASSIGNED", "SHAPES", "SMOKE_SHAPES", "cells", "get"]
