"""--arch config module (exact published spec; see registry.py)."""
from repro.configs.registry import GRANITE_MOE as CONFIG

__all__ = ["CONFIG"]
