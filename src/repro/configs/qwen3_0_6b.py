"""--arch config module (exact published spec; see registry.py)."""
from repro.configs.registry import QWEN3 as CONFIG

__all__ = ["CONFIG"]
