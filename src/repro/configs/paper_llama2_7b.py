"""--arch config module (exact published spec; see registry.py)."""
from repro.configs.registry import PAPER_BASELINE as CONFIG

__all__ = ["CONFIG"]
