"""--arch config module (exact published spec; see registry.py)."""
from repro.configs.registry import LLAMA32 as CONFIG

__all__ = ["CONFIG"]
