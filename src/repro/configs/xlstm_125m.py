"""--arch config module (exact published spec; see registry.py)."""
from repro.configs.registry import XLSTM as CONFIG

__all__ = ["CONFIG"]
