"""Trainer: microbatched train step (remat + optional compressed gradient
accumulation), checkpoint/restart fault tolerance, step-time telemetry with
straggler accounting, and the mitigation actuation surface.

The same Trainer drives the CPU smoke tests (reduced config, 1 device) and
the production mesh (sharded via MeshRules) — scale is a config, not a code
path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import Event, EventKind
from repro.core.sketch import EWMA
from repro.core.telemetry import TelemetryPlane
from repro.models import Model
from repro.parallel.collectives import accumulate_grads, init_error_buf
from repro.training import checkpoint as ckpt
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclass
class TrainConfig:
    steps: int = 50
    n_micro: int = 1
    compress_grads: bool = False
    ckpt_dir: str = ""
    ckpt_every: int = 25
    ckpt_keep: int = 3
    log_every: int = 10
    node: int = 0
    optimizer: AdamWConfig = field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, model: Model, params, tcfg: TrainConfig,
                 shard=None, plane: TelemetryPlane | None = None) -> None:
        self.model = model
        self.tcfg = tcfg
        self.plane = plane
        self.shard = shard
        self.params = params
        self.opt_state = adamw_init(params)
        if tcfg.compress_grads:
            self.opt_state["error_buf"] = init_error_buf(params)
        self.step = 0
        self.step_time = EWMA(0.1)
        self.history: list[dict] = []
        self._jit_step = jax.jit(self._train_step, donate_argnums=(0, 1))
        if self.plane is not None and self.plane.controller is not None:
            self.plane.controller.engine = self

    # ------------------------------------------------------------------

    def _loss(self, params, batch):
        if self.shard is not None:
            return self.model.loss(params, batch, shard=self.shard)
        return self.model.loss(params, batch)

    def _train_step(self, params, opt_state, micro_batches):
        ebuf = opt_state.get("error_buf")
        loss, grads, new_ebuf = accumulate_grads(
            self._loss, params, micro_batches,
            compress=self.tcfg.compress_grads, error_buf=ebuf)
        params, opt_state2, metrics = adamw_update(
            self.tcfg.optimizer, grads,
            {k: opt_state[k] for k in ("m", "v", "step")}, params)
        if ebuf is not None:
            opt_state2["error_buf"] = new_ebuf
        return params, opt_state2, loss, metrics

    # ------------------------------------------------------------------
    # EngineControls (mitigation surface for training-side findings)
    # ------------------------------------------------------------------

    def apply_action(self, action: str, node: int, detail: dict) -> bool:
        if action in ("rebalance_microbatches", "rebalance_shards",
                      "repartition_stages", "batch_launches",
                      "isolate_host_threads", "pin_and_coalesce"):
            return True   # accounting hook; resharding is a restart-level op
        return False

    # ------------------------------------------------------------------

    def _emit(self, kind: EventKind, ts: float, **kw) -> None:
        if self.plane is not None:
            self.plane.observe(Event(ts=ts, kind=kind, node=self.tcfg.node,
                                     **kw))

    def maybe_restore(self) -> bool:
        """Checkpoint/restart: resume from the latest checkpoint if any."""
        if not self.tcfg.ckpt_dir:
            return False
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        state = ckpt.restore(self.tcfg.ckpt_dir, last, state)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = last
        return True

    def save(self) -> None:
        if self.tcfg.ckpt_dir:
            ckpt.save(self.tcfg.ckpt_dir, self.step,
                      {"params": self.params, "opt": self.opt_state},
                      keep=self.tcfg.ckpt_keep)

    def run(self, batches, crash_at: int | None = None) -> list[dict]:
        """Train over an iterable of batches; ``crash_at`` injects a
        simulated failure after N steps (fault-tolerance tests)."""
        t0 = time.perf_counter()
        for batch in batches:
            if self.step >= self.tcfg.steps:
                break
            mb = self._microbatch(batch)
            ts = time.perf_counter() - t0
            self._emit(EventKind.H2D_XFER, ts, device=0,
                       size=int(sum(np.asarray(x).nbytes
                                    for x in jax.tree.leaves(batch))))
            self._emit(EventKind.DISPATCH, ts, device=0)
            st = time.perf_counter()
            self.params, self.opt_state, loss, metrics = self._jit_step(
                self.params, self.opt_state, mb)
            loss = float(loss)
            dt = time.perf_counter() - st
            self.step_time.update(dt)
            ts = time.perf_counter() - t0
            self._emit(EventKind.D2H_XFER, ts, device=0, size=8)
            rec = {"step": self.step, "loss": loss, "sec": dt,
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"]),
                   "straggler_z": self.step_time.zscore(dt)}
            self.history.append(rec)
            self.step += 1
            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
            if crash_at is not None and self.step >= crash_at:
                raise RuntimeError("injected failure")
        self.save()
        return self.history

    def _microbatch(self, batch):
        n = self.tcfg.n_micro

        def split(x):
            b = x.shape[0]
            return x.reshape(n, b // n, *x.shape[1:])
        return jax.tree.map(split, batch)
