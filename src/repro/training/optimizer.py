"""AdamW in pure JAX pytrees, with fp32 master moments over (possibly) bf16
params, global-norm clipping, and cosine/linear schedules.

Optimizer state mirrors the parameter tree, so the same FSDP/ZeRO sharding
specs apply — with ``fsdp=True`` moments are sharded over (data x model),
which is ZeRO-ish state partitioning for free.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: Any, state: dict, params: Any
                 ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                        + cfg.weight_decay * pf * (p.ndim >= 2))
        return pf.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
