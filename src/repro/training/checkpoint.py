"""Atomic sharded checkpoints with resume (fault-tolerance substrate).

Layout: <dir>/step_<n>/  one .npy per pytree leaf + index.json manifest;
written to a tmp dir then os.rename'd (atomic on POSIX) so a mid-write
crash never corrupts the latest checkpoint.  ``latest_step`` +
``restore`` give checkpoint/restart; old steps are garbage-collected
keeping the newest K.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree) -> list[tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    """Atomically write one checkpoint; returns its path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": []}
    for i, (key, arr) in enumerate(_flatten(tree)):
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"key": key, "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (optionally resharded)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "index.json")) as f:
        manifest = json.load(f)
    arrays = [np.load(os.path.join(path, leaf["file"]))
              for leaf in manifest["leaves"]]
    treedef = jax.tree_util.tree_structure(like_tree)
    tree = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree
