"""Elastic scaling / node-failure handling.

Policy for a 1000+-node deployment (documented + mechanically tested at
small scale):

  1. A node failure surfaces as a collective timeout (or, earlier, as the
     telemetry plane's 'early_stop_skew_across_nodes' / 'tp_straggler'
     findings — the paper's detectors give ADVANCE warning of degrading
     nodes before hard failure).
  2. The coordinator drops the failed hosts, rebuilds the mesh with a
     smaller DP extent (TP degree is preserved — it's the intra-pod axis),
     and reshards the latest checkpoint onto the new mesh.
  3. Global batch is preserved by raising grad-accumulation microbatches
     (token-identical training) or shrunk deliberately (throughput mode).

``remesh`` implements step 2's mechanics: checkpoint -> new mesh ->
device_put with the new shardings.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.parallel.sharding import MeshRules


@dataclass
class RemeshPlan:
    old_shape: dict
    new_shape: dict
    dp_scale: float           # new/old data-parallel extent
    micro_scale: int          # grad-accum multiplier to keep global batch


def plan_remesh(old_mesh, failed_nodes: int, hosts_per_data: int = 1
                ) -> RemeshPlan:
    """Drop failed hosts from the 'data' axis; keep 'model' intact."""
    old = dict(old_mesh.shape)
    new = dict(old)
    lost = failed_nodes * hosts_per_data
    if old["data"] - lost < 1:
        raise ValueError("not enough healthy hosts to continue")
    new["data"] = old["data"] - lost
    dp_scale = new["data"] / old["data"]
    micro_scale = -(-old["data"] // new["data"])   # ceil
    return RemeshPlan(old, new, dp_scale, micro_scale)


def remesh(state, old_rules: MeshRules, new_mesh,
           fsdp: bool = True):
    """Reshard a (params/opt) pytree onto a new, smaller mesh."""
    new_rules = MeshRules(new_mesh, fsdp=fsdp)
    shardings = new_rules.shardings_of(new_rules.param_specs(state))
    return jax.tree.map(jax.device_put, state, shardings), new_rules
