"""Training substrate: AdamW, microbatched trainer with checkpoint/restart,
elastic remeshing, gradient compression."""
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.train_loop import TrainConfig, Trainer
__all__ = ["AdamWConfig", "TrainConfig", "Trainer", "adamw_init",
           "adamw_update"]
