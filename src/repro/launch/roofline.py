import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Roofline analysis with layer-count extrapolation.

XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE
regardless of trip count, so the raw numbers from the scan-over-layers
production lowering undercount per-layer work.  We therefore lower each
(arch x shape) cell a few more times with SMALL UNROLLED layer counts at
full production width, fit the exact linear model

    flops(L) = out + L * per_layer            (dense/moe/vlm: 2 lowerings)
    flops(e, d) = out + e*enc + d*dec         (encdec: 3 lowerings)
    flops(s, k) = out + s*shared + s*k*mamba  (hybrid: 3 lowerings)

and extrapolate to the production depth.  The same model corrects bytes and
collective bytes (collectives inside loop bodies appear once in the HLO
text too).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

    compute term    = flops_per_device / peak_flops
    memory term     = bytes_per_device / hbm_bw
    collective term = collective_bytes_per_device / ici_bw
"""

import argparse
import dataclasses
import json

from repro.configs import ARCHS, SHAPES, cells
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


def _with(cfg, **kw):
    return dataclasses.replace(cfg, **kw)


def _lower_variant(arch_cfg, shape_name: str, mesh, **rules_kw) -> dict:
    """Lower a config variant and return its raw record."""
    name = arch_cfg.name
    ARCHS[name] = arch_cfg          # registry override (restored by caller)
    try:
        return lower_cell(name, shape_name, mesh, **rules_kw)
    finally:
        pass


def calibration_plan(cfg):
    """Returns list of (tag, variant_cfg) lowerings + solver fn."""
    base = _with(cfg, unroll_layers=True, name=cfg.name)
    if cfg.family == "hybrid":
        v = [
            ("s1k1", _with(base, n_layers=1, attn_every=1)),
            ("s1k2", _with(base, n_layers=2, attn_every=2)),
            ("s2k1", _with(base, n_layers=2, attn_every=1)),
        ]

        def solve(f):
            mamba = f["s1k2"] - f["s1k1"]
            shared = f["s2k1"] - f["s1k1"] - mamba
            out = f["s1k1"] - shared - mamba
            n_super = cfg.n_layers // cfg.attn_every
            return out + n_super * shared + cfg.n_layers * mamba
        return v, solve
    if cfg.family == "encdec":
        v = [
            ("e1d1", _with(base, enc_layers=1, n_layers=1)),
            ("e2d1", _with(base, enc_layers=2, n_layers=1)),
            ("e1d2", _with(base, enc_layers=1, n_layers=2)),
        ]

        def solve(f):
            enc = f["e2d1"] - f["e1d1"]
            dec = f["e1d2"] - f["e1d1"]
            out = f["e1d1"] - enc - dec
            return out + cfg.enc_layers * enc + cfg.n_layers * dec
        return v, solve
    if cfg.family == "ssm" and cfg.xlstm:
        v = [
            ("p1", _with(base, n_layers=2)),    # 1 pair
            ("p2", _with(base, n_layers=4)),    # 2 pairs
        ]

        def solve(f):
            pair = f["p2"] - f["p1"]
            out = f["p1"] - pair
            return out + (cfg.n_layers // 2) * pair
        return v, solve
    # dense / moe / vlm
    v = [
        ("l1", _with(base, n_layers=1)),
        ("l2", _with(base, n_layers=2)),
    ]

    def solve(f):
        layer = f["l2"] - f["l1"]
        out = f["l1"] - layer
        return out + cfg.n_layers * layer
    return v, solve


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); decode D=batch."""
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * cfg.active_param_count() * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * cfg.active_param_count() * d
    return 2.0 * cfg.active_param_count() * shape.global_batch


def analyze_cell(arch: str, shape_name: str, mesh, calibrate: bool = True,
                 prod_record: dict | None = None, **rules_kw) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    prod = prod_record or lower_cell(arch, shape_name, mesh, **rules_kw)
    n_dev = prod["n_devices"]

    corrected_flops = prod["flops_per_device"]
    corrected_bytes = prod["bytes_per_device"]
    corrected_coll = dict(prod["collective_bytes"])
    calib = {}
    if calibrate:
        variants, solve = calibration_plan(cfg)
        recs = {}
        orig = ARCHS[arch]
        try:
            for tag, vcfg in variants:
                recs[tag] = _lower_variant(vcfg, shape_name, mesh,
                                           **rules_kw)
        finally:
            ARCHS[arch] = orig
        corrected_flops = solve({t: r["flops_per_device"]
                                 for t, r in recs.items()})
        corrected_bytes = solve({t: r["bytes_per_device"]
                                 for t, r in recs.items()})
        ops = set()
        for r in recs.values():
            ops |= set(r["collective_bytes"])
        corrected_coll = {
            op: max(0.0, solve({t: r["collective_bytes"].get(op, 0.0)
                                for t, r in recs.items()}))
            for op in ops
        }
        calib = {t: {"flops": r["flops_per_device"],
                     "compile_s": r["compile_s"]}
                 for t, r in recs.items()}

    coll_total = sum(corrected_coll.values())
    # collective bytes parsed from the per-device program text are already
    # per-device traffic
    compute_t = corrected_flops / PEAK_FLOPS
    memory_t = corrected_bytes / HBM_BW
    coll_t = coll_total / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    bound_s = max(terms.values())
    useful_frac = (mf / n_dev) / PEAK_FLOPS / bound_s if bound_s else 0.0

    return {
        **prod,
        "corrected": {
            "flops_per_device": corrected_flops,
            "bytes_per_device": corrected_bytes,
            "collective_bytes": corrected_coll,
        },
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_total": mf,
            "useful_flops_ratio": mf / (corrected_flops * n_dev)
            if corrected_flops else 0.0,
            "roofline_fraction": useful_frac,
        },
        "calibration": calib,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/roofline")
    ap.add_argument("--prod-dir", default="artifacts/dryrun",
                    help="reuse production records from the dry-run sweep")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    todo = ([(a, s) for a, s, st in cells() if st == "run"] if args.all
            else [(args.arch, args.shape)])
    for arch, shape in todo:
        out_path = os.path.join(args.out, f"{arch}__{shape}.json")
        if os.path.exists(out_path):
            print(f"[skip-cached] {arch} {shape}")
            continue
        prod = None
        prod_path = os.path.join(args.prod_dir,
                                 f"{arch}__{shape}__single.json")
        if os.path.exists(prod_path):
            with open(prod_path) as f:
                cand = json.load(f)
            if cand.get("ok"):
                prod = cand
        print(f"[roofline] {arch} {shape} ...", flush=True)
        try:
            rec = analyze_cell(arch, shape, mesh, prod_record=prod)
            r = rec["roofline"]
            print(f"  compute={r['compute_s']*1e3:.2f}ms "
                  f"memory={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms "
                  f"dominant={r['dominant']} "
                  f"roofline_frac={r['roofline_fraction']:.3f}", flush=True)
        except Exception as e:  # noqa: BLE001
            import traceback
            rec = {"arch": arch, "shape": shape, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"  FAIL {rec['error']}", flush=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
