"""Training launcher: ``--arch`` selects any registry architecture.

CPU smoke by default (reduced config); on a TPU pod the same driver takes
``--mesh data,model`` extents and shards via MeshRules (scale is config).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --steps 20 --batch 8 --seq 64 --ckpt /tmp/ck
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS
from repro.data import DataConfig, Prefetcher, SyntheticCorpus, pack_documents
from repro.models import build_model
from repro.training import AdamWConfig, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full published config (needs a pod)")
    ap.add_argument("--mesh", default="",
                    help="data,model extents for a sharded run, e.g. 4,2")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if not args.full_config:
        cfg = cfg.reduced()
    model = build_model(cfg)
    shard = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        from repro.parallel.sharding import MeshRules
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        shard = MeshRules(mesh)
    params = model.init(jax.random.key(0))
    print(f"[train] {cfg.name}: ~{cfg.param_count():.2e} params, "
          f"{args.steps} steps")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    data = Prefetcher(pack_documents(SyntheticCorpus(dcfg),
                                     args.steps + 4))
    tcfg = TrainConfig(
        steps=args.steps, n_micro=args.micro,
        compress_grads=args.compress_grads, ckpt_dir=args.ckpt,
        ckpt_every=max(args.steps // 4, 1),
        optimizer=AdamWConfig(warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps))
    trainer = Trainer(model, params, tcfg, shard=shard)
    if trainer.maybe_restore():
        print(f"[train] resumed at step {trainer.step}")
    hist = trainer.run(data)
    for h in hist[:: max(len(hist) // 8, 1)]:
        print(f"  step {h['step']:4d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.2f} {h['sec'] * 1e3:.0f} ms")
    if hist:
        print(f"[train] done: loss {hist[0]['loss']:.3f} -> "
              f"{hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
