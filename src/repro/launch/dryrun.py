import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production meshes using ShapeDtypeStruct inputs (no allocation), then record
memory_analysis / cost_analysis / collective-bytes for the roofline.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out artifacts/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cells
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.parallel.sharding import MeshRules
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

COLLECTIVE_RE = re.compile(
    r"=\s+(\S+)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the partitioned HLO."""
    out: dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        nbytes = DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[op] = out.get(op, 0.0) + nbytes
    return out


def _spec_tree(rules: MeshRules, shape_tree, spec_fn):
    specs = spec_fn(shape_tree)
    return rules.shardings_of(specs)


def lower_cell(arch: str, shape_name: str, mesh, **rules_kw) -> dict:
    """Build + lower + compile one cell; returns the roofline record."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    rules = MeshRules(mesh, **{"fsdp": True, **rules_kw})
    key = jax.random.key(0)
    t0 = time.time()

    params_shape = jax.eval_shape(model.init, key)
    param_sh = _spec_tree(rules, params_shape, rules.param_specs)
    specs = model.input_specs(shape)
    opt_cfg = AdamWConfig()

    if shape.kind == "train":
        batch_shape = specs["batch"]
        batch_sh = rules.shardings_of(rules.batch_specs(batch_shape))
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        opt_sh = {
            "m": _spec_tree(rules, params_shape, rules.param_specs),
            "v": _spec_tree(rules, params_shape, rules.param_specs),
            "step": NamedSharding(mesh, P()),
        }

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch, shard=rules))(params)
            params, opt_state, metrics = adamw_update(
                opt_cfg, grads, opt_state, params)
            return params, opt_state, loss, metrics

        fn = jax.jit(
            train_step,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, NamedSharding(mesh, P()),
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = fn.lower(params_shape, opt_shape, batch_shape)
    elif shape.kind == "prefill":
        cache_shape = specs["cache"]
        cache_sh = rules.shardings_of(rules.cache_specs(cache_shape))
        tok_sh = rules.shardings_of(rules.batch_specs(
            {"tokens": specs["tokens"]}))["tokens"]
        frontend = specs.get("frontend")

        def prefill(params, tokens, cache, frontend=None):
            return model.prefill(params, tokens, cache, shard=rules,
                                 frontend=frontend)

        fe_sh = None
        if frontend is not None:
            fe_sh = rules.shardings_of(
                rules.batch_specs({"frontend": frontend}))["frontend"]
        fn = jax.jit(
            prefill,
            in_shardings=(param_sh, tok_sh, cache_sh, fe_sh),
            out_shardings=None,
            donate_argnums=(2,),
        )
        with mesh:
            lowered = fn.lower(params_shape, specs["tokens"], cache_shape,
                               frontend)
    else:  # decode
        cache_shape = specs["cache"]
        cache_sh = rules.shardings_of(rules.cache_specs(cache_shape))
        tok_sh = rules.shardings_of(rules.batch_specs(
            {"tokens": specs["tokens"]}))["tokens"]

        def step(params, tokens, cache):
            return model.decode_step(params, tokens, cache, shard=rules)

        fn = jax.jit(
            step,
            in_shardings=(param_sh, tok_sh, cache_sh),
            out_shardings=None,
            donate_argnums=(2,),
        )
        with mesh:
            lowered = fn.lower(params_shape, specs["tokens"], cache_shape)

    lower_s = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    n_dev = mesh.devices.size
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "ok": True,
        "lower_s": round(lower_s, 1),
        "compile_s": round(compile_s, 1),
        # cost_analysis of the partitioned executable = per-device program
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "rules": rules_kw,
        "params_total": ARCHS[arch].param_count(),
        "params_active": ARCHS[arch].active_param_count(),
        "tokens": SHAPES[shape_name].global_batch * (
            SHAPES[shape_name].seq_len
            if SHAPES[shape_name].kind == "train" else
            (SHAPES[shape_name].seq_len
             if SHAPES[shape_name].kind == "prefill" else 1)),
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, shape, status in cells():
            print(f"{arch:24s} {shape:12s} {status}")
        return

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    todo = []
    if args.all:
        todo = [(a, s) for a, s, st in cells() if st == "run"]
    else:
        todo = [(args.arch, args.shape)]

    n_fail = 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        tag = "multi" if multi else "single"
        for arch, shape in todo:
            out_path = os.path.join(args.out, f"{arch}__{shape}__{tag}.json")
            if os.path.exists(out_path):
                print(f"[skip-cached] {arch} {shape} {tag}")
                continue
            print(f"[dryrun] {arch} {shape} mesh={tag} ...", flush=True)
            try:
                rec = lower_cell(arch, shape, mesh)
                print(f"  ok: compile={rec['compile_s']}s "
                      f"flops/dev={rec['flops_per_device']:.3e} "
                      f"coll={ {k: f'{v:.2e}' for k, v in rec['collective_bytes'].items()} }",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {"arch": arch, "shape": shape, "mesh": tag,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                n_fail += 1
                print(f"  FAIL: {rec['error']}", flush=True)
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1)
    print(f"done; failures={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
